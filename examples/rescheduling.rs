//! Re-scheduling demo (§4.4): the workload shifts mid-stream, the
//! monitor detects it, and Cascadia produces an updated plan.
//!
//!     cargo run --release --example rescheduling
//!
//! Phase 1 serves the easy trace 3; phase 2 switches to the hard trace
//! 1 at a higher rate. The monitor's sliding window flags the shift;
//! we re-run the bi-level scheduler and show how thresholds,
//! allocations and strategies moved — then verify the new plan beats
//! the stale one on the new workload.

use anyhow::Result;
use cascadia::cluster::ClusterSpec;
use cascadia::coordinator::monitor::{Monitor, MonitorConfig};
use cascadia::coordinator::simulate_cascade;
use cascadia::judge::Judger;
use cascadia::models::deepseek_cascade;
use cascadia::sched::outer::{optimize, select_plan, OuterOptions};
use cascadia::util::cli::Args;
use cascadia::workload::{estimate_stats, generate, paper_trace};

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 1200)?;
    let quality = args.f64_or("quality", 80.0)?;

    let cascade = deepseek_cascade();
    let cluster = ClusterSpec::paper_testbed();
    let judger = Judger::new(5);
    let opts = OuterOptions::default();

    // Phase 1: easy trace.
    let phase1 = generate(&paper_trace(3, 10.0), n, 1);
    let sweep1 = optimize(&cascade, &cluster, &judger, &phase1, 32, &opts)?;
    let plan1 = select_plan(&sweep1, quality).expect("phase-1 plan");
    println!("phase 1 plan   : {}", plan1.summary());

    // Monitor watches the live stream, baselined on phase 1.
    let mut monitor = Monitor::new(MonitorConfig::default(), estimate_stats(&phase1));

    // Phase 2: the workload shifts (hard trace, higher rate).
    let phase2 = generate(&paper_trace(1, 7.0), n, 2);
    let mut detected_at = None;
    for (i, req) in phase2.iter().enumerate() {
        if let Some(stats) = monitor.observe(*req) {
            detected_at = Some((i, stats));
            break;
        }
    }
    let (seen, new_stats) = detected_at.expect("shift should be detected");
    println!(
        "shift detected after {seen} requests: rate {:.1}->{:.1}, complexity {:.2}->{:.2}",
        monitor.baseline().rate,
        new_stats.rate,
        monitor.baseline().complexity_mean,
        new_stats.complexity_mean
    );

    // Re-schedule on the recent window.
    let sweep2 = optimize(&cascade, &cluster, &judger, &phase2, 32, &opts)?;
    let plan2 = select_plan(&sweep2, quality).expect("phase-2 plan");
    monitor.rebased(new_stats);
    println!("re-scheduled   : {}", plan2.summary());

    // Stale plan vs fresh plan on the new workload.
    let stale = simulate_cascade(&plan1, &cascade, &cluster, &judger, &phase2);
    let fresh = simulate_cascade(&plan2, &cascade, &cluster, &judger, &phase2)?;
    match stale {
        Ok(stale) => {
            println!(
                "stale plan on new workload : p95 {:.2}s quality {:.1}",
                stale.p95(),
                stale.quality
            );
            println!(
                "fresh plan on new workload : p95 {:.2}s quality {:.1}",
                fresh.p95(),
                fresh.quality
            );
            let speedup = stale.p95() / fresh.p95().max(1e-9);
            println!("re-scheduling gain: {speedup:.2}x on p95");
        }
        Err(e) => {
            // The stale plan may be outright infeasible for the new mix
            // (e.g. it never deployed the large tier).
            println!("stale plan cannot even serve the new workload: {e}");
            println!(
                "fresh plan on new workload : p95 {:.2}s quality {:.1}",
                fresh.p95(),
                fresh.quality
            );
        }
    }
    println!("re-schedules triggered: {}", monitor.reschedules);
    Ok(())
}
