//! Network serving demo: expose the real tiny-tier cascade over TCP
//! with the line-delimited JSON protocol, fire a few client requests
//! at it, and print the replies.
//!
//!     make artifacts && cargo run --release --example serve_tcp
//!
//! (Runs client and server in one process for the demo; the server
//! side is `coordinator::net::TcpFrontend` and works standalone.)

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};
use cascadia::coordinator::net::TcpFrontend;
use cascadia::router::PolicySpec;
use cascadia::runtime::{pjrt_factory, Manifest, TaskJudger};
use cascadia::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let addr = args.str_or("addr", "127.0.0.1:8741");

    let dir = std::env::var("CASCADIA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    let manifest = Manifest::load(&dir)
        .context("artifacts missing — run `make artifacts` first")?;
    let task = manifest.task.clone();
    let judger = TaskJudger::new(task.clone(), 6);
    let factory = pjrt_factory(dir);

    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = shutdown.clone();
    let server_addr = addr.clone();
    let n_tiers = manifest.tiers.len();
    let server = std::thread::spawn(move || {
        let fe = TcpFrontend::new(
            PolicySpec::uniform_threshold(n_tiers - 1, 80.0).expect("valid policy"),
            n_tiers,
            8,
        )
        .expect("policy fits the artifact tiers");
        fe.serve(&server_addr, &factory, &judger, sd)
    });
    std::thread::sleep(std::time::Duration::from_millis(500));
    println!("cascade listening on {addr}");

    // Client: one easy (m=1) and one hard (m=3) request.
    let mut stream = TcpStream::connect(&addr)?;
    let marker = task.marker_base as i32;
    let requests = [
        format!(r#"{{"id": 1, "prompt": [{}, 7, 7, 7], "max_new": 6}}"#, marker + 1),
        format!(
            r#"{{"id": 2, "prompt": [{}, 3, 5, 2, 10, 1, 13], "max_new": 6}}"#,
            marker + 3
        ),
    ];
    for r in &requests {
        writeln!(stream, "{r}")?;
    }
    let reader = BufReader::new(stream.try_clone()?);
    for (i, line) in reader.lines().enumerate() {
        println!("reply: {}", line?);
        if i + 1 == requests.len() {
            break;
        }
    }

    shutdown.store(true, Ordering::SeqCst);
    drop(stream);
    let _ = server.join();
    println!("done");
    Ok(())
}
