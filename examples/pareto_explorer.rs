//! Pareto explorer: walk the latency/quality trade-off interactively
//! from the command line.
//!
//!     cargo run --release --example pareto_explorer -- --trace 1 --gpus 32
//!
//! Prints the full Pareto front with thresholds, allocations and
//! parallelism strategies, then shows which plan each quality
//! requirement in {70, 75, ..., 95} selects — the decision a service
//! operator makes with Cascadia.

use anyhow::Result;
use cascadia::harness::{default_rate, Scenario};
use cascadia::models::{cascade_by_name, deepseek_cascade};
use cascadia::report::Table;
use cascadia::router::RoutingPolicy;
use cascadia::sched::outer::{select_plan, tchebycheff_winners, OuterOptions};
use cascadia::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let trace = args.usize_or("trace", 1)?;
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1200)?;
    let cascade = cascade_by_name(&args.str_or("cascade", "deepseek"))
        .unwrap_or_else(deepseek_cascade);

    let scenario = Scenario::new(cascade, gpus, trace, default_rate(trace), n, 3);
    let opts = OuterOptions::default();
    let (sweep, secs) = scenario.schedule(&opts)?;

    println!(
        "explored {} candidates in {secs:.1}s; utopia: L*={:.2}s Q*={:.1}\n",
        sweep.explored.len(),
        sweep.utopia.0,
        sweep.utopia.1
    );

    let mut front = Table::new(
        "Pareto front (latency ↑, quality ↑)",
        &["L(s)", "Q", "policy", "allocation f_i", "strategies"],
    );
    for p in &sweep.pareto {
        front.row(vec![
            format!("{:.2}", p.latency),
            format!("{:.1}", p.quality),
            p.plan.policy.label(),
            format!("{:?}", p.plan.tiers.iter().map(|t| t.gpus).collect::<Vec<_>>()),
            p.plan
                .tiers
                .iter()
                .map(|t| t.strategy.as_ref().map(|s| s.label()).unwrap_or_else(|| "-".into()))
                .collect::<Vec<_>>()
                .join(" | "),
        ]);
    }
    print!("{}", front.render());

    let winners = tchebycheff_winners(&sweep, &opts);
    println!("\nTchebycheff winners across λ sweep: {} distinct points", winners.len());

    let mut picks = Table::new(
        "operator view: plan per quality requirement",
        &["quality req", "selected plan"],
    );
    for q in [70.0, 75.0, 80.0, 85.0, 90.0, 95.0] {
        let pick = select_plan(&sweep, q)
            .map(|p| p.summary())
            .unwrap_or_else(|| "(unattainable)".into());
        picks.row(vec![format!("{q:.0}"), pick]);
    }
    print!("{}", picks.render());
    Ok(())
}
