//! End-to-end serving driver: the REAL three-layer stack.
//!
//!     make artifacts && cargo run --release --example e2e_serving
//!
//! Loads the three AOT-compiled tiny transformer tiers (JAX + Pallas →
//! HLO text → PJRT CPU), serves a synthetic-task trace through the
//! threshold-routed cascade with continuous batching, judges every
//! response against the task's ground truth, and reports latency,
//! throughput, quality, and per-tier processing ratios. Python is not
//! involved at any point of this run.
//!
//! Options: --n 60 --rate 2.0 --max-new 12 --h1 80 --h2 80
//!          --single-tier 2 (serve everything on one tier instead)

use std::path::PathBuf;

use anyhow::{Context, Result};
use cascadia::coordinator::server::{CascadeServer, ExecMode, ServerConfig};
use cascadia::report::{fmt_secs, Table};
use cascadia::router::{PolicySpec, RoutingPolicy};
use cascadia::runtime::{pjrt_factory, Manifest, TaskJudger};
use cascadia::util::cli::Args;
use cascadia::util::rng::Rng;

/// Build a prompt for the synthetic task: marker(m) + m seed tokens +
/// a couple of continuation tokens so the rule is established.
fn make_prompt(rng: &mut Rng, m: usize, marker_base: usize, vocab: usize) -> Vec<i32> {
    let mut p = vec![(marker_base + m) as i32];
    for _ in 0..m {
        p.push(rng.below(vocab as u64) as i32);
    }
    // Extend deterministically so the model sees a bit of context.
    for _ in 0..3 {
        let n = p.len();
        let next: i64 = p[n - m..].iter().map(|&t| t as i64).sum::<i64>()
            % vocab as i64;
        p.push(next as i32);
    }
    p
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 60)?;
    let rate = args.f64_or("rate", 2.0)?;
    let max_new = args.usize_or("max-new", 12)?;
    let h1 = args.f64_or("h1", 80.0)?;
    let h2 = args.f64_or("h2", 80.0)?;

    let dir = std::env::var("CASCADIA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    let manifest = Manifest::load(&dir)
        .context("artifacts missing — run `make artifacts` first")?;
    let task = manifest.task.clone();
    let tiers = manifest.cascade_order();
    println!(
        "cascade: {}",
        tiers
            .iter()
            .map(|t| format!("{}({} params)", t.config.name, t.config.n_params))
            .collect::<Vec<_>>()
            .join(" -> ")
    );

    // Trace: mixed difficulties (1..=4), Poisson arrivals.
    let mut rng = Rng::new(7);
    let mut t = 0.0f64;
    let mut trace = Vec::with_capacity(n);
    let mut difficulties = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exp(rate);
        let m = 1 + rng.below(task.max_difficulty as u64) as usize;
        difficulties.push(m);
        trace.push((t, make_prompt(&mut rng, m, task.marker_base, task.data_vocab)));
    }

    let single = args.get("single-tier").map(|s| s.parse::<usize>().unwrap());
    let config = match single {
        // Single-tier baseline: everything on one model.
        Some(tier) => ServerConfig {
            replicas: (0..3).map(|i| if i == tier { 2 } else { 0 }).collect(),
            max_batch: vec![4, 4, 4],
            policy: PolicySpec::threshold(match tier {
                0 => vec![0.0, 0.0],
                1 => vec![101.0, 0.0],
                _ => vec![101.0, 101.0],
            })?,
            max_new_tokens: max_new,
            exec: ExecMode::BatchLockstep,
        },
        None => ServerConfig {
            replicas: vec![2, 1, 1],
            max_batch: vec![4, 3, 2],
            policy: PolicySpec::threshold(vec![h1, h2])?,
            max_new_tokens: max_new,
            exec: ExecMode::BatchLockstep,
        },
    };
    // Tiers with 0 replicas still spawn one worker; routing keeps them
    // idle. Simplify: give every tier >= 1 worker.
    let config = ServerConfig {
        replicas: config.replicas.iter().map(|&r| r.max(1)).collect(),
        ..config
    };

    let judger = TaskJudger::new(task.clone(), max_new.min(8));
    let factory = pjrt_factory(dir.clone());
    let server = CascadeServer::new(config.clone())?;

    println!(
        "serving {n} requests at {rate:.1} req/s (policy {}, replicas {:?})...",
        config.policy.label(),
        config.replicas
    );
    let stats = server.serve(&trace, &factory, &judger)?;

    let mut table = Table::new("e2e serving results", &["metric", "value"]);
    table.row(vec!["requests".into(), stats.completions.len().to_string()]);
    table.row(vec!["wall clock".into(), fmt_secs(stats.wall_clock.as_secs_f64())]);
    table.row(vec!["throughput".into(), format!("{:.2} req/s", stats.throughput_rps())]);
    table.row(vec!["mean latency".into(), fmt_secs(stats.mean_latency())]);
    table.row(vec!["p95 latency".into(), fmt_secs(stats.p95_latency())]);
    table.row(vec!["mean quality".into(), format!("{:.1}/100", stats.mean_quality())]);
    let ratios = stats.processing_ratios();
    for (i, r) in ratios.iter().enumerate() {
        table.row(vec![
            format!("tier {} processed", i + 1),
            format!("{:.0}%", r * 100.0),
        ]);
    }
    // Quality by difficulty (the cascade should nail easy ones at tier
    // 1 and escalate hard ones).
    for m in 1..=task.max_difficulty {
        let scores: Vec<f64> = stats
            .completions
            .iter()
            .filter(|c| difficulties[c.id] == m)
            .map(|c| c.score)
            .collect();
        if !scores.is_empty() {
            let mean = scores.iter().sum::<f64>() / scores.len() as f64;
            let tiers_used: Vec<usize> = stats
                .completions
                .iter()
                .filter(|c| difficulties[c.id] == m)
                .map(|c| c.accepting_tier + 1)
                .collect();
            let mean_tier =
                tiers_used.iter().sum::<usize>() as f64 / tiers_used.len() as f64;
            table.row(vec![
                format!("difficulty {m}"),
                format!("quality {mean:.0}, mean accepting tier {mean_tier:.2}"),
            ]);
        }
    }
    print!("{}", table.render());

    // Record for EXPERIMENTS.md.
    table.write_csv("results/e2e_serving.csv")?;
    println!("wrote results/e2e_serving.csv");
    Ok(())
}
