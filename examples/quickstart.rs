//! Quickstart: schedule and simulate a cascade in ~20 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Builds the DeepSeek cascade on the paper's 32-GPU testbed, runs the
//! bi-level scheduler for a quality requirement of 85, and simulates
//! the resulting plan on a held-out trace.

use anyhow::Result;
use cascadia::harness::Scenario;
use cascadia::models::deepseek_cascade;
use cascadia::sched::outer::OuterOptions;

fn main() -> Result<()> {
    // A scenario = cascade + cluster + workload trace (+ judger).
    let scenario = Scenario::new(
        deepseek_cascade(),
        32,   // GPUs
        2,    // trace index (mixed chat/math)
        8.0,  // requests/s
        1500, // requests
        42,   // seed
    );

    // Bi-level scheduling: inner MILP picks allocations + parallelism,
    // outer Tchebycheff sweeps routing thresholds.
    let plan = scenario.cascadia_plan(85.0, &OuterOptions::default())?;
    println!("plan: {}", plan.summary());

    // Evaluate on a held-out trace with the discrete-event simulator.
    let sim = scenario.evaluate(&plan)?;
    println!(
        "p95 latency {:.2}s | throughput {:.2} req/s | quality {:.1}",
        sim.p95(),
        sim.throughput_rps,
        sim.quality
    );
    Ok(())
}
