#!/usr/bin/env python3
"""Perf-trajectory gate: compare a fresh BENCH_serving.json against the
committed BENCH_baseline.json with a tolerance band.

Two baseline shapes are understood:

* **ratio floors** (the committed seed baseline): top-level
  `p95_speedup`, `throughput_gain`, `prefix.page_reduction`,
  `prefix.prefill_reduction`, `chunked.ttft_speedup`,
  `swap.p95_speedup`, `swap.reprefill_reduction`,
  `disagg.ttft_p95_speedup`, `spec.p95_speedup` — machine-independent
  relative wins the fresh run must not regress below
  `floor * (1 - RTOL)`.
* **full report** (a captured BENCH_serving.json from the nightly
  artifact's smoke run, promoted by `scripts/promote_baseline.py` and
  committed as `--full-baseline`): additionally gates the absolute
  continuous-mode `p95_s` (must not exceed `baseline * (1 + SLACK)`)
  and `throughput_rps` (must not drop below `baseline * (1 - SLACK)`).
  SLACK is `--atol` for a hand-authored envelope; a promoted baseline
  (`"source": "nightly-capture"`) carries its own tighter `slack`
  field — measured floors need less headroom than guessed ones.
  Absolute numbers are in *simulated* seconds (time compression
  undone), so they are calibrated-model quantities, not raw runner
  wall clock — still, slack covers scheduler jitter on shared runners.

`--full-baseline PATH` names the committed full report; a missing file
is not an error (absolute gating simply reports "not yet baselined"),
so the job can carry the flag before the first nightly capture is
committed. The nightly bench-full job promotes its smoke-config run as
the re-baselining candidate.

`--check-baselines [DIR]` is a standalone mode: schema-validate every
committed `BENCH_*.json` in DIR (default `.`) — the lint job runs it
so a malformed or floor-less baseline fails CI *before* a bench run
silently gates against garbage.

Exit 0 = within band; exit 1 = regression (each violation printed).

Usage: bench_gate.py <fresh.json> <baseline.json>
           [--full-baseline BENCH_baseline_full.json]
           [--rtol 0.25] [--atol 0.40]
       bench_gate.py --check-baselines [DIR]
"""

import argparse
import glob
import json
import os
import sys


def ratio_of(report: dict, path: str):
    node = report
    for key in path.split("."):
        if not isinstance(node, dict) or key not in node:
            return None
        node = node[key]
    return node


def derived_ratios(report: dict) -> dict:
    """Machine-independent win ratios of a full or floor-style report."""
    out = {}
    for path in ("p95_speedup", "throughput_gain"):
        v = ratio_of(report, path)
        if v is not None:
            out[path] = float(v)
    v = ratio_of(report, "chunked.ttft_speedup")
    if v is not None:
        out["chunked.ttft_speedup"] = float(v)
    v = ratio_of(report, "swap.p95_speedup")
    if v is not None:
        out["swap.p95_speedup"] = float(v)
    swap = report.get("swap", {})
    if "reprefill_reduction" in swap:
        out["swap.reprefill_reduction"] = float(swap["reprefill_reduction"])
    elif swap.get("swap_prefill_tokens"):
        out["swap.reprefill_reduction"] = swap["recompute_prefill_tokens"] / max(
            swap["swap_prefill_tokens"], 1
        )
    prefix = report.get("prefix", {})
    if "page_reduction" in prefix:
        out["prefix.page_reduction"] = float(prefix["page_reduction"])
    elif prefix.get("shared_peak_pages"):
        out["prefix.page_reduction"] = prefix["baseline_peak_pages"] / max(
            prefix["shared_peak_pages"], 1
        )
    if "prefill_reduction" in prefix:
        out["prefix.prefill_reduction"] = float(prefix["prefill_reduction"])
    elif prefix.get("shared_prefill_tokens"):
        out["prefix.prefill_reduction"] = prefix["baseline_prefill_tokens"] / max(
            prefix["shared_prefill_tokens"], 1
        )
    disagg = report.get("disagg", {})
    if "ttft_p95_speedup" in disagg:
        out["disagg.ttft_p95_speedup"] = float(disagg["ttft_p95_speedup"])
    elif disagg.get("disagg_p95_ttft_s"):
        out["disagg.ttft_p95_speedup"] = disagg["unified_p95_ttft_s"] / max(
            disagg["disagg_p95_ttft_s"], 1e-12
        )
    spec = report.get("spec", {})
    if "p95_speedup" in spec:
        out["spec.p95_speedup"] = float(spec["p95_speedup"])
    elif spec.get("spec_p95_s"):
        out["spec.p95_speedup"] = spec["off_p95_s"] / max(spec["spec_p95_s"], 1e-12)
    return out


# Required floors of the primary (ratio-floor) baseline: a committed
# baseline missing one would silently stop gating that win.
REQUIRED_FLOORS = (
    "p95_speedup",
    "throughput_gain",
    "prefix.page_reduction",
    "prefix.prefill_reduction",
    "chunked.ttft_speedup",
    "swap.p95_speedup",
    "swap.reprefill_reduction",
    "disagg.ttft_p95_speedup",
    "spec.p95_speedup",
)


def check_baseline_file(path: str) -> list:
    """Schema-validate one committed BENCH_*.json; returns violations."""
    problems = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable or invalid JSON ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level must be a JSON object"]

    name = os.path.basename(path)
    is_full = "continuous" in doc or doc.get("source") == "nightly-capture"
    if name == "BENCH_baseline.json" or (not is_full and "p95_speedup" in doc):
        ratios = derived_ratios(doc)
        for key in REQUIRED_FLOORS:
            v = ratios.get(key)
            if v is None:
                problems.append(f"{path}: missing ratio floor '{key}'")
            elif not (isinstance(v, (int, float)) and v > 0 and v == v and v != float("inf")):
                problems.append(f"{path}: ratio floor '{key}' must be a positive finite number, got {v!r}")
    if is_full:
        cont = doc.get("continuous")
        if not isinstance(cont, dict):
            problems.append(f"{path}: full baseline lacks a 'continuous' section")
        else:
            for key in ("p95_s", "throughput_rps"):
                v = cont.get(key)
                if not (isinstance(v, (int, float)) and v > 0):
                    problems.append(f"{path}: continuous.{key} must be a positive number, got {v!r}")
        slack = doc.get("slack")
        if slack is not None and not (isinstance(slack, (int, float)) and 0 < slack <= 1):
            problems.append(f"{path}: slack must be in (0, 1], got {slack!r}")
        src = doc.get("source")
        if src is not None and not isinstance(src, str):
            problems.append(f"{path}: source must be a string label, got {src!r}")
        if doc.get("source") == "nightly-capture" and slack is None:
            problems.append(f"{path}: a nightly-capture baseline must carry its measured 'slack'")
    return problems


def check_baselines(root: str) -> int:
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    # Fresh bench output sitting in a workspace is not a baseline.
    paths = [p for p in paths if "baseline" in os.path.basename(p)]
    if not paths:
        print(f"no BENCH_*baseline*.json under {root}", file=sys.stderr)
        return 1
    problems = []
    for p in paths:
        got = check_baseline_file(p)
        problems.extend(got)
        if not got:
            print(f"ok  {p}")
    if problems:
        print("\nBASELINE SCHEMA ERRORS:", file=sys.stderr)
        for msg in problems:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"\n{len(paths)} baseline file(s) schema-valid")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", nargs="?")
    ap.add_argument("baseline", nargs="?")
    ap.add_argument(
        "--full-baseline",
        default=None,
        help="committed full BENCH_serving.json for absolute gating"
        " (missing file = not yet baselined, skipped with a note)",
    )
    ap.add_argument("--rtol", type=float, default=0.25, help="ratio-floor tolerance")
    ap.add_argument("--atol", type=float, default=0.40, help="absolute tolerance")
    ap.add_argument(
        "--check-baselines",
        nargs="?",
        const=".",
        default=None,
        metavar="DIR",
        help="schema-validate committed BENCH_*baseline*.json under DIR and exit",
    )
    args = ap.parse_args()

    if args.check_baselines is not None:
        return check_baselines(args.check_baselines)
    if not args.fresh or not args.baseline:
        ap.error("fresh and baseline reports are required (or use --check-baselines)")

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    full = None
    if args.full_baseline:
        try:
            with open(args.full_baseline) as f:
                full = json.load(f)
        except FileNotFoundError:
            print(
                f"note: no committed full baseline at {args.full_baseline}"
                " — absolute p95/throughput gating not yet enabled"
                " (commit the nightly artifact to turn it on)"
            )

    failures = []

    # Boolean gates: the fresh run must be green everywhere.
    for flag in ("win", "occupancy_ok"):
        if fresh.get(flag) is not True:
            failures.append(f"fresh report flag '{flag}' is not true")
    for section in ("prefix", "chunked", "swap", "disagg"):
        if fresh.get(section, {}).get("win") is not True:
            failures.append(f"fresh report flag '{section}.win' is not true")
    disagg = fresh.get("disagg", {})
    if disagg and not disagg.get("migrations"):
        failures.append("disagg section reports zero prefill->decode migrations")
    # Speculation gate: tolerated as absent (reports predating
    # cross-tier speculation), but a present section must be green,
    # byte-identical across the arms (the losslessness contract), and
    # must have actually accepted draft tokens.
    spec = fresh.get("spec")
    if spec is not None:
        spec_failures = []
        if spec.get("win") is not True:
            spec_failures.append("fresh report flag 'spec.win' is not true")
        if spec.get("outputs_match") is not True:
            spec_failures.append(
                "speculation is not lossless: on/off outputs diverged"
            )
        if not spec.get("accepted_tokens"):
            spec_failures.append("spec section accepted zero draft tokens")
        if spec_failures:
            failures.extend(spec_failures)
        else:
            print(
                "ok  spec.win:"
                f" p95 {spec.get('off_p95_s', 0.0):.3f}s ->"
                f" {spec.get('spec_p95_s', 0.0):.3f}s"
                f" (x{spec.get('p95_speedup', 0.0):.2f}),"
                f" deep iters {spec.get('off_deep_iterations', 0):.0f} ->"
                f" {spec.get('spec_deep_iterations', 0):.0f},"
                f" {spec.get('accepted_tokens', 0):.0f} tokens accepted"
            )
    # Tracing-overhead gate: tolerated as absent (reports predating the
    # obs subsystem), but when the section exists it must be green and
    # must have actually recorded events.
    tracing = fresh.get("tracing")
    if tracing is not None:
        tracing_failures = []
        if tracing.get("overhead_ok") is not True:
            tracing_failures.append("fresh report flag 'tracing.overhead_ok' is not true")
        if not tracing.get("events_recorded"):
            tracing_failures.append("tracing section recorded zero events")
        if tracing.get("dropped_events"):
            tracing_failures.append(
                f"tracing ring buffers dropped {tracing['dropped_events']} events"
            )
        if tracing_failures:
            failures.extend(tracing_failures)
        else:
            print(
                "ok  tracing.overhead_ok:"
                f" p95 {tracing.get('p95_off_s', 0.0):.3f}s ->"
                f" {tracing.get('p95_on_s', 0.0):.3f}s"
                f" ({100.0 * tracing.get('overhead_frac', 0.0):+.1f}%),"
                f" {tracing.get('events_recorded', 0):.0f} events"
            )
    # Profile-aggregation gate: same tolerance for absence (reports
    # predating the latency-attribution fold), but a present section
    # must be green, have matched every waterfall it attributed, and
    # have folded a non-empty event stream.
    profile = fresh.get("profile")
    if profile is not None:
        profile_failures = []
        if profile.get("fold_ok") is not True:
            profile_failures.append("fresh report flag 'profile.fold_ok' is not true")
        if not profile.get("matched"):
            profile_failures.append("profile section attributed zero requests")
        if not profile.get("events_folded"):
            profile_failures.append("profile section folded zero events")
        if profile_failures:
            failures.extend(profile_failures)
        else:
            print(
                "ok  profile.fold_ok:"
                f" {profile.get('events_folded', 0):.0f} events folded in"
                f" {profile.get('fold_wall_s', 0.0):.4f}s"
                f" ({100.0 * profile.get('fold_frac', 0.0):.2f}% of the run),"
                f" p95 attribution err {100.0 * profile.get('p95_err_frac', 0.0):.2f}%"
            )

    # Ratio floors.
    fresh_r = derived_ratios(fresh)
    base_r = derived_ratios(base)
    for key, floor in sorted(base_r.items()):
        got = fresh_r.get(key)
        if got is None:
            failures.append(f"fresh report lacks ratio '{key}'")
            continue
        bound = floor * (1.0 - args.rtol)
        if got < bound:
            failures.append(
                f"{key}: fresh {got:.3f} < baseline {floor:.3f} * (1-{args.rtol}) = {bound:.3f}"
            )
        else:
            print(f"ok  {key}: fresh {got:.3f} >= floor {bound:.3f}")

    # Absolute p95 / throughput when a full report is available: the
    # committed --full-baseline wins, else a full-shaped primary
    # baseline (backward compatible). A measured (nightly-capture)
    # baseline carries its own slack — tighter than the hand-authored
    # envelope's --atol, because its floors were observed, not guessed.
    abs_src = full or base
    base_cont = abs_src.get("continuous", {})
    fresh_cont = fresh.get("continuous", {})
    slack = args.atol
    if abs_src.get("source") == "nightly-capture" and "slack" in abs_src:
        slack = float(abs_src["slack"])
        print(f"measured baseline ({abs_src.get('captured_at', 'nightly-capture')}): slack {slack}")
    if "p95_s" in base_cont:
        cap = base_cont["p95_s"] * (1.0 + slack)
        got = fresh_cont.get("p95_s", float("inf"))
        if got > cap:
            failures.append(
                f"continuous.p95_s: fresh {got:.3f}s > baseline {base_cont['p95_s']:.3f}s"
                f" * (1+{slack}) = {cap:.3f}s"
            )
        else:
            print(f"ok  continuous.p95_s: {got:.3f}s <= cap {cap:.3f}s")
    if "throughput_rps" in base_cont:
        floor = base_cont["throughput_rps"] * (1.0 - slack)
        got = fresh_cont.get("throughput_rps", 0.0)
        if got < floor:
            failures.append(
                f"continuous.throughput_rps: fresh {got:.3f} < baseline"
                f" {base_cont['throughput_rps']:.3f} * (1-{slack}) = {floor:.3f}"
            )
        else:
            print(f"ok  continuous.throughput_rps: {got:.3f} >= floor {floor:.3f}")

    if failures:
        print("\nPERF REGRESSION:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\nperf trajectory within tolerance band")
    return 0


if __name__ == "__main__":
    sys.exit(main())
