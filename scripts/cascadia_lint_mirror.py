#!/usr/bin/env python3
"""Development mirror of the in-repo `cascadia-lint` static-analysis pass.

The AUTHORITATIVE implementation is `rust/src/analysis/` (run via the
`cascadia-lint` binary and enforced by the tree-clean test in
`rust/src/analysis/mod.rs`); this mirror re-implements the same token-level
semantics in Python so violation sweeps can run in environments without a
Rust toolchain. Keep the two in lockstep: every rule change lands in both.

Usage: python3 scripts/cascadia_lint_mirror.py [rust/src]
Exit codes: 0 clean, 1 violations, 2 usage/io error.
"""

import os
import sys

# ---------------------------------------------------------------- rules

RULES = ("lock-order", "blocking-under-lock", "hot-path-unwrap", "determinism")

# Declared lock hierarchy, outermost tier first. Nested acquisitions must
# move strictly down this list; same-tier or upward nesting is flagged.
LOCK_HIERARCHY = (("pending",), ("batcher",), ("queue_time", "first_tokens"), ("policy",))

ACQUIRE_METHODS = ("lock", "read", "write", "plock", "pread", "pwrite")
BLOCKING_CALLS = ("recv", "recv_timeout", "join", "sleep", "generate", "step", "prefill_chunk")
UNWRAP_METHODS = ("unwrap", "expect")

MULTI_OPS = (
    "<<=", ">>=", "..=", "...",
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "..",
    "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
)


def unwrap_scope(rel):
    return rel.startswith("engine/") or rel.startswith("coordinator/")


def determinism_scope(rel):
    # `obs/` is pinned (the DES emits trace events through it) except
    # `obs/clock.rs`, the designated wall-clock boundary.
    # `engine/migrate.rs` is pinned because the disagg DES models the
    # MigrationHub's exact routing. `engine/spec.rs` is pinned because
    # the DES models draft agreement with the same pure function the
    # live SpecPair replays through.
    return (
        rel.startswith("sim/")
        or rel.startswith("sched/")
        or rel == "engine/scheduler.rs"
        or rel == "engine/migrate.rs"
        or rel == "engine/spec.rs"
        or (rel.startswith("obs/") and rel != "obs/clock.rs")
    )


def hierarchy_rank(name):
    for rank, tier in enumerate(LOCK_HIERARCHY):
        if name in tier:
            return rank
    return None


def normalize_lock_name(name):
    if name is None:
        return None
    if hierarchy_rank(name) is not None:
        return name
    for suffix in ("_ref", "_arc"):
        if name.endswith(suffix):
            stripped = name[: -len(suffix)]
            if hierarchy_rank(stripped) is not None:
                return stripped
    return name


# ---------------------------------------------------------------- lexer

IDENT = "ident"
PUNCT = "punct"
LIT_STR = "str"
LIT_CHAR = "char"
LIT_NUM_INT = "int"
LIT_NUM_FLOAT = "float"
LIFETIME = "lifetime"


class Tok:
    __slots__ = ("kind", "text", "line")

    def __init__(self, kind, text, line):
        self.kind = kind
        self.text = text
        self.line = line

    def __repr__(self):
        return f"{self.kind}({self.text!r}@{self.line})"


def is_ident_start(c):
    return c.isalpha() or c == "_"


def is_ident_char(c):
    return c.isalnum() or c == "_"


def lex(src):
    """Returns (tokens, comments) where comments is [(line, text)] for
    line comments only (directives never live in block comments)."""
    toks = []
    comments = []
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "/":
            j = i
            while j < n and src[j] != "\n":
                j += 1
            comments.append((line, src[i:j]))
            i = j
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if src[j] == "\n":
                    line += 1
                    j += 1
                elif src[j] == "/" and j + 1 < n and src[j + 1] == "*":
                    depth += 1
                    j += 2
                elif src[j] == "*" and j + 1 < n and src[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            i = j
            continue
        # Raw strings / byte strings / raw byte strings: (b?)r#*" ... "#*
        if c in "rb":
            j = i
            if src[j] == "b" and j + 1 < n and src[j + 1] == "r":
                j += 1
            if src[j] == "r":
                k = j + 1
                hashes = 0
                while k < n and src[k] == "#":
                    hashes += 1
                    k += 1
                if k < n and src[k] == '"':
                    # raw string body
                    close = '"' + "#" * hashes
                    start_line = line
                    k += 1
                    while k < n:
                        if src[k] == "\n":
                            line += 1
                            k += 1
                        elif src[k] == '"' and src[k : k + 1 + hashes] == close:
                            k += 1 + hashes
                            break
                        else:
                            k += 1
                    toks.append(Tok(LIT_STR, "", start_line))
                    i = k
                    continue
                if hashes == 1 and k < n and is_ident_start(src[k]):
                    # raw identifier r#ident
                    m = k
                    while m < n and is_ident_char(src[m]):
                        m += 1
                    toks.append(Tok(IDENT, src[k:m], line))
                    i = m
                    continue
        if c == "b" and i + 1 < n and src[i + 1] == "'":
            # byte char literal b'x'
            j = i + 2
            if j < n and src[j] == "\\":
                j += 2
            else:
                j += 1
            while j < n and src[j] != "'":
                j += 1
            toks.append(Tok(LIT_CHAR, "", line))
            i = j + 1
            continue
        if c == "b" and i + 1 < n and src[i + 1] == '"':
            i += 1
            c = '"'  # fall through to string below
        if c == '"':
            j = i + 1
            start_line = line
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == "\n":
                    line += 1
                    j += 1
                elif src[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            toks.append(Tok(LIT_STR, "", start_line))
            i = j
            continue
        if c == "'":
            # char literal vs lifetime
            if i + 1 < n and src[i + 1] == "\\":
                j = i + 2 + 1  # skip escaped char
                while j < n and src[j] != "'":
                    j += 1
                toks.append(Tok(LIT_CHAR, "", line))
                i = j + 1
                continue
            if i + 2 < n and src[i + 2] == "'" and src[i + 1] != "'":
                toks.append(Tok(LIT_CHAR, "", line))
                i = i + 3
                continue
            j = i + 1
            while j < n and is_ident_char(src[j]):
                j += 1
            toks.append(Tok(LIFETIME, src[i:j], line))
            i = j
            continue
        if is_ident_start(c):
            j = i
            while j < n and is_ident_char(src[j]):
                j += 1
            toks.append(Tok(IDENT, src[i:j], line))
            i = j
            continue
        if c.isdigit():
            j = i
            is_float = False
            is_hex = src[i : i + 2] in ("0x", "0X")
            while j < n:
                d = src[j]
                if d.isalnum() or d == "_":
                    if not is_hex and d in "eE" and j + 1 < n and src[j + 1] in "+-":
                        is_float = True
                        j += 2
                        continue
                    j += 1
                elif d == "." and j + 1 < n and src[j + 1].isdigit():
                    is_float = True
                    j += 1
                else:
                    break
            text = src[i:j]
            if not is_hex and ("e" in text or "E" in text) and "x" not in text:
                is_float = True
            toks.append(Tok(LIT_NUM_FLOAT if is_float else LIT_NUM_INT, text, line))
            i = j
            continue
        matched = None
        for op in MULTI_OPS:
            if src.startswith(op, i):
                matched = op
                break
        if matched:
            toks.append(Tok(PUNCT, matched, line))
            i += len(matched)
        else:
            toks.append(Tok(PUNCT, c, line))
            i += 1
    return toks, comments


# ------------------------------------------------------------ directives


def parse_directives(comments):
    """Returns (allows, errors): allows is {(line, rule)} granted for the
    comment's own line and the next; errors are bad-annotation violations."""
    allows = set()
    errors = []
    for line, text in comments:
        marker = "cascadia-lint:"
        pos = text.find(marker)
        if pos < 0:
            continue
        rest = text[pos + len(marker) :].strip()
        rule, reason, err = parse_allow(rest)
        if err is not None:
            errors.append((line, err))
            continue
        _ = reason
        allows.add((line, rule))
        allows.add((line + 1, rule))
    return allows, errors


def parse_allow(rest):
    """Grammar: allow(<rule>, reason = "<non-empty>"). Returns
    (rule, reason, error)."""
    if not rest.startswith("allow(") or not rest.endswith(")"):
        return None, None, "directive must be exactly `allow(<rule>, reason = \"...\")`"
    inner = rest[len("allow(") : -1]
    comma = inner.find(",")
    if comma < 0:
        return None, None, "missing `, reason = \"...\"`"
    rule = inner[:comma].strip()
    if rule not in RULES:
        return None, None, f"unknown rule `{rule}`"
    tail = inner[comma + 1 :].strip()
    if not tail.startswith("reason"):
        return None, None, "missing `reason`"
    tail = tail[len("reason") :].strip()
    if not tail.startswith("="):
        return None, None, "missing `=` after `reason`"
    tail = tail[1:].strip()
    if len(tail) < 2 or tail[0] != '"' or tail[-1] != '"':
        return None, None, "reason must be a double-quoted string"
    if not tail[1:-1].strip():
        return None, None, "reason must not be empty"
    return rule, tail[1:-1], None


# ---------------------------------------------------------------- lints


class Guard:
    __slots__ = ("name", "rank", "var", "depth", "temp", "line")

    def __init__(self, name, rank, var, depth, temp, line):
        self.name = name
        self.rank = rank
        self.var = var
        self.depth = depth
        self.temp = temp
        self.line = line


def lint_tokens(rel, toks):
    """Returns [(line, rule, message)] (pre-annotation)."""
    out = []
    in_unwrap = unwrap_scope(rel)
    in_det = determinism_scope(rel)

    depth = 0
    guards = []
    test_stack = []
    pending_test = False
    pending_let_var = None
    last_stmt = None  # (set of lock names, depth)
    cur_stmt = set()

    def tok(j):
        return toks[j] if 0 <= j < len(toks) else None

    def skip_unwrap_chain(j):
        """j points just past an acquisition's `()`; skip `.unwrap()` /
        `.expect(...)` links, returning the index of the next token."""
        while True:
            a, b, c = tok(j), tok(j + 1), tok(j + 2)
            if (
                a is not None
                and a.kind == PUNCT
                and a.text == "."
                and b is not None
                and b.kind == IDENT
                and b.text in UNWRAP_METHODS
                and c is not None
                and c.kind == PUNCT
                and c.text == "("
            ):
                pdepth = 1
                k = j + 3
                while k < len(toks) and pdepth > 0:
                    if toks[k].kind == PUNCT and toks[k].text == "(":
                        pdepth += 1
                    elif toks[k].kind == PUNCT and toks[k].text == ")":
                        pdepth -= 1
                    k += 1
                j = k
            else:
                return j

    i = 0
    while i < len(toks):
        t = toks[i]
        in_test = bool(test_stack)

        # Attributes: skip their tokens entirely; `test` anywhere inside
        # marks the next braced item as test-gated.
        if t.kind == PUNCT and t.text == "#":
            nxt = tok(i + 1)
            j = i + 1
            inner = nxt is not None and nxt.kind == PUNCT and nxt.text == "!"
            if inner:
                j += 1
            open_tok = tok(j)
            if open_tok is not None and open_tok.kind == PUNCT and open_tok.text == "[":
                bdepth = 1
                k = j + 1
                saw_test = False
                while k < len(toks) and bdepth > 0:
                    tk = toks[k]
                    if tk.kind == PUNCT and tk.text == "[":
                        bdepth += 1
                    elif tk.kind == PUNCT and tk.text == "]":
                        bdepth -= 1
                    elif tk.kind == IDENT and tk.text == "test":
                        saw_test = True
                    k += 1
                if saw_test and not inner:
                    pending_test = True
                i = k
                continue

        if t.kind == PUNCT and t.text == "{":
            depth += 1
            if pending_test:
                test_stack.append(depth)
                pending_test = False
            last_stmt = None
            cur_stmt = set()
        elif t.kind == PUNCT and t.text == "}":
            guards = [g for g in guards if g.depth < depth]
            if test_stack and test_stack[-1] == depth:
                test_stack.pop()
            depth -= 1
            last_stmt = None
            cur_stmt = set()
        elif t.kind == PUNCT and t.text == ";":
            guards = [g for g in guards if not (g.temp and g.depth == depth)]
            last_stmt = (cur_stmt, depth)
            cur_stmt = set()
            pending_let_var = None
            pending_test = False
        elif t.kind == PUNCT and t.text == "=>":
            last_stmt = None
            cur_stmt = set()
        elif t.kind == IDENT and t.text == "let":
            nxt = tok(i + 1)
            if nxt is not None and nxt.kind == IDENT and nxt.text == "mut":
                nxt = tok(i + 2)
            if nxt is not None and nxt.kind == IDENT:
                pending_let_var = nxt.text
            else:
                pending_let_var = None
        elif (
            t.kind == IDENT
            and t.text == "drop"
            and tok(i + 1) is not None
            and tok(i + 1).kind == PUNCT
            and tok(i + 1).text == "("
            and tok(i + 2) is not None
            and tok(i + 2).kind == IDENT
            and tok(i + 3) is not None
            and tok(i + 3).kind == PUNCT
            and tok(i + 3).text == ")"
        ):
            var = tok(i + 2).text
            guards = [g for g in guards if g.var != var]

        # Lock acquisition: `.lock()` / `.read()` / `.write()` (+ p-forms),
        # empty parens only (RwLock/Mutex take no arguments).
        if (
            t.kind == PUNCT
            and t.text == "."
            and tok(i + 1) is not None
            and tok(i + 1).kind == IDENT
            and tok(i + 1).text in ACQUIRE_METHODS
            and tok(i + 2) is not None
            and tok(i + 2).kind == PUNCT
            and tok(i + 2).text == "("
            and tok(i + 3) is not None
            and tok(i + 3).kind == PUNCT
            and tok(i + 3).text == ")"
            and not in_test
        ):
            line = tok(i + 1).line
            prev = tok(i - 1)
            raw = prev.text if prev is not None and prev.kind == IDENT else None
            name = normalize_lock_name(raw)
            rank = hierarchy_rank(name) if name is not None else None
            # (a) same-lock re-entry while a guard is live
            if name is not None:
                for g in guards:
                    if g.name == name:
                        out.append((
                            line,
                            "lock-order",
                            f"`{name}` re-acquired while already held "
                            f"(guard taken on line {g.line}): deadlock",
                        ))
                        break
            # (b) hierarchy order: nested acquisitions must move strictly
            # down the declared hierarchy
            if rank is not None:
                for g in guards:
                    if g.rank is not None and g.name != name and rank <= g.rank:
                        out.append((
                            line,
                            "lock-order",
                            f"`{name}` (tier {rank}) acquired while holding "
                            f"`{g.name}` (tier {g.rank}, line {g.line}): "
                            "out of declared hierarchy order",
                        ))
                        break
            # binding shape decides the guard's lifetime
            j = skip_unwrap_chain(i + 4)
            nxt = tok(j)
            if nxt is not None and nxt.kind == PUNCT and nxt.text == ";":
                guards.append(Guard(name, rank, pending_let_var, depth, False, line))
            elif nxt is not None and nxt.kind == PUNCT and nxt.text == "{":
                guards.append(Guard(name, rank, None, depth + 1, False, line))
            else:
                # (c) statement-adjacent churn: the previous statement
                # took and dropped this same lock
                if (
                    name is not None
                    and last_stmt is not None
                    and last_stmt[1] == depth
                    and name in last_stmt[0]
                ):
                    out.append((
                        line,
                        "lock-order",
                        f"`{name}` re-acquired immediately after the previous "
                        "statement released it: take one guard and reuse it",
                    ))
                if name is not None:
                    cur_stmt.add(name)
                guards.append(Guard(name, rank, None, depth, True, line))

        # Blocking call while any guard is held.
        if (
            t.kind == IDENT
            and t.text in BLOCKING_CALLS
            and tok(i + 1) is not None
            and tok(i + 1).kind == PUNCT
            and tok(i + 1).text == "("
            and guards
            and not in_test
        ):
            held = ", ".join(
                f"`{g.name}`" if g.name is not None else "<unnamed>" for g in guards
            )
            out.append((
                t.line,
                "blocking-under-lock",
                f"`{t.text}()` called while holding {held}: a blocked worker "
                "starves every other thread contending for the guard",
            ))

        # Hot-path unwrap/expect ban.
        if (
            in_unwrap
            and not in_test
            and t.kind == IDENT
            and t.text in UNWRAP_METHODS
            and tok(i - 1) is not None
            and tok(i - 1).kind == PUNCT
            and tok(i - 1).text == "."
            and tok(i + 1) is not None
            and tok(i + 1).kind == PUNCT
            and tok(i + 1).text == "("
        ):
            out.append((
                t.line,
                "hot-path-unwrap",
                f"`.{t.text}()` on an engine/coordinator hot path: handle the "
                "failure or annotate the invariant",
            ))

        # Determinism surface.
        if in_det and not in_test:
            if t.kind == IDENT and t.text in ("HashMap", "HashSet"):
                out.append((
                    t.line,
                    "determinism",
                    f"`{t.text}` in a determinism-pinned module: iteration "
                    "order is unstable; use BTreeMap/BTreeSet or annotate",
                ))
            if (
                t.kind == IDENT
                and t.text in ("Instant", "SystemTime")
                and tok(i + 1) is not None
                and tok(i + 1).kind == PUNCT
                and tok(i + 1).text == "::"
                and tok(i + 2) is not None
                and tok(i + 2).kind == IDENT
                and tok(i + 2).text == "now"
            ):
                out.append((
                    t.line,
                    "determinism",
                    f"`{t.text}::now()` in a determinism-pinned module: wall "
                    "clock reads break DES/engine replay equivalence",
                ))
            if t.kind == PUNCT and t.text in ("==", "!="):
                p, q = tok(i - 1), tok(i + 1)
                if (p is not None and p.kind == LIT_NUM_FLOAT) or (
                    q is not None and q.kind == LIT_NUM_FLOAT
                ):
                    out.append((
                        t.line,
                        "determinism",
                        "direct f64 comparison against a literal: use an "
                        "epsilon or restructure",
                    ))
        i += 1
    return out


def lint_source(rel, src):
    toks, comments = lex(src)
    allows, bad = parse_directives(comments)
    violations = [
        (line, rule, msg)
        for (line, rule, msg) in lint_tokens(rel, toks)
        if (line, rule) not in allows
    ]
    for line, err in bad:
        violations.append((line, "bad-annotation", err))
    violations.sort(key=lambda v: (v[0], v[1]))
    return violations


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "rust/src"
    if not os.path.isdir(root):
        print(f"error: {root} is not a directory", file=sys.stderr)
        return 2
    if not LOCK_HIERARCHY:
        print("error: no lock hierarchy declared", file=sys.stderr)
        return 2
    files = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for f in sorted(filenames):
            if f.endswith(".rs"):
                files.append(os.path.join(dirpath, f))
    total = 0
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        for line, rule, msg in lint_source(rel, src):
            print(f"{rel}:{line}: [{rule}] {msg}")
            total += 1
    print(f"cascadia-lint (mirror): {len(files)} files, {total} violation(s)")
    return 0 if total == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
