#!/usr/bin/env python3
"""Promote a captured smoke-config bench run to the measured absolute
baseline (`BENCH_baseline_full.json`).

The nightly `bench-full` job runs the smoke bench config (the same
config the push/PR gate runs), captures its report, and feeds it here.
Promotion validates that the capture is *green* — every win flag true,
occupancy held, migrations actually happened — and then rewrites it as
a baseline document:

* `"source": "nightly-capture"` labels it as measured, which makes
  `scripts/bench_gate.py` apply the file's own `slack` to the absolute
  p95/throughput floors instead of the looser hand-authored `--atol`
  envelope (measured floors need less headroom than guessed ones);
* `continuous.p95_s` / `continuous.throughput_rps` are copied verbatim
  — the gate's absolute anchors;
* the machine-independent win ratios ride along under `ratios` for
  review (the primary `BENCH_baseline.json` floors stay hand-curated).

A red capture refuses to promote (exit 1): regressing the *baseline*
to match a regression is exactly what this pipeline exists to prevent.
Committing the artifact this script writes is still a human act — CI
only uploads it.

Usage: promote_baseline.py <captured_smoke.json>
           [--out BENCH_baseline_full.json] [--slack 0.25]
           [--captured-at LABEL]
"""

import argparse
import json
import sys

from bench_gate import derived_ratios, REQUIRED_FLOORS


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("capture", help="smoke-config BENCH_serving.json to promote")
    ap.add_argument("--out", default="BENCH_baseline_full.json")
    ap.add_argument(
        "--slack",
        type=float,
        default=0.25,
        help="absolute tolerance the promoted baseline carries (tighter than"
        " the hand-authored envelope's 0.40)",
    )
    ap.add_argument(
        "--captured-at",
        default=None,
        help="provenance label (e.g. the capturing commit SHA or run id)",
    )
    args = ap.parse_args()
    if not 0 < args.slack <= 1:
        ap.error(f"--slack must be in (0, 1], got {args.slack}")

    with open(args.capture) as f:
        cap = json.load(f)

    problems = []
    for flag in ("win", "occupancy_ok"):
        if cap.get(flag) is not True:
            problems.append(f"capture flag '{flag}' is not true")
    for section in ("prefix", "chunked", "swap", "disagg"):
        if cap.get(section, {}).get("win") is not True:
            problems.append(f"capture flag '{section}.win' is not true")
    if not cap.get("disagg", {}).get("migrations"):
        problems.append("capture saw zero prefill->decode migrations")
    cont = cap.get("continuous", {})
    for key in ("p95_s", "throughput_rps"):
        v = cont.get(key)
        if not (isinstance(v, (int, float)) and v > 0):
            problems.append(f"capture continuous.{key} must be a positive number, got {v!r}")
    ratios = derived_ratios(cap)
    for key in REQUIRED_FLOORS:
        if key not in ratios:
            problems.append(f"capture lacks ratio '{key}'")
    if problems:
        print("REFUSING TO PROMOTE (capture is not green):", file=sys.stderr)
        for msg in problems:
            print(f"  - {msg}", file=sys.stderr)
        return 1

    doc = {
        "_comment": (
            "Measured absolute-envelope baseline for scripts/bench_gate.py"
            " --full-baseline, promoted from a nightly smoke-config capture"
            " by scripts/promote_baseline.py. The gate applies this file's"
            " 'slack' to the continuous p95/throughput floors."
        ),
        "source": "nightly-capture",
        "slack": args.slack,
        "continuous": {
            "p95_s": cont["p95_s"],
            "throughput_rps": cont["throughput_rps"],
        },
        "ratios": {k: ratios[k] for k in REQUIRED_FLOORS},
    }
    if args.captured_at:
        doc["captured_at"] = args.captured_at
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(
        f"promoted {args.capture} -> {args.out}:"
        f" p95 {cont['p95_s']:.3f}s, {cont['throughput_rps']:.3f} rps,"
        f" slack {args.slack}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
