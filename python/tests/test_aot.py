"""AOT export checks: HLO text is producible and parseable, the
manifest matches the parameter blobs, and — when `make artifacts` has
run — the shipped artifacts exhibit the monotone tier-quality gradient
the cascade relies on.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lowering_produces_hlo_text():
    cfg = M.TIERS["small"]
    text = aot.lower_prefill(cfg)
    assert "ENTRY" in text and "f32" in text
    assert len(text) > 10_000
    text = aot.lower_decode(cfg)
    assert "ENTRY" in text
    # Decode updates a (L, Hkv, S, hd) cache.
    shape = f"f32[{cfg.n_layers},{cfg.n_kv_heads},{cfg.max_seq},{cfg.head_dim}]"
    assert shape in text


def test_param_export_roundtrip(tmp_path):
    cfg = M.TIERS["small"]
    params = M.init_params(cfg, seed=3)
    path = tmp_path / "p.bin"
    n = aot.export_params(params, cfg, str(path))
    assert n == cfg.n_params
    blob = np.fromfile(path, dtype="<f4")
    assert blob.size == n
    # First entry is the embedding, in order.
    emb = np.asarray(params["embed"]).reshape(-1)
    np.testing.assert_array_equal(blob[: emb.size], emb)


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_is_consistent():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest["tiers"]) == {"small", "medium", "large"}
    for tier, entry in manifest["tiers"].items():
        cfg = M.TIERS[tier]
        assert entry["config"]["n_params"] == cfg.n_params
        blob = os.path.join(ARTIFACTS, entry["files"]["params"])
        assert os.path.getsize(blob) == entry["n_floats"] * 4
        n = sum(int(np.prod(p["shape"])) for p in entry["params"])
        assert n == entry["n_floats"]
        for key in ("prefill", "decode"):
            assert os.path.exists(os.path.join(ARTIFACTS, entry["files"][key]))


@needs_artifacts
def test_tier_quality_gradient_is_monotone():
    """The cascade premise: each tier masters strictly more difficulty
    levels than the previous one."""
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    acc = {t: manifest["tiers"][t]["eval_accuracy"] for t in manifest["tiers"]}
    # Every tier nails difficulty 1.
    for t in acc:
        assert acc[t]["1"] > 0.9, (t, acc[t])
    # medium > small on difficulty 2; large > medium on difficulty 3.
    assert acc["medium"]["2"] > 0.8 > acc["small"]["2"]
    assert acc["large"]["3"] > 0.8 > acc["medium"]["3"]
    assert acc["large"]["4"] > 0.8
