"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/GQA ratios; every property asserts allclose
against `ref.py`. This is the core correctness signal for the kernels
that the exported HLO artifacts embed.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import decode_attention, flash_attention
from compile.kernels.matmul import blocked_matmul
from compile.kernels import ref

TOL = dict(rtol=2e-4, atol=2e-4)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype("float32"))


# Hypothesis strategy: (Hq, Hkv, Sq, Sk, D) with Hq % Hkv == 0.
@st.composite
def attn_shapes(draw):
    hkv = draw(st.sampled_from([1, 2, 4]))
    group = draw(st.sampled_from([1, 2, 3]))
    hq = hkv * group
    sq = draw(st.integers(1, 70))
    d = draw(st.sampled_from([8, 16, 32]))
    return hq, hkv, sq, d


@settings(max_examples=25, deadline=None)
@given(attn_shapes(), st.integers(0, 2**31 - 1))
def test_flash_attention_causal_matches_ref(shape, seed):
    hq, hkv, sq, d = shape
    rng = np.random.default_rng(seed)
    q = rand(rng, hq, sq, d)
    k = rand(rng, hkv, sq, d)
    v = rand(rng, hkv, sq, d)
    out = flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **TOL)


@settings(max_examples=15, deadline=None)
@given(attn_shapes(), st.integers(0, 2**31 - 1))
def test_flash_attention_non_causal(shape, seed):
    hq, hkv, sq, d = shape
    rng = np.random.default_rng(seed)
    q = rand(rng, hq, sq, d)
    k = rand(rng, hkv, sq, d)
    v = rand(rng, hkv, sq, d)
    out = flash_attention(q, k, v, causal=False)
    exp = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **TOL)


@pytest.mark.parametrize("block", [8, 16, 32])
def test_flash_attention_block_size_invariance(block):
    rng = np.random.default_rng(0)
    q = rand(rng, 4, 33, 16)
    k = rand(rng, 2, 33, 16)
    v = rand(rng, 2, 33, 16)
    out = flash_attention(q, k, v, causal=True, block_q=block, block_k=block)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **TOL)


def test_flash_attention_seq_one():
    rng = np.random.default_rng(1)
    q = rand(rng, 2, 1, 16)
    k = rand(rng, 2, 1, 16)
    v = rand(rng, 2, 1, 16)
    out = flash_attention(q, k, v, causal=True)
    # Single position attends only to itself -> output == v.
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), **TOL)


@settings(max_examples=25, deadline=None)
@given(
    st.sampled_from([1, 2, 4]),
    st.sampled_from([1, 2, 3]),
    st.integers(1, 80),
    st.sampled_from([8, 16]),
    st.integers(0, 2**31 - 1),
    st.floats(0.05, 0.95),
)
def test_decode_attention_matches_ref(hkv, group, s, d, seed, keep_frac):
    hq = hkv * group
    rng = np.random.default_rng(seed)
    q = rand(rng, hq, d)
    k = rand(rng, hkv, s, d)
    v = rand(rng, hkv, s, d)
    mask = (rng.random(s) < keep_frac).astype("float32")
    if mask.sum() == 0:
        mask[rng.integers(0, s)] = 1.0  # at least one valid position
    mask = jnp.asarray(mask)
    out = decode_attention(q, k, v, mask)
    exp = ref.decode_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **TOL)


def test_decode_attention_single_valid_position_returns_that_value():
    rng = np.random.default_rng(2)
    q = rand(rng, 2, 8)
    k = rand(rng, 2, 20, 8)
    v = rand(rng, 2, 20, 8)
    mask = np.zeros(20, dtype="float32")
    mask[7] = 1.0
    out = decode_attention(q, k, v, jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, 7, :]), **TOL)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(1, 70),
    st.integers(1, 70),
    st.integers(1, 70),
    st.integers(0, 2**31 - 1),
)
def test_blocked_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, m, k)
    b = rand(rng, k, n)
    out = blocked_matmul(a, b)
    exp = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-3, atol=1e-3)


def test_blocked_matmul_identity():
    rng = np.random.default_rng(3)
    a = rand(rng, 24, 24)
    eye = jnp.eye(24, dtype=jnp.float32)
    out = blocked_matmul(a, eye)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a), **TOL)


def test_flash_attention_rejects_bad_gqa():
    rng = np.random.default_rng(4)
    q = rand(rng, 3, 8, 16)  # 3 q heads cannot share 2 kv heads
    k = rand(rng, 2, 8, 16)
    v = rand(rng, 2, 8, 16)
    with pytest.raises(AssertionError):
        flash_attention(q, k, v)
