"""L2 correctness: the transformer tiers.

Key invariants:
* Pallas-kernel path == reference-kernel path (same logits).
* Padded prefill + decode steps == contiguous full forward.
* The synthetic task generator obeys its own rule and the trained
  manifest quality gradient is monotone (checked in test_aot).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import train as T

CFG = M.TIERS["small"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=42)


def test_param_shapes_cover_all_names():
    shapes = M.param_shapes(CFG)
    names = M.param_names(CFG)
    assert set(shapes) == set(names)
    n = sum(int(np.prod(shapes[k])) for k in names)
    assert n == CFG.n_params


def test_pallas_and_ref_paths_agree(params):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=24).astype("int32"))
    ref_logits, _, _ = M.forward(params, CFG, toks, use_pallas=False)
    pl_logits, _, _ = M.forward(params, CFG, toks, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(ref_logits), np.asarray(pl_logits), rtol=5e-4, atol=5e-4
    )


@settings(max_examples=5, deadline=None)
@given(st.integers(2, 40), st.integers(0, 2**31 - 1))
def test_prefill_matches_full_forward(true_len, seed):
    params = M.init_params(CFG, seed=7)
    rng = np.random.default_rng(seed)
    seq = rng.integers(0, CFG.vocab, size=true_len).astype("int32")
    padded = np.zeros(CFG.prefill_len, dtype="int32")
    padded[:true_len] = seq
    logits, _, _ = M.prefill(params, CFG, jnp.asarray(padded),
                             jnp.asarray(true_len), use_pallas=True)
    full, _, _ = M.forward(params, CFG, jnp.asarray(seq), use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full[-1]), rtol=5e-4, atol=5e-4
    )


def test_multi_step_decode_matches_contiguous(params):
    """Three decode steps after a padded prefill must equal the
    contiguous forward pass over the growing sequence."""
    rng = np.random.default_rng(3)
    true_len = 17
    seq = rng.integers(0, CFG.vocab, size=true_len).astype("int32")
    padded = np.zeros(CFG.prefill_len, dtype="int32")
    padded[:true_len] = seq
    logits, kc, vc = M.prefill(params, CFG, jnp.asarray(padded),
                               jnp.asarray(true_len), use_pallas=True)
    mask = np.zeros(CFG.max_seq, dtype="float32")
    mask[:true_len] = 1.0
    cur = list(seq)
    for i in range(3):
        tok = int(np.argmax(np.asarray(logits)))
        slot = CFG.prefill_len + i
        mask[slot] = 1.0
        logits, kc, vc = M.decode_step(
            params, CFG, jnp.asarray(tok), jnp.asarray(slot),
            jnp.asarray(true_len + i), jnp.asarray(mask), kc, vc,
            use_pallas=True)
        cur.append(tok)
        full, _, _ = M.forward(params, CFG, jnp.asarray(np.array(cur, dtype="int32")),
                               use_pallas=False)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[-1]), rtol=1e-3, atol=1e-3,
            err_msg=f"decode step {i}")


def test_padding_does_not_leak(params):
    """Changing pad tokens (beyond true_len) must not change logits."""
    rng = np.random.default_rng(4)
    true_len = 12
    seq = rng.integers(0, CFG.vocab, size=true_len).astype("int32")
    a = np.zeros(CFG.prefill_len, dtype="int32")
    a[:true_len] = seq
    b = a.copy()
    b[true_len:] = rng.integers(0, CFG.vocab, size=CFG.prefill_len - true_len)
    la, _, _ = M.prefill(params, CFG, jnp.asarray(a), jnp.asarray(true_len))
    lb, _, _ = M.prefill(params, CFG, jnp.asarray(b), jnp.asarray(true_len))
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5, atol=1e-5)


def test_task_sequences_follow_rule():
    rng = np.random.default_rng(5)
    for m in range(1, T.MAX_DIFFICULTY + 1):
        seq = T.make_sequence(rng, m, 30)
        assert seq[0] == T.MARKER_BASE + m
        for i in range(1 + m, 30):
            assert seq[i] == np.sum(seq[i - m:i]) % T.DATA_VOCAB, (m, i)


def test_batch_weights_skip_seed_region():
    rng = np.random.default_rng(6)
    toks, tgts, wts = T.make_batch(rng, 8, 20)
    for b in range(8):
        m = int(toks[b, 0]) - T.MARKER_BASE
        assert (wts[b, :m] == 0).all()
        assert (wts[b, m:] == 1).all()
        # Targets are the shifted sequence.
        assert (tgts[b, :-1] == toks[b, 1:]).all()


def test_short_training_reduces_loss():
    cfg = M.TIERS["small"]
    rng = np.random.default_rng(7)
    toks, tgts, wts = T.make_batch(rng, 8, 24, difficulties=(1,))
    p0 = M.init_params(cfg, seed=1)
    loss0 = float(M.loss_fn(p0, cfg, jnp.asarray(toks), jnp.asarray(tgts),
                            jnp.asarray(wts)))
    p1 = T.train_tier(cfg, steps=40, batch=8, seq_len=24, seed=1,
                      difficulties=(1,), log_every=0)
    loss1 = float(M.loss_fn(p1, cfg, jnp.asarray(toks), jnp.asarray(tgts),
                            jnp.asarray(wts)))
    assert loss1 < loss0 * 0.8, (loss0, loss1)
