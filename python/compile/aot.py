"""AOT export: train the tiers, lower prefill/decode to HLO text, dump
parameter blobs + a manifest the Rust runtime consumes.

Interchange format is **HLO text**, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (per tier t in {small, medium, large}):
  artifacts/{t}_prefill.hlo.txt   fn(tokens i32[P], true_len i32[], *params)
                                  -> (logits f32[V], k f32[L,Hkv,S,hd], v ...)
  artifacts/{t}_decode.hlo.txt    fn(token i32[], pos i32[], rope_pos i32[],
                                     mask f32[S], k, v, *params)
                                  -> (logits, k', v')
  artifacts/{t}_params.bin        f32 little-endian, param_names() order
  artifacts/manifest.json         configs, param table, eval accuracies

Python runs ONCE here (`make artifacts`); the Rust binary is then
self-contained.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T

# Training recipe per tier: a difficulty *curriculum* (which task
# difficulties the tier sees) plus a step budget. The curriculum is the
# capability knob that gives the cascade a controlled, monotone quality
# gradient — small masters m=1 only, medium m<=2, large m<=4 — mirroring
# the paper's premise that request complexity maps to model capability.
TRAIN_RECIPE = {
    "small": {"steps": 260, "difficulties": (1,)},
    "medium": {"steps": 400, "difficulties": (1, 2)},
    "large": {"steps": 560, "difficulties": (1, 2, 3, 4)},
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_prefill(cfg: M.ModelConfig) -> str:
    names = M.param_names(cfg)

    def fn(tokens, true_len, *flat_params):
        params = dict(zip(names, flat_params))
        logits, k, v = M.prefill(params, cfg, tokens, true_len,
                                 use_pallas=True)
        return (logits, k, v)

    shapes = M.param_shapes(cfg)
    specs = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names]
    tok_spec = jax.ShapeDtypeStruct((cfg.prefill_len,), jnp.int32)
    len_spec = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(fn).lower(tok_spec, len_spec, *specs)
    return to_hlo_text(lowered)


def lower_decode(cfg: M.ModelConfig) -> str:
    names = M.param_names(cfg)

    def fn(token, pos, rope_pos, mask, k_cache, v_cache, *flat_params):
        params = dict(zip(names, flat_params))
        logits, k, v = M.decode_step(params, cfg, token, pos, rope_pos,
                                     mask, k_cache, v_cache, use_pallas=True)
        return (logits, k, v)

    shapes = M.param_shapes(cfg)
    specs = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names]
    cache_shape = (cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((cfg.max_seq,), jnp.float32),
        jax.ShapeDtypeStruct(cache_shape, jnp.float32),
        jax.ShapeDtypeStruct(cache_shape, jnp.float32),
        *specs)
    return to_hlo_text(lowered)


def export_params(params: M.Params, cfg: M.ModelConfig, path: str) -> int:
    """Write the f32-LE blob in param_names order; returns total floats."""
    total = 0
    with open(path, "wb") as f:
        for name in M.param_names(cfg):
            arr = np.asarray(params[name], dtype="<f4")
            f.write(arr.tobytes())
            total += arr.size
    return total


def build_tier(tier: str, out_dir: str, *, train_steps: int,
               difficulties=(1, 2, 3, 4), seed: int = 0) -> dict:
    cfg = M.TIERS[tier]
    t0 = time.time()
    print(f"[{tier}] training {train_steps} steps on difficulties "
          f"{difficulties} ({cfg.n_params:,} params)...", flush=True)
    params = T.train_tier(cfg, steps=train_steps, seed=seed,
                          difficulties=difficulties)
    acc = T.eval_accuracy(params, cfg)
    print(f"[{tier}] accuracy per difficulty: "
          f"{ {k: round(v, 3) for k, v in acc.items()} }", flush=True)

    n_floats = export_params(params, cfg, os.path.join(out_dir,
                                                       f"{tier}_params.bin"))
    for kind, lower in (("prefill", lower_prefill), ("decode", lower_decode)):
        text = lower(cfg)
        path = os.path.join(out_dir, f"{tier}_{kind}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"[{tier}] wrote {kind} HLO ({len(text):,} chars)", flush=True)

    shapes = M.param_shapes(cfg)
    return {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_q_heads": cfg.n_q_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_ff": cfg.d_ff,
            "head_dim": cfg.head_dim, "max_seq": cfg.max_seq,
            "prefill_len": cfg.prefill_len, "n_params": cfg.n_params,
        },
        "params": [{"name": n, "shape": list(shapes[n])}
                   for n in M.param_names(cfg)],
        "n_floats": n_floats,
        "train_steps": train_steps,
        "train_difficulties": list(difficulties),
        "eval_accuracy": {str(k): v for k, v in acc.items()},
        "build_seconds": round(time.time() - t0, 1),
        "files": {
            "prefill": f"{tier}_prefill.hlo.txt",
            "decode": f"{tier}_decode.hlo.txt",
            "params": f"{tier}_params.bin",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tiers", default="small,medium,large")
    ap.add_argument("--train-steps", type=int, default=None,
                    help="override per-tier training budget (0 = untrained)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"task": {
        "data_vocab": T.DATA_VOCAB, "marker_base": T.MARKER_BASE,
        "max_difficulty": T.MAX_DIFFICULTY,
    }, "tiers": {}}
    for tier in args.tiers.split(","):
        recipe = TRAIN_RECIPE[tier]
        steps = (args.train_steps if args.train_steps is not None
                 else recipe["steps"])
        manifest["tiers"][tier] = build_tier(
            tier, args.out_dir, train_steps=steps,
            difficulties=tuple(recipe["difficulties"]), seed=args.seed)
    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
