"""L2: decoder-only transformer model tiers (JAX, build-time only).

A small Llama-style architecture (RMSNorm, RoPE, GQA attention, SwiGLU
MLP) instantiated at three sizes — the *tiers* of the end-to-end cascade
that the Rust coordinator actually serves on CPU PJRT. The attention and
MLP hot-spots call the L1 Pallas kernels (``use_pallas=True``, the export
path); the training path uses the pure-jnp references so autodiff works.

Export surface (consumed by ``aot.py``):

* ``prefill(params, tokens, true_len)`` — process a padded prompt, return
  the next-token logits at ``true_len - 1`` plus the KV cache padded to
  ``max_seq``.
* ``decode_step(params, token, pos, mask, k_cache, v_cache)`` — one
  autoregressive step; functional KV-cache update (PJRT execution is
  stateless, the Rust runtime threads the cache through calls).

Shapes are static: prompts are padded to ``cfg.prefill_len`` and the KV
cache to ``cfg.max_seq``; the validity ``mask`` (computed by the Rust
coordinator) makes decode attention skip the padding hole between
``true_len`` and ``prefill_len``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention, flash_attention
from .kernels.matmul import blocked_matmul
from .kernels import ref

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture constants for one cascade tier."""

    name: str
    vocab: int = 64
    d_model: int = 64
    n_layers: int = 2
    n_q_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 128
    head_dim: int = 16
    max_seq: int = 160
    prefill_len: int = 64
    rope_theta: float = 10000.0

    @property
    def n_params(self) -> int:
        d, v, f, L = self.d_model, self.vocab, self.d_ff, self.n_layers
        hq, hkv, hd = self.n_q_heads, self.n_kv_heads, self.head_dim
        per_layer = (d * hq * hd + 2 * d * hkv * hd + hq * hd * d
                     + 3 * d * f + 2 * d)
        return v * d + L * per_layer + d + d * v


# The three cascade tiers served end-to-end. Sizes are deliberately tiny
# (CPU interpret-mode Pallas) but architecturally faithful; capability
# grows with depth/width so the cascade quality gradient is real.
TIERS: Dict[str, ModelConfig] = {
    "small": ModelConfig(name="small", d_model=64, n_layers=2, n_q_heads=4,
                         n_kv_heads=2, d_ff=128),
    "medium": ModelConfig(name="medium", d_model=128, n_layers=3,
                          n_q_heads=8, n_kv_heads=4, d_ff=256),
    "large": ModelConfig(name="large", d_model=192, n_layers=4,
                         n_q_heads=12, n_kv_heads=4, d_ff=384),
}


def param_names(cfg: ModelConfig) -> List[str]:
    """Deterministic parameter order shared with the Rust runtime."""
    names = ["embed"]
    for i in range(cfg.n_layers):
        names += [f"l{i}.attn_norm", f"l{i}.wq", f"l{i}.wk", f"l{i}.wv",
                  f"l{i}.wo", f"l{i}.mlp_norm", f"l{i}.w_gate",
                  f"l{i}.w_up", f"l{i}.w_down"]
    names += ["out_norm", "lm_head"]
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_q_heads, cfg.n_kv_heads
    shapes: Dict[str, Tuple[int, ...]] = {"embed": (cfg.vocab, d)}
    for i in range(cfg.n_layers):
        shapes[f"l{i}.attn_norm"] = (d,)
        shapes[f"l{i}.wq"] = (d, hq * hd)
        shapes[f"l{i}.wk"] = (d, hkv * hd)
        shapes[f"l{i}.wv"] = (d, hkv * hd)
        shapes[f"l{i}.wo"] = (hq * hd, d)
        shapes[f"l{i}.mlp_norm"] = (d,)
        shapes[f"l{i}.w_gate"] = (d, cfg.d_ff)
        shapes[f"l{i}.w_up"] = (d, cfg.d_ff)
        shapes[f"l{i}.w_down"] = (cfg.d_ff, d)
    shapes["out_norm"] = (d,)
    shapes["lm_head"] = (d, cfg.vocab)
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Scaled-normal initialization (1/sqrt(fan_in); norms at 1)."""
    key = jax.random.PRNGKey(seed)
    shapes = param_shapes(cfg)
    params: Params = {}
    for name in param_names(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, dtype=jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = (jax.random.normal(sub, shape, dtype=jnp.float32)
                            / jnp.sqrt(jnp.asarray(fan_in, jnp.float32)))
    return params


def rms_norm(x, weight, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * weight


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, hd); positions: (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (S, half)
    cos = jnp.cos(angles)[..., None, :]  # (S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k, v, *, causal: bool, use_pallas: bool):
    if use_pallas:
        return flash_attention(q, k, v, causal=causal)
    return ref.attention_ref(q, k, v, causal=causal)


def _matmul(a, b, *, use_pallas: bool):
    if use_pallas:
        return blocked_matmul(a, b)
    return ref.matmul_ref(a, b)


def forward(params: Params, cfg: ModelConfig, tokens,
            *, use_pallas: bool = False):
    """Full-sequence forward pass. tokens: (S,) int32 -> logits (S, V).

    Also returns the post-RoPE per-layer K/V for cache construction:
    lists of (Hkv, S, hd).
    """
    s = tokens.shape[0]
    positions = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"][tokens]  # (S, d)
    ks, vs = [], []
    for i in range(cfg.n_layers):
        h = rms_norm(x, params[f"l{i}.attn_norm"])
        q = _matmul(h, params[f"l{i}.wq"], use_pallas=use_pallas)
        k = _matmul(h, params[f"l{i}.wk"], use_pallas=use_pallas)
        v = _matmul(h, params[f"l{i}.wv"], use_pallas=use_pallas)
        q = q.reshape(s, cfg.n_q_heads, cfg.head_dim)
        k = k.reshape(s, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(s, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        # kernels take (H, S, hd)
        qh = jnp.transpose(q, (1, 0, 2))
        kh = jnp.transpose(k, (1, 0, 2))
        vh = jnp.transpose(v, (1, 0, 2))
        ks.append(kh)
        vs.append(vh)
        attn = _attention(qh, kh, vh, causal=True, use_pallas=use_pallas)
        attn = jnp.transpose(attn, (1, 0, 2)).reshape(s, -1)
        x = x + _matmul(attn, params[f"l{i}.wo"], use_pallas=use_pallas)
        h = rms_norm(x, params[f"l{i}.mlp_norm"])
        gate = _matmul(h, params[f"l{i}.w_gate"], use_pallas=use_pallas)
        up = _matmul(h, params[f"l{i}.w_up"], use_pallas=use_pallas)
        x = x + _matmul(jax.nn.silu(gate) * up, params[f"l{i}.w_down"],
                        use_pallas=use_pallas)
    x = rms_norm(x, params["out_norm"])
    logits = _matmul(x, params["lm_head"], use_pallas=use_pallas)
    return logits, ks, vs


def prefill(params: Params, cfg: ModelConfig, tokens, true_len,
            *, use_pallas: bool = True):
    """Prefill a padded prompt.

    Args:
      tokens: (prefill_len,) int32; positions >= true_len are padding.
      true_len: scalar int32, actual prompt length (>= 1).

    Returns:
      logits: (vocab,) next-token logits at position true_len - 1.
      k_cache, v_cache: (L, Hkv, max_seq, hd) with [0:prefill_len) filled.
        (Causality makes pad positions inert for positions < true_len; the
        decode mask hides them afterwards.)
    """
    logits_all, ks, vs = forward(params, cfg, tokens, use_pallas=use_pallas)
    idx = jnp.clip(true_len - 1, 0, cfg.prefill_len - 1)
    logits = jax.lax.dynamic_index_in_dim(logits_all, idx, axis=0,
                                          keepdims=False)
    pad = cfg.max_seq - cfg.prefill_len
    k_cache = jnp.stack([jnp.pad(k, ((0, 0), (0, pad), (0, 0))) for k in ks])
    v_cache = jnp.stack([jnp.pad(v, ((0, 0), (0, pad), (0, 0))) for v in vs])
    return logits, k_cache, v_cache


def decode_step(params: Params, cfg: ModelConfig, token, pos, rope_pos,
                mask, k_cache, v_cache, *, use_pallas: bool = True):
    """One autoregressive decode step with a functional KV-cache update.

    Args:
      token: scalar int32, the last generated token.
      pos: scalar int32, the cache *slot* to write (prefill_len + i for
        the i-th decoded token).
      rope_pos: scalar int32, the *logical* position for RoPE
        (true_len + i). Separating slot from logical position makes the
        padded-prefill layout exactly equivalent to a contiguous
        sequence: attention is permutation-invariant over the valid set,
        and RoPE sees the gap-free positions.
      mask: (max_seq,) f32 validity mask, computed by the coordinator:
        1 for slots < true_len and for decoded slots <= pos (including
        pos itself), 0 for the padding hole and the future.
      k_cache, v_cache: (L, Hkv, max_seq, hd).

    Returns:
      logits: (vocab,), and the updated caches.
    """
    x = params["embed"][token]  # (d,)
    pos_arr = jnp.reshape(rope_pos, (1,)).astype(jnp.int32)
    new_ks, new_vs = [], []
    for i in range(cfg.n_layers):
        h = rms_norm(x, params[f"l{i}.attn_norm"])
        hq, hkv, hd = cfg.n_q_heads, cfg.n_kv_heads, cfg.head_dim
        q = (h @ params[f"l{i}.wq"]).reshape(1, hq, hd)
        k = (h @ params[f"l{i}.wk"]).reshape(1, hkv, hd)
        v = (h @ params[f"l{i}.wv"]).reshape(1, hkv, hd)
        q = rope(q, pos_arr, cfg.rope_theta)[0]  # (Hq, hd)
        k = rope(k, pos_arr, cfg.rope_theta)[0]  # (Hkv, hd)
        v = v[0]
        # Write this token's K/V into the cache at `pos`.
        kc = jax.lax.dynamic_update_slice(
            k_cache[i], k.reshape(hkv, 1, hd), (0, pos, 0))
        vc = jax.lax.dynamic_update_slice(
            v_cache[i], v.reshape(hkv, 1, hd), (0, pos, 0))
        new_ks.append(kc)
        new_vs.append(vc)
        if use_pallas:
            attn = decode_attention(q, kc, vc, mask)
        else:
            attn = ref.decode_attention_ref(q, kc, vc, mask)
        x = x + attn.reshape(-1) @ params[f"l{i}.wo"]
        h = rms_norm(x, params[f"l{i}.mlp_norm"])
        gate = h @ params[f"l{i}.w_gate"]
        up = h @ params[f"l{i}.w_up"]
        x = x + (jax.nn.silu(gate) * up) @ params[f"l{i}.w_down"]
    x = rms_norm(x, params["out_norm"])
    logits = x @ params["lm_head"]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def loss_fn(params: Params, cfg: ModelConfig, tokens, targets, weights):
    """Batched next-token cross-entropy (training path, ref kernels).

    tokens/targets/weights: (B, S); weights zero out positions that carry
    no supervision (e.g. the difficulty-marker prefix).
    """

    def one(seq):
        logits, _, _ = forward(params, cfg, seq, use_pallas=False)
        return logits

    logits = jax.vmap(one)(tokens)  # (B, S, V)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return (nll * weights).sum() / jnp.maximum(weights.sum(), 1.0)
