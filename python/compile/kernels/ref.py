"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package must match its reference here to ~1e-5 (f32) across the shape
sweep in ``python/tests/test_kernels.py``. They are also used on the
training path (build-time only), where autodiff through ``pallas_call``
is not required.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def attention_ref(q, k, v, *, causal: bool = True):
    """Multi-head attention with grouped-query (GQA) head mapping.

    Args:
      q: (Hq, Sq, D) queries.
      k: (Hkv, Sk, D) keys; Hq must be a multiple of Hkv.
      v: (Hkv, Sk, D) values.
      causal: apply a causal mask (query i attends to keys <= i; assumes
        Sq == Sk when True).

    Returns:
      (Hq, Sq, D) attention output, f32.
    """
    hq, sq, d = q.shape
    hkv, sk, _ = k.shape
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}"
    group = hq // hkv
    k = jnp.repeat(k, group, axis=0)  # (Hq, Sk, D)
    v = jnp.repeat(v, group, axis=0)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        logits = jnp.where(mask[None, :, :], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def decode_attention_ref(q, k, v, mask):
    """Single-token decode attention with an explicit validity mask.

    Args:
      q: (Hq, D) query for the current position.
      k: (Hkv, S, D) key cache (padded to max sequence length).
      v: (Hkv, S, D) value cache.
      mask: (S,) f32 validity mask; positions with mask <= 0 are excluded.

    Returns:
      (Hq, D) attention output.
    """
    hq, d = q.shape
    hkv, s, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    logits = jnp.einsum("hd,hsd->hs", q, k) * scale
    logits = jnp.where(mask[None, :] > 0, logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hs,hsd->hd", probs, v)


def matmul_ref(a, b):
    """Reference for the blocked matmul kernel: plain (M,K)@(K,N)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
