"""Flash-attention Pallas kernels (L1, the serving hot-spot).

Two kernels:

* :func:`flash_attention` — prefill attention. Online-softmax schedule:
  the grid tiles (query-head, q-block); each program streams K/V through
  VMEM in ``block_k`` chunks, carrying the running max / denominator /
  accumulator. This is the TPU re-think of the paper's GPU hot path: the
  HBM<->VMEM schedule a CUDA flash kernel expresses with threadblocks and
  shared memory is expressed here with the BlockSpec grid + an inner
  ``fori_loop`` (see DESIGN.md section "Hardware adaptation").

* :func:`decode_attention` — single-token decode attention over a padded
  KV cache with an explicit validity mask (the Rust coordinator computes
  the mask: causal bound + prompt-padding holes).

Both are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the execution path and the
numerics oracle; real-TPU performance is *estimated* analytically in
DESIGN.md section 9.

GQA is supported: ``Hq`` query heads share ``Hkv`` KV heads via the
BlockSpec index map (query head h reads KV head ``h // (Hq // Hkv)``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sk: int,
                  causal: bool, block_q: int):
    """One (head, q-block) program of the online-softmax schedule."""
    # q_ref: (1, block_q, D); k_ref/v_ref: (1, Sk_padded, D); o_ref like q_ref.
    qi = pl.program_id(1)
    q = q_ref[0, :, :]  # (bq, D)
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    q = q * scale
    sk_padded = k_ref.shape[1]
    num_kb = sk_padded // block_k

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(j * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(j * block_k, block_k), slice(None)))
        # (bq, bk) tile on the MXU.
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        valid = k_pos < sk  # mask zero-padded keys
        if causal:
            valid = jnp.logical_and(valid, k_pos <= q_pos)
        s = jnp.where(valid, s, NEG_INF)
        # Online softmax update (VPU work between the two MXU matmuls).
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32)
        return m_cur, l_cur, acc

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    # Rows that saw no valid key (fully masked, only possible for padded
    # q rows) would divide by zero; guard them.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, :, :] = acc / l[:, None]


def _pad_to(x, axis: int, multiple: int):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 16,
                    block_k: int = 16, interpret: bool = True):
    """Flash attention over (Hq, Sq, D) queries and (Hkv, Sk, D) KV.

    Arbitrary Sq/Sk are supported by zero-padding to the block size; the
    kernel masks out-of-range keys and the wrapper slices padded query
    rows off the output.
    """
    hq, sq, d = q.shape
    hkv, sk, _ = k.shape
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}"
    group = hq // hkv

    qp = _pad_to(q, 1, block_q)
    kp = _pad_to(k, 1, block_k)
    vp = _pad_to(v, 1, block_k)
    sq_p, sk_p = qp.shape[1], kp.shape[1]

    grid = (hq, sq_p // block_q)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_k=block_k, sk=sk,
                          causal=causal, block_q=block_q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, sk_p, d), lambda h, i, g=group: (h // g, 0, 0)),
            pl.BlockSpec((1, sk_p, d), lambda h, i, g=group: (h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, sq_p, d), jnp.float32),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block_k: int):
    """One query-head program: masked online softmax over the KV cache."""
    # q_ref: (1, D); k_ref/v_ref: (1, S, D); mask_ref: (S,); o_ref: (1, D).
    q = q_ref[0, :]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    q = q * scale
    s_total = k_ref.shape[1]
    num_kb = s_total // block_k

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(j * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(j * block_k, block_k), slice(None)))
        mask = pl.load(mask_ref, (pl.dslice(j * block_k, block_k),))
        s = jnp.dot(k_blk, q, preferred_element_type=jnp.float32)  # (bk,)
        s = jnp.where(mask > 0, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max())
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_cur = l_prev * alpha + p.sum()
        acc = acc * alpha + jnp.dot(p, v_blk, preferred_element_type=jnp.float32)
        return m_cur, l_cur, acc

    m0 = jnp.asarray(NEG_INF, dtype=jnp.float32)
    l0 = jnp.asarray(0.0, dtype=jnp.float32)
    acc0 = jnp.zeros((d,), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, :] = acc / l


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, mask, *, block_k: int = 16,
                     interpret: bool = True):
    """Single-token decode attention.

    Args:
      q: (Hq, D) query at the current position.
      k, v: (Hkv, S, D) KV cache padded to the max sequence length.
      mask: (S,) f32; positions with mask <= 0 are excluded (the caller
        encodes both the causal bound and prompt-padding holes here).

    Returns:
      (Hq, D) attention output.
    """
    hq, d = q.shape
    hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv

    kp = _pad_to(k, 1, block_k)
    vp = _pad_to(v, 1, block_k)
    maskp = _pad_to(mask, 0, block_k)  # zero padding == invalid, as required
    s_p = kp.shape[1]

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k),
        grid=(hq,),
        in_specs=[
            pl.BlockSpec((1, d), lambda h: (h, 0)),
            pl.BlockSpec((1, s_p, d), lambda h, g=group: (h // g, 0, 0)),
            pl.BlockSpec((1, s_p, d), lambda h, g=group: (h // g, 0, 0)),
            pl.BlockSpec((s_p,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, d), jnp.float32),
        interpret=interpret,
    )(q, kp, vp, maskp)
    return out
