"""Blocked matmul Pallas kernel used by the transformer MLP projections.

Grid tiles (M, N); each program streams K through VMEM in ``block_k``
chunks and accumulates in f32 — the classic MXU-oriented schedule.
Arbitrary shapes are handled by zero-padding (zeros contribute nothing
to the accumulation, so no masking is needed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, block_k: int):
    # a_ref: (bm, K); b_ref: (K, bn); o_ref: (bm, bn).
    k_total = a_ref.shape[1]
    num_kb = k_total // block_k
    bm, bn = o_ref.shape

    def body(j, acc):
        a_blk = pl.load(a_ref, (slice(None), pl.dslice(j * block_k, block_k)))
        b_blk = pl.load(b_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        return acc + jnp.dot(a_blk, b_blk, preferred_element_type=jnp.float32)

    acc0 = jnp.zeros((bm, bn), dtype=jnp.float32)
    o_ref[:, :] = jax.lax.fori_loop(0, num_kb, body, acc0)


def _pad_to(x, axis: int, multiple: int):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def blocked_matmul(a, b, *, block_m: int = 16, block_n: int = 16,
                   block_k: int = 16, interpret: bool = True):
    """(M, K) @ (K, N) -> (M, N) via the blocked Pallas kernel."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    ap = _pad_to(_pad_to(a, 0, block_m), 1, block_k)
    bp = _pad_to(_pad_to(b, 0, block_k), 1, block_n)
    mp, kp = ap.shape
    _, np_ = bp.shape

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, block_k=block_k),
        grid=(mp // block_m, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_m, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
