"""Build-time trainer for the cascade tiers on the graded synthetic task.

The task gives the end-to-end cascade a *real* quality gradient: each
sequence starts with a difficulty marker m in {1..4}; after m seed tokens
every next token is determined by ``t[i] = (t[i-1] + ... + t[i-m]) % V``.
Harder (larger-m) sequences need more capacity/attention span, so the
small tier masters m=1..2 while the large tier handles m=1..4 — mirroring
the paper's premise that simple requests can be answered by small models.

Runs once at `make artifacts`; Adam is hand-rolled (no optax in the
image). Training uses the pure-jnp reference kernels (autodiff); the
exported inference graphs use the Pallas kernels — equality of the two
paths is asserted by ``python/tests/test_model.py``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M

DATA_VOCAB = 16          # tokens 0..15 carry data (mod-16 arithmetic)
MARKER_BASE = 59         # marker for difficulty m is MARKER_BASE + m (60..63)
MAX_DIFFICULTY = 4


def make_sequence(rng: np.random.Generator, m: int, length: int) -> np.ndarray:
    """One task sequence: [marker(m), seed_1..seed_m, determined...]."""
    seq = np.zeros(length, dtype=np.int32)
    seq[0] = MARKER_BASE + m
    seq[1:1 + m] = rng.integers(0, DATA_VOCAB, size=m)
    for i in range(1 + m, length):
        seq[i] = int(np.sum(seq[i - m:i]) % DATA_VOCAB)
    return seq


def make_batch(rng: np.random.Generator, batch: int, length: int,
               difficulties=(1, 2, 3, 4)) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(tokens, targets, weights) for LM training; supervision starts
    after the seed region (position >= m + 1)."""
    toks = np.zeros((batch, length), dtype=np.int32)
    tgts = np.zeros((batch, length), dtype=np.int32)
    wts = np.zeros((batch, length), dtype=np.float32)
    for b in range(batch):
        m = int(rng.choice(difficulties))
        seq = make_sequence(rng, m, length + 1)
        toks[b] = seq[:-1]
        tgts[b] = seq[1:]
        wts[b, m:] = 1.0  # predicting t[i+1] is well-defined for i >= m
    return toks, tgts, wts


def adam_init(params: M.Params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), dtype=jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        new_m[k] = b1 * state["m"][k] + (1 - b1) * grads[k]
        new_v[k] = b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k])
        mhat = new_m[k] / (1 - b1 ** t.astype(jnp.float32))
        vhat = new_v[k] / (1 - b2 ** t.astype(jnp.float32))
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, {"m": new_m, "v": new_v, "t": t}


def train_tier(cfg: M.ModelConfig, *, steps: int, batch: int = 16,
               seq_len: int = 32, seed: int = 0, lr: float = 2e-3,
               difficulties=(1, 2, 3, 4), log_every: int = 50) -> M.Params:
    """Train one tier on a restricted difficulty mixture.

    The per-tier `difficulties` curriculum is the capability knob: a tier
    only masters the difficulties it trains on, giving the cascade a
    controlled, monotone quality gradient (small: m=1; medium: m<=2;
    large: m<=4).
    """
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, seed=seed)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, toks, tgts, wts):
        loss, grads = jax.value_and_grad(M.loss_fn)(params, cfg, toks, tgts, wts)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    for i in range(steps):
        toks, tgts, wts = make_batch(rng, batch, seq_len,
                                     difficulties=difficulties)
        params, opt, loss = step(params, opt, jnp.asarray(toks),
                                 jnp.asarray(tgts), jnp.asarray(wts))
        if log_every and (i + 1) % log_every == 0:
            print(f"  [{cfg.name}] step {i + 1}/{steps} loss {float(loss):.4f}",
                  flush=True)
    return params


def eval_accuracy(params: M.Params, cfg: M.ModelConfig, *, n_seqs: int = 32,
                  seq_len: int = 32, seed: int = 123) -> Dict[int, float]:
    """Teacher-forced next-token accuracy per difficulty level."""
    rng = np.random.default_rng(seed)

    @jax.jit
    def logits_of(seq):
        out, _, _ = M.forward(params, cfg, seq, use_pallas=False)
        return out

    acc: Dict[int, float] = {}
    for m in range(1, MAX_DIFFICULTY + 1):
        correct = total = 0
        for _ in range(n_seqs):
            seq = make_sequence(rng, m, seq_len + 1)
            logits = np.asarray(logits_of(jnp.asarray(seq[:-1])))
            pred = logits.argmax(axis=-1)
            sl = slice(m, seq_len)  # supervised region
            correct += int((pred[sl] == seq[1:][sl]).sum())
            total += seq_len - m
        acc[m] = correct / max(total, 1)
    return acc
