//! Serving hot-path benchmarks: router decision cost, batcher
//! admission, judger scoring, and the end-to-end coordinator overhead
//! per request with an instant backend (i.e. everything EXCEPT model
//! execution — the target is <100µs p95 per request; EXPERIMENTS.md
//! §Perf).

use anyhow::Result;
use cascadia::coordinator::batcher::Batcher;
use cascadia::coordinator::server::{
    CascadeServer, ResponseJudger, ServerConfig, TierBackend,
};
use cascadia::engine::{EngineConfig, EngineCore};
use cascadia::judge::Judger;
use cascadia::models::deepseek_cascade;
use cascadia::router::{route, route_with, MarginPolicy, Thresholds};
use cascadia::util::bench::Bencher;
use cascadia::workload::{generate, paper_trace};

struct InstantBackend;

impl TierBackend for InstantBackend {
    fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        Ok(vec![1; max_new.min(4)])
    }
}

struct ConstJudger(f64);

impl ResponseJudger for ConstJudger {
    fn score(&self, _p: &[i32], _o: &[i32]) -> f64 {
        self.0
    }
}

fn main() {
    let mut b = Bencher::default();
    let cascade = deepseek_cascade();
    let judger = Judger::new(1);
    let reqs = generate(&paper_trace(2, 10.0), 2000, 5);
    let span = reqs.last().unwrap().arrival;
    let th = Thresholds(vec![70.0, 50.0]);

    b.bench("judger score (1 request x 1 tier)", || {
        judger.score(&cascade[0], &reqs[0], 0)
    });

    b.bench("route 2000 requests through 3 tiers", || {
        route(&cascade, &judger, &reqs, &th, span).quality
    });

    // Policy dispatch overhead: the same trace through the trait object
    // path with a skip-capable policy.
    let margin = MarginPolicy::new(vec![70.0, 50.0], 15.0).unwrap();
    b.bench("route 2000 requests (margin policy, dyn dispatch)", || {
        route_with(&cascade, &judger, &reqs, &margin, span).unwrap().quality
    });

    b.bench("batcher push+admit+complete x1000", || {
        let mut batcher: Batcher<u32> = Batcher::new(16);
        let mut done = 0usize;
        for i in 0..1000u32 {
            batcher.push(i, 0.0);
            let n = batcher.admit(0.0).len();
            if n > 0 {
                batcher.complete(n);
                done += n;
            }
        }
        done
    });

    // Continuous-engine overhead: pure scheduling/page accounting per
    // iteration with an instant whole-request backend.
    b.bench("engine submit+step 256 requests (instant backend)", || {
        let mut engine: EngineCore<u32> = EngineCore::new(
            Box::new(InstantBackend),
            EngineConfig {
                pool_pages: 4096,
                page_tokens: 16,
                max_running: 32,
                prefill_chunk: usize::MAX,
                share_prefixes: false,
                preemption: cascadia::engine::PreemptionConfig::default(),
            },
        );
        for i in 0..256u32 {
            engine.submit(i, vec![1, 2, 3], 4);
        }
        let mut done = 0usize;
        while !engine.is_idle() {
            done += engine.step().unwrap().completed.len();
        }
        done
    });

    // Whole-coordinator overhead with an instant backend: latency here
    // is pure queueing/dispatch/judging machinery.
    let server = CascadeServer::new(
        ServerConfig::with_thresholds(vec![2, 1, 1], vec![8, 8, 8], vec![50.0, 50.0], 4)
            .unwrap(),
    )
    .unwrap();
    let trace: Vec<(f64, Vec<i32>)> = (0..200).map(|_| (0.0, vec![60, 1, 2])).collect();
    let meas = b.bench("serve 200 requests (instant backend)", || {
        let factory =
            |_t: usize| -> Result<Box<dyn TierBackend>> { Ok(Box::new(InstantBackend)) };
        server
            .serve(&trace, &factory, &ConstJudger(90.0))
            .unwrap()
            .completions
            .len()
    });
    println!(
        "  -> coordinator overhead ≈ {:.1}µs/request",
        meas.mean.as_secs_f64() * 1e6 / 200.0
    );

    b.write_csv("results/bench_serving.csv").unwrap();
    println!("wrote results/bench_serving.csv");
}
