//! MILP substrate benchmarks: simplex LP solves and branch-and-bound
//! on the §3.2 assignment family at realistic sizes.

use cascadia::milp::simplex::Sense;
use cascadia::milp::{MilpProblem, Rel};
use cascadia::util::bench::Bencher;
use cascadia::util::rng::Rng;

/// Build a §3.2-shaped instance: `tiers` tiers x `n_gpus` allocations,
/// synthetic latency tables.
fn assignment_instance(tiers: usize, n_gpus: usize, seed: u64) -> MilpProblem {
    let mut rng = Rng::new(seed);
    let n_bin = tiers * n_gpus;
    let l_var = n_bin;
    let mut obj = vec![0.0; n_bin + 1];
    obj[l_var] = 1.0;
    let mut p = MilpProblem::new(n_bin + 1, obj, Sense::Minimize);
    // One allocation per tier.
    for t in 0..tiers {
        let mut row = vec![0.0; n_bin + 1];
        for f in 0..n_gpus {
            row[t * n_gpus + f] = 1.0;
        }
        p.constrain(row, Rel::Eq, 1.0);
    }
    // Budget.
    let mut row = vec![0.0; n_bin + 1];
    for t in 0..tiers {
        for f in 0..n_gpus {
            row[t * n_gpus + f] = (f + 1) as f64;
        }
    }
    p.constrain(row, Rel::Eq, n_gpus as f64);
    // L >= selected latency (decreasing in f with noise).
    for t in 0..tiers {
        let mut row = vec![0.0; n_bin + 1];
        for f in 0..n_gpus {
            let lat = 100.0 / (f + 1) as f64 * rng.range_f64(0.8, 1.2)
                * (t + 1) as f64;
            row[t * n_gpus + f] = lat;
        }
        row[l_var] = -1.0;
        p.constrain(row, Rel::Le, 0.0);
    }
    for v in 0..n_bin {
        p.set_binary(v);
    }
    p
}

fn main() {
    let mut b = Bencher::default();
    for &(tiers, gpus) in &[(3usize, 32usize), (3, 64), (3, 128), (5, 32)] {
        let p = assignment_instance(tiers, gpus, 42);
        let label = format!("B&B assignment {tiers} tiers x {gpus} GPUs");
        let meas = b.bench(&label, || p.solve().unwrap().nodes);
        let nodes = p.solve().unwrap().nodes;
        println!("  -> {nodes} nodes, {:?}/solve", meas.mean);
    }
    b.write_csv("results/bench_milp.csv").unwrap();
    println!("wrote results/bench_milp.csv");
}
