//! Simulator benchmarks: DES event throughput and analytic-estimate
//! cost — the quantities that bound scheduler runtime (EXPERIMENTS.md
//! §Perf tracks these before/after optimization).

use cascadia::cluster::ClusterSpec;
use cascadia::models::llama_cascade;
use cascadia::perf::{ReplicaModel, Workload};
use cascadia::sim::analytic::estimate_p95;
use cascadia::sim::des::{simulate, SimRequest};
use cascadia::util::bench::Bencher;
use cascadia::util::rng::Rng;

fn poisson_trace(rate: f64, n: usize, seed: u64) -> Vec<SimRequest> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exp(rate);
            SimRequest::new(t, 512, 128)
        })
        .collect()
}

fn main() {
    let mut b = Bencher::default();
    let m = &llama_cascade()[0];
    let cluster = ClusterSpec::paper_testbed();
    let pool: Vec<ReplicaModel> =
        (0..4).map(|_| ReplicaModel::new(m, &cluster, 2, 1, 640.0)).collect();
    let w = Workload { rate: 40.0, avg_input: 512.0, avg_output: 128.0 };

    b.bench("ReplicaModel::new", || ReplicaModel::new(m, &cluster, 2, 1, 640.0));
    b.bench("analytic estimate_p95 (4 replicas)", || estimate_p95(&pool, &w));

    for &n in &[1_000usize, 10_000] {
        let trace = poisson_trace(40.0, n, 7);
        let label = format!("DES {n} requests (4 replicas)");
        let meas = b.bench(&label, || simulate(&pool, &trace).latencies.len());
        let req_per_sec = n as f64 / meas.mean.as_secs_f64();
        println!("  -> {req_per_sec:.0} simulated requests/s");
    }

    b.write_csv("results/bench_simulator.csv").unwrap();
    println!("wrote results/bench_simulator.csv");
}
