//! Scheduler benchmarks (Figure 12 companion): inner MILP/DP solve,
//! l_i(f) table construction, strategy enumeration, and the full
//! bi-level sweep at 32/64/128 GPUs.

use cascadia::cluster::ClusterSpec;
use cascadia::judge::Judger;
use cascadia::models::deepseek_cascade;
use cascadia::parallel::enumerate_strategies;
use cascadia::perf::Workload;
use cascadia::sched::inner::{InnerOptions, InnerSolver};
use cascadia::sched::outer::{optimize, OuterOptions};
use cascadia::util::bench::Bencher;
use cascadia::workload::{generate, paper_trace};

fn main() {
    let mut b = Bencher::default();
    let cascade = deepseek_cascade();
    let cluster = ClusterSpec::paper_testbed();
    let w = Workload { rate: 20.0, avg_input: 512.0, avg_output: 256.0 };
    let tier_w = vec![w, w.scaled(0.5), w.scaled(0.15)];

    b.bench("enumerate_strategies(7B, 32 GPUs)", || {
        enumerate_strategies(&cascade[0], &cluster, 32).len()
    });

    // Cold tables (no memo hits).
    b.bench("l_i(f) tables, 3 tiers x 32 GPUs (cold)", || {
        let solver =
            InnerSolver::new(cascade.clone(), cluster.clone(), InnerOptions::default());
        solver.tables(&tier_w, 32)
    });

    for &(label, use_milp) in &[("MILP", true), ("DP", false)] {
        let solver = InnerSolver::new(
            cascade.clone(),
            cluster.clone(),
            InnerOptions { use_milp, ..Default::default() },
        );
        solver.tables(&tier_w, 32); // warm the memo
        b.bench(&format!("inner solve 32 GPUs ({label}, warm tables)"), || {
            solver.solve(&tier_w, 32).unwrap()
        });
    }

    // Full sweep at increasing cluster sizes (Figure 12's subject).
    for &gpus in &[32usize, 64, 128] {
        let judger = Judger::new(1);
        let reqs = generate(&paper_trace(1, 2.0 * gpus as f64), 600, 3);
        let c = ClusterSpec::with_gpus(gpus);
        let opts = OuterOptions::default();
        let mut quick = Bencher::quick();
        quick.bench(&format!("full bi-level sweep, {gpus} GPUs"), || {
            optimize(&cascade, &c, &judger, &reqs, gpus, &opts).unwrap().pareto.len()
        });
        for m in quick.results() {
            b.push_external(m.clone());
        }
    }

    b.write_csv("results/bench_scheduler.csv").unwrap();
    println!("wrote results/bench_scheduler.csv");
}
