//! Online-adaptation micro-benchmarks: monitor observe cost, plan-cache
//! lookup, swap-mailbox submission, and the end-to-end overhead of a
//! hot-swap on a live serve (vs the same serve without one).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;
use cascadia::adapt::{CacheConfig, PlanCache};
use cascadia::coordinator::monitor::{Monitor, MonitorConfig};
use cascadia::coordinator::server::{
    AdmissionObserver, CascadeServer, ResponseJudger, ServeControl, ServerConfig, TierBackend,
};
use cascadia::util::bench::Bencher;
use cascadia::workload::{estimate_stats, generate, paper_trace, TraceStats};

struct InstantBackend;

impl TierBackend for InstantBackend {
    fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        Ok(vec![1; max_new.min(4)])
    }
}

struct ConstJudger(f64);

impl ResponseJudger for ConstJudger {
    fn score(&self, _p: &[i32], _o: &[i32]) -> f64 {
        self.0
    }
}

struct SwapOnce {
    control: Arc<ServeControl>,
    next: ServerConfig,
    fired: AtomicBool,
}

impl AdmissionObserver for SwapOnce {
    fn on_admit(&self, i: usize) {
        if i == 50 && !self.fired.swap(true, Ordering::SeqCst) {
            self.control.apply_config(self.next.clone()).unwrap();
        }
    }
}

fn main() {
    let mut b = Bencher::default();

    // Monitor ingest cost: window maintenance + stats estimation every
    // observation. An infinite threshold keeps detection armed (the
    // estimate runs) but never latches `pending`, so every measured
    // iteration pays the full path even after the stream wraps.
    let reqs = generate(&paper_trace(2, 4.0), 2000, 5);
    let baseline = estimate_stats(&reqs);
    let cfg = MonitorConfig { shift_threshold: f64::INFINITY, ..Default::default() };
    let mut monitor = Monitor::new(cfg, baseline);
    let mut i = 0usize;
    b.bench("monitor observe (ingest + estimate)", || {
        i = (i + 1) % reqs.len();
        monitor.observe(reqs[i]).is_some()
    });

    // Plan-cache lookup across a populated gear set.
    let mut cache = PlanCache::new(CacheConfig::default());
    let mut stats_set: Vec<TraceStats> = Vec::new();
    for t in 1..=3 {
        for &rate in &[2.0, 8.0, 32.0] {
            let sample = generate(&paper_trace(t, rate), 200, t as u64);
            stats_set.push(estimate_stats(&sample));
        }
    }
    // Seed the cache via misses recorded against a shared dummy plan
    // shape (lookups dominate; the plan payload is irrelevant here).
    let plan_sample = {
        use cascadia::parallel::Strategy;
        use cascadia::perf::Workload;
        use cascadia::router::PolicySpec;
        use cascadia::sched::plan::{CascadePlan, TierPlan};
        CascadePlan {
            policy: PolicySpec::threshold(vec![50.0]).unwrap(),
            tiers: vec![
                TierPlan {
                    model_name: "small".into(),
                    gpus: 4,
                    strategy: Some(Strategy::uniform(1, 1, 4)),
                    workload: Workload { rate: 4.0, avg_input: 300.0, avg_output: 100.0 },
                    processing_ratio: 1.0,
                    predicted_p95: 1.0,
                    disagg: None,
                },
                TierPlan {
                    model_name: "large".into(),
                    gpus: 8,
                    strategy: Some(Strategy::uniform(4, 1, 2)),
                    workload: Workload { rate: 1.0, avg_input: 300.0, avg_output: 100.0 },
                    processing_ratio: 0.2,
                    predicted_p95: 2.0,
                    disagg: None,
                },
            ],
            predicted_latency: 2.0,
            predicted_quality: 80.0,
            preemption: Vec::new(),
        }
    };
    for s in &stats_set {
        cache.insert(s, plan_sample.clone());
    }
    let mut j = 0usize;
    b.bench("plan-cache lookup (9 gears)", || {
        j = (j + 1) % stats_set.len();
        cache.get(&stats_set[j]).is_some()
    });

    // Swap-mailbox submission (validation + queue).
    let control = ServeControl::new(2);
    let next = ServerConfig::with_thresholds(vec![2, 1], vec![4, 4], vec![50.0], 4).unwrap();
    b.bench("serve-control submit (validate + queue)", || {
        control.apply_config(next.clone()).unwrap();
        control.hot_swaps()
    });

    // End-to-end: 200 instant-backend requests without vs with one
    // mid-run hot-swap — the delta is the swap's serving overhead.
    let trace: Vec<(f64, Vec<i32>)> = (0..200).map(|_| (0.0, vec![1, 2, 3])).collect();
    let factory = |_t: usize| -> Result<Box<dyn TierBackend>> { Ok(Box::new(InstantBackend)) };
    let server = CascadeServer::new(
        ServerConfig::with_thresholds(vec![2, 1], vec![8, 8], vec![50.0], 4).unwrap(),
    )
    .unwrap();
    b.bench("serve 200 requests (no swap)", || {
        server.serve(&trace, &factory, &ConstJudger(90.0)).unwrap().completions.len()
    });
    b.bench("serve 200 requests (one hot-swap mid-run)", || {
        let control = ServeControl::new(2);
        let swap = SwapOnce {
            control: Arc::clone(&control),
            next: ServerConfig::with_thresholds(vec![3, 2], vec![8, 8], vec![60.0], 4)
                .unwrap(),
            fired: AtomicBool::new(false),
        };
        server
            .serve_adaptive(&trace, &factory, &ConstJudger(90.0), &control, Some(&swap))
            .unwrap()
            .completions
            .len()
    });

    b.write_csv("results/bench_adapt.csv").unwrap();
    println!("wrote results/bench_adapt.csv");
}
