//! Integration tests of the online adaptation subsystem: the example
//! drift-replay config parses and drives the full monitor →
//! re-schedule → hot-swap loop end-to-end with zero dropped requests.

use cascadia::adapt::{run_replay, ReplayConfig};

fn example_config_path() -> String {
    concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/configs/drift_replay.json"
    )
    .to_string()
}

#[test]
fn example_drift_replay_config_parses() {
    let cfg = ReplayConfig::load(example_config_path()).expect("example config must load");
    cfg.validate().unwrap();
    assert_eq!(cfg.cascade_name, "deepseek");
    assert_eq!(cfg.phases.len(), 2);
    // The example drifts from the easy/short trace to the hard/long
    // one — the regime change the monitor must catch.
    assert_eq!(cfg.phases[0].trace_index, 3);
    assert_eq!(cfg.phases[1].trace_index, 1);
    assert!(cfg.phases[0].rate > cfg.phases[1].rate);
    assert!(cfg.time_scale >= 1.0);
}

#[test]
fn replay_smoke_runs_the_full_loop_without_drops() {
    // The example config, shrunk for test runtime: fewer requests and
    // heavier time compression, same drift shape.
    let mut cfg = ReplayConfig::load(example_config_path()).unwrap();
    cfg.phases[0].n_requests = 160;
    cfg.phases[1].n_requests = 220;
    cfg.time_scale = 60.0;
    cfg.validate().unwrap();

    let report = run_replay(&cfg).expect("replay must run end-to-end");
    let total = cfg.phases.iter().map(|p| p.n_requests).sum::<usize>();

    // The hot-swap contract: nothing dropped in either run.
    assert_eq!(report.frozen.dropped, 0, "frozen run dropped requests");
    assert_eq!(report.adaptive.dropped, 0, "adaptive run dropped requests");
    assert_eq!(report.frozen.served, total);
    assert_eq!(report.adaptive.served, total);

    // The drift must be detected and re-scheduled on.
    assert!(
        report.adaptive.counters.drifts_detected >= 1,
        "phase shift not detected: {}",
        report.adaptive.counters
    );
    assert!(
        report.adaptive.counters.reschedules >= 1,
        "no re-schedule fired: {}",
        report.adaptive.counters
    );
    assert!(report.final_plan.is_some(), "a re-scheduled plan must exist");
    // `hot_swaps` holds the server-applied count; it can never exceed
    // the number of plans the controller queued. (Whether the swap
    // lands before serving ends is timing-dependent at this heavy
    // compression, so >= 1 is asserted by `cascadia replay` on the
    // full-scale config, not here.)
    assert!(report.adaptive.counters.hot_swaps <= report.adaptive.counters.reschedules);

    // Per-phase reporting covers every phase for both runs.
    assert_eq!(report.frozen.phases.len(), 2);
    assert_eq!(report.adaptive.phases.len(), 2);
    for p in report.frozen.phases.iter().chain(&report.adaptive.phases) {
        assert!(p.requests > 0);
        assert!((0.0..=1.0).contains(&p.slo_attainment));
        assert!(p.latency.p50 <= p.latency.p99);
    }

    // The replay serves through the continuous-batching engine by
    // default and reports its queue + page telemetry per tier; page
    // occupancy never exceeds any pool budget.
    assert_eq!(report.adaptive.queue.len(), 3, "deepseek cascade has 3 tiers");
    assert_eq!(report.adaptive.engine.len(), 3);
    assert!(report.adaptive.queue.iter().any(|q| q.admitted > 0));
    assert!(report.adaptive.engine.iter().any(|e| e.iterations > 0));
    assert!(report
        .adaptive
        .engine
        .iter()
        .all(|e| e.peak_pages <= e.peak_pool_pages && e.forced_expansions == 0));
}
