//! Property-based tests over the scheduler/router/simulator invariants
//! (DESIGN.md §Testing), using the in-repo `util::prop` harness.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::Result;
use cascadia::cluster::ClusterSpec;
use cascadia::coordinator::server::TierBackend;
use cascadia::engine::{
    draft_agrees, prompt_page_hashes, EngineConfig, EngineCore, IterationScheduler, KvPool,
    PreemptionConfig, PreemptionMode, SeqId, StepBackend, VerifyOutcome,
};
use cascadia::judge::Judger;
use cascadia::models::{deepseek_cascade, llama_cascade};
use cascadia::perf::Workload;
use cascadia::router::{route, Thresholds};
use cascadia::sched::inner::{solve_dp, InnerOptions, InnerSolver};
use cascadia::sched::outer::{optimize, pareto_front, tchebycheff, OuterOptions, ParetoPoint};
use cascadia::sim::des::{simulate, SimRequest};
use cascadia::perf::ReplicaModel;
use cascadia::util::prop::{check_n, Gen};
use cascadia::workload::{generate, paper_trace};

fn rand_workloads(g: &mut Gen, tiers: usize) -> Vec<Workload> {
    (0..tiers)
        .map(|_| Workload {
            rate: if g.bool() { g.f64(0.1, 20.0) } else { 0.0 },
            avg_input: g.f64(64.0, 2048.0),
            avg_output: g.f64(32.0, 1024.0),
        })
        .collect()
}

/// Inner solver: the allocation always (a) uses the exact GPU budget,
/// (b) deploys exactly the tiers with traffic, (c) strategies fit their
/// allocations.
#[test]
fn prop_inner_allocation_feasible() {
    let cascade = deepseek_cascade();
    let cluster = ClusterSpec::paper_testbed();
    check_n("inner allocation feasible", 30, |g| {
        let mut tw = rand_workloads(g, 3);
        tw[0].rate = g.f64(0.5, 30.0); // tier 1 always has traffic
        let n_gpus = *g.choose(&[16usize, 24, 32]);
        let solver =
            InnerSolver::new(cascade.clone(), cluster.clone(), InnerOptions::default());
        match solver.solve(&tw, n_gpus) {
            Err(_) => Ok(()), // infeasible combos are allowed to error
            Ok(sol) => {
                if sol.gpus.iter().sum::<usize>() != n_gpus {
                    return Err(format!("budget violated: {:?} != {n_gpus}", sol.gpus));
                }
                for i in 0..3 {
                    let has_traffic = tw[i].rate > 0.0;
                    if has_traffic != (sol.gpus[i] > 0) {
                        return Err(format!(
                            "tier {i} traffic={has_traffic} but f={}",
                            sol.gpus[i]
                        ));
                    }
                    if let Some(s) = &sol.strategies[i] {
                        if s.gpus() > sol.gpus[i] {
                            return Err(format!(
                                "strategy {} exceeds allocation {}",
                                s.gpus(),
                                sol.gpus[i]
                            ));
                        }
                    }
                }
                Ok(())
            }
        }
    });
}

/// MILP optimum equals the exact DP optimum on the same tables.
#[test]
fn prop_milp_equals_dp() {
    let cascade = deepseek_cascade();
    let cluster = ClusterSpec::paper_testbed();
    check_n("milp == dp", 20, |g| {
        let mut tw = rand_workloads(g, 3);
        tw[0].rate = g.f64(0.5, 25.0);
        let n_gpus = *g.choose(&[16usize, 32]);
        let solver =
            InnerSolver::new(cascade.clone(), cluster.clone(), InnerOptions::default());
        let table = solver.tables(&tw, n_gpus);
        let active: Vec<usize> = (0..3).filter(|&i| tw[i].rate > 0.0).collect();
        let milp = solver.solve(&tw, n_gpus);
        let dp = solve_dp(&table, &active, n_gpus, 3);
        match (milp, dp) {
            (Err(_), Err(_)) => Ok(()),
            (Ok(m), Ok(d)) => {
                // Compare objective values, not allocations (ties).
                let obj = |alloc: &[usize]| -> f64 {
                    active
                        .iter()
                        .map(|&i| table.l[i][alloc[i]])
                        .fold(0.0, f64::max)
                };
                let mv = m.max_latency;
                let dv = obj(&d);
                if (mv - dv).abs() > 1e-6 * dv.max(1.0) {
                    return Err(format!("milp {mv} != dp {dv}"));
                }
                Ok(())
            }
            (m, d) => Err(format!(
                "feasibility disagreement: milp ok={} dp ok={}",
                m.is_ok(),
                d.is_ok()
            )),
        }
    });
}

/// Router conservation: every request is accepted at exactly one tier,
/// visits all tiers before it, and ratios are the visit shares.
#[test]
fn prop_router_conservation() {
    let cascade = deepseek_cascade();
    let judger = Judger::new(77);
    check_n("router conservation", 40, |g| {
        let n = g.sized(10, 400);
        let trace_idx = *g.choose(&[1usize, 2, 3]);
        let reqs = generate(&paper_trace(trace_idx, 5.0), n, g.int(0, 1 << 30) as u64);
        let h1 = g.f64(0.0, 100.0);
        let h2 = g.f64(0.0, h1);
        let span = reqs.last().unwrap().arrival.max(1e-9);
        let out = route(&cascade, &judger, &reqs, &Thresholds(vec![h1, h2]), span);
        if out.accepting_tier.len() != n {
            return Err("missing assignments".into());
        }
        // Ratios must be consistent with accepting tiers.
        for t in 0..3 {
            let visits = out
                .accepting_tier
                .iter()
                .filter(|&&a| a as usize >= t)
                .count() as f64
                / n as f64;
            if (visits - out.processing_ratios[t]).abs() > 1e-9 {
                return Err(format!("ratio mismatch at tier {t}"));
            }
        }
        // Monotone non-increasing ratios, p1 == 1.
        if out.processing_ratios[0] != 1.0 {
            return Err("p1 != 1".into());
        }
        if out.processing_ratios[1] > 1.0 || out.processing_ratios[2] > out.processing_ratios[1] {
            return Err("ratios not monotone".into());
        }
        Ok(())
    });
}

/// The Pareto front is mutually non-dominated and every Tchebycheff
/// winner (for any positive weights) lies on it.
#[test]
fn prop_pareto_front_sound() {
    check_n("pareto front sound", 30, |g| {
        // Synthetic point clouds (plans are irrelevant to the math, use
        // a fixed tiny plan).
        let n = g.sized(2, 60);
        let base_plan = {
            let cascade = llama_cascade();
            let cluster = ClusterSpec::paper_testbed();
            let judger = Judger::new(1);
            let reqs = generate(&paper_trace(3, 5.0), 50, 3);
            let opts = OuterOptions {
                threshold_grid: vec![50.0],
                ..Default::default()
            };
            optimize(&cascade, &cluster, &judger, &reqs, 16, &opts)
                .unwrap()
                .explored
                .remove(0)
                .plan
        };
        let points: Vec<ParetoPoint> = (0..n)
            .map(|_| ParetoPoint {
                latency: g.f64(0.1, 100.0),
                quality: g.f64(0.0, 100.0),
                plan: base_plan.clone(),
            })
            .collect();
        let front = pareto_front(&points);
        if front.is_empty() {
            return Err("empty front".into());
        }
        for a in &front {
            for b in &front {
                if a.latency < b.latency - 1e-12 && a.quality >= b.quality + 1e-12 {
                    return Err("front point dominated".into());
                }
            }
        }
        // Tchebycheff winner for random weights must be non-dominated.
        let utopia = (
            points.iter().map(|p| p.latency).fold(f64::INFINITY, f64::min),
            points.iter().map(|p| p.quality).fold(0.0, f64::max),
        );
        let l = (g.f64(0.01, 10.0), g.f64(0.01, 10.0));
        let winner = points
            .iter()
            .min_by(|a, b| {
                tchebycheff(a.latency, a.quality, utopia, l)
                    .partial_cmp(&tchebycheff(b.latency, b.quality, utopia, l))
                    .unwrap()
            })
            .unwrap();
        let strictly_dominated = points.iter().any(|q| {
            q.latency < winner.latency - 1e-12 && q.quality > winner.quality + 1e-12
        });
        if strictly_dominated {
            return Err("tchebycheff winner strictly dominated".into());
        }
        Ok(())
    });
}

/// Simulator sanity over random traces: all requests complete, latency
/// >= the no-queue service floor, completions are time-ordered.
#[test]
fn prop_simulator_conservation() {
    let m = &llama_cascade()[0];
    let cluster = ClusterSpec::paper_testbed();
    check_n("simulator conservation", 30, |g| {
        let replicas: Vec<ReplicaModel> = (0..g.sized(1, 3))
            .map(|_| {
                let tp = *g.choose(&[1usize, 2, 4]);
                ReplicaModel::new(m, &cluster, tp, 1, 768.0)
            })
            .collect();
        let n = g.sized(5, 300);
        let rate = g.f64(0.5, 30.0);
        let mut t = 0.0;
        let trace: Vec<SimRequest> = (0..n)
            .map(|_| {
                t += g.f64(0.0, 2.0 / rate);
                SimRequest::new(t, g.int(8, 2048) as u32, g.int(4, 512) as u32)
            })
            .collect();
        let out = simulate(&replicas, &trace);
        if out.latencies.len() != n {
            return Err(format!("{} of {n} completed", out.latencies.len()));
        }
        for (i, r) in trace.iter().enumerate() {
            let done = out.completions[i];
            if !done.is_finite() || done < r.arrival {
                return Err(format!("request {i} completed before arrival"));
            }
        }
        if out.latencies.iter().any(|l| *l <= 0.0) {
            return Err("non-positive latency".into());
        }
        Ok(())
    });
}

/// Raw KvPool soak under the full op mix — grow / claim / publish /
/// CoW-growth / swap-out / swap-in / release on random sequences:
/// after every op the pool's internal invariants hold (refcounts match
/// table references, free-list closure, trie liveness, shared pages
/// are published pages, swap space within budget), and a full release
/// drains to zero. The scheduler-level twin lives in
/// `rust/tests/swap_preemption.rs`.
#[test]
fn prop_kv_pool_swap_invariants() {
    check_n("kv pool swap invariants", 40, |g| {
        let page_tokens = 16usize;
        let capacity = g.sized(8, 40).max(8);
        let mut p = KvPool::new(capacity, page_tokens);
        let swap_budget = g.sized(0, 32);
        p.set_swap_capacity(swap_budget);
        let shared_prompt: Vec<i32> = (0..64).collect();
        let hashes = prompt_page_hashes(&shared_prompt, page_tokens);
        let mut live: Vec<SeqId> = Vec::new();
        let mut next: SeqId = 0;
        for _ in 0..g.sized(15, 120).max(15) {
            match g.int(0, 5) {
                0 | 1 => {
                    // New sequence: claim the shared prefix half the
                    // time, then grow into (or past) it — CoW path.
                    let id = next;
                    next += 1;
                    let claimed = if g.bool() {
                        p.claim_prefix(id, &hashes, 64)
                    } else {
                        0
                    };
                    let want = claimed + g.sized(1, 60).max(1);
                    if p.grow_to(id, want).is_ok() {
                        if g.bool() {
                            p.publish_prefix(id, &hashes);
                        }
                        live.push(id);
                    } else if claimed > 0 {
                        p.retract_claim(id);
                    } else {
                        p.release(id);
                    }
                }
                2 => {
                    // Grow a random live (unswapped) sequence a little.
                    if let Some(&id) = live.get(g.int(0, 31) as usize % live.len().max(1)) {
                        if !p.is_swapped(id) {
                            let _ = p.grow_to(id, g.sized(1, 80).max(1));
                        }
                    }
                }
                3 => {
                    // Swap a random live (unswapped) sequence out.
                    if !live.is_empty() {
                        let id = live[g.int(0, 31) as usize % live.len()];
                        if !p.is_swapped(id) {
                            let _ = p.swap_out(id);
                        }
                    }
                }
                4 => {
                    // Swap a random parked sequence back in.
                    if !live.is_empty() {
                        let id = live[g.int(0, 31) as usize % live.len()];
                        if p.is_swapped(id) {
                            let _ = p.swap_in(id);
                        }
                    }
                }
                _ => {
                    // Release a random sequence (parked or live).
                    if !live.is_empty() {
                        let idx = g.int(0, 31) as usize % live.len();
                        let id = live.swap_remove(idx);
                        p.release(id);
                    }
                }
            }
            p.validate().map_err(|e| format!("invariant: {e}"))?;
            if p.swapped_pages() > swap_budget {
                return Err(format!(
                    "swap space {} over budget {swap_budget}",
                    p.swapped_pages()
                ));
            }
        }
        for id in live.drain(..) {
            p.release(id);
        }
        p.validate().map_err(|e| format!("post-drain: {e}"))?;
        if p.in_use() != 0 || p.swapped_pages() != 0 || p.trie_len() != 0 {
            return Err(format!(
                "leak: in_use {} swapped {} trie {}",
                p.in_use(),
                p.swapped_pages(),
                p.trie_len()
            ));
        }
        Ok(())
    });
}

/// Scheduler-level speculation chaos soak: random enqueues, draft-k
/// changes, pool resizes and cancels interleave with plan-driven
/// execution under both eviction disciplines, with a random accepted
/// prefix settled per speculative task. After EVERY tick the pool's
/// internal invariants hold; every surviving sequence finishes exactly
/// once with exactly its token budget (speculation never over- or
/// under-emits); the acceptance counters match an externally-kept
/// mirror token for token; and the drained scheduler leaks nothing.
#[test]
fn prop_scheduler_speculation_lossless_accounting() {
    check_n("scheduler speculation soak", 30, |g| {
        let page_tokens = 16usize;
        let mut sched =
            IterationScheduler::new(KvPool::new(g.sized(24, 72), page_tokens), g.sized(2, 4));
        let mode = if g.bool() {
            PreemptionMode::Swap
        } else {
            PreemptionMode::Recompute
        };
        sched.set_preemption(PreemptionConfig {
            mode,
            swap_pages: g.sized(16, 64),
            prefill_s_per_token: 1e-4,
            swap_s_per_page: g.f64(1e-6, 1e-3),
            page_bytes: 1.0,
        });
        sched.set_spec_k(g.sized(0, 4));

        let total = g.sized(6, 12);
        let mut next_id: SeqId = 0;
        let mut budget: BTreeMap<SeqId, usize> = BTreeMap::new();
        let mut gen: BTreeMap<SeqId, usize> = BTreeMap::new();
        let mut finished: BTreeSet<SeqId> = BTreeSet::new();
        let mut cancelled: BTreeSet<SeqId> = BTreeSet::new();
        let (mut acc_mirror, mut rej_mirror) = (0u64, 0u64);
        let mut tick = 0usize;
        loop {
            tick += 1;
            if tick > 4000 {
                return Err("soak failed to drain within 4000 ticks".into());
            }
            // Random mutations ahead of the plan: arrivals, a live
            // draft-depth change, a pool resize, a cancellation.
            if next_id < total as u64 && (g.bool() || sched.is_idle()) {
                let id = next_id;
                next_id += 1;
                let max_new = g.sized(1, 30);
                sched.enqueue(id, g.sized(20, 120), max_new);
                budget.insert(id, max_new);
                gen.insert(id, 0);
            }
            if g.int(0, 9) == 0 {
                sched.set_spec_k(g.sized(0, 4));
            }
            if g.int(0, 9) == 0 {
                sched.resize_pool(g.sized(24, 96));
            }
            if g.int(0, 14) == 0 {
                let live: Vec<SeqId> = gen
                    .keys()
                    .copied()
                    .filter(|id| !finished.contains(id) && !cancelled.contains(id))
                    .collect();
                if !live.is_empty() {
                    let id = live[g.int(0, 31) as usize % live.len()];
                    sched.retire(id);
                    cancelled.insert(id);
                }
            }

            let plan = sched.next_iteration();
            for &id in &plan.preempted {
                if finished.contains(&id) || cancelled.contains(&id) {
                    return Err(format!("preempted retired sequence {id}"));
                }
                // Recompute semantics: progress resets to zero.
                gen.insert(id, 0);
            }
            let mut done: Vec<SeqId> = Vec::new();
            for c in &plan.prefill {
                if c.last {
                    *gen.get_mut(&c.id).unwrap() += 1;
                    if sched.advance(c.id) {
                        done.push(c.id);
                    }
                }
            }
            for &id in &plan.decode {
                *gen.get_mut(&id).unwrap() += 1;
                if sched.advance(id) {
                    done.push(id);
                }
            }
            for t in &plan.spec {
                if t.k == 0 {
                    return Err(format!("zero-depth speculative task for {}", t.id));
                }
                let g_now = gen[&t.id];
                let cap = budget[&t.id];
                if g_now + t.k + 1 > cap {
                    return Err(format!(
                        "spec task for {} can overshoot: gen {g_now} + k {} + 1 > max_new {cap}",
                        t.id, t.k
                    ));
                }
                let accepted = g.sized(0, t.k);
                acc_mirror += accepted as u64;
                rej_mirror += (t.k - accepted) as u64;
                *gen.get_mut(&t.id).unwrap() += accepted + 1;
                if sched.advance_spec(t.id, t.k, accepted + 1) {
                    done.push(t.id);
                }
            }
            for id in done {
                if !finished.insert(id) {
                    return Err(format!("sequence {id} finished twice"));
                }
                if gen[&id] != budget[&id] {
                    return Err(format!(
                        "sequence {id} finished with {} of {} tokens",
                        gen[&id], budget[&id]
                    ));
                }
                sched.retire(id);
            }
            sched
                .pool()
                .validate()
                .map_err(|e| format!("tick {tick}: {e}"))?;
            if next_id >= total as u64 && sched.is_idle() {
                break;
            }
        }

        if finished.len() + cancelled.len() != total {
            return Err(format!(
                "{} finished + {} cancelled != {total} submitted",
                finished.len(),
                cancelled.len()
            ));
        }
        let (acc, rej) = sched.spec_counts();
        if (acc, rej) != (acc_mirror, rej_mirror) {
            return Err(format!(
                "acceptance counters ({acc}, {rej}) != mirror ({acc_mirror}, {rej_mirror})"
            ));
        }
        let p = sched.pool();
        if p.in_use() != 0 || p.swapped_pages() != 0 || p.trie_len() != 0 {
            return Err(format!(
                "leak: in_use {} swapped {} trie {}",
                p.in_use(),
                p.swapped_pages(),
                p.trie_len()
            ));
        }
        Ok(())
    });
}

/// Deterministic verify-model backend for the end-to-end losslessness
/// property: token `p` of sequence `s` is a pure function of `(s, p)`,
/// the draft stream agrees with it per [`draft_agrees`], and verify
/// accepts exactly the leading prefix the verify model would have
/// produced alone. Per-sequence position state drops on `release` so a
/// recompute-preempted sequence replays the identical stream.
struct LossStep {
    agree_mod: u64,
    pos: BTreeMap<SeqId, usize>,
}

fn model_tok(seq: SeqId, pos: usize) -> i32 {
    (seq.wrapping_mul(31).wrapping_add(pos as u64 * 7) % 997) as i32 + 1
}

impl StepBackend for LossStep {
    fn prefill_chunk(&mut self, seq: SeqId, _chunk: &[i32], last: bool) -> Result<Option<i32>> {
        if last {
            self.pos.insert(seq, 1);
            return Ok(Some(model_tok(seq, 0)));
        }
        Ok(None)
    }
    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
        Ok(seqs
            .iter()
            .map(|&s| {
                let p = self.pos.entry(s).or_insert(0);
                let t = model_tok(s, *p);
                *p += 1;
                t
            })
            .collect())
    }
    fn release(&mut self, seq: SeqId) {
        self.pos.remove(&seq);
    }
    fn draft(&mut self, seq: SeqId, k: usize) -> Result<Option<Vec<i32>>> {
        let base = self.pos.get(&seq).copied().unwrap_or(0);
        Ok(Some(
            (0..k)
                .map(|i| {
                    let t = model_tok(seq, base + i);
                    if draft_agrees(seq, base + i, self.agree_mod) {
                        t
                    } else {
                        t.wrapping_add(1)
                    }
                })
                .collect(),
        ))
    }
    fn verify(&mut self, seq: SeqId, draft: &[i32]) -> Result<Option<VerifyOutcome>> {
        let base = self.pos.get(&seq).copied().unwrap_or(0);
        let accepted = draft
            .iter()
            .enumerate()
            .take_while(|&(i, &t)| t == model_tok(seq, base + i))
            .count();
        let next = model_tok(seq, base + accepted);
        *self.pos.entry(seq).or_insert(0) += accepted + 1;
        Ok(Some(VerifyOutcome { accepted, next }))
    }
}

impl TierBackend for LossStep {
    fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        Ok(vec![0; max_new])
    }
    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        Some(self)
    }
}

/// Run one arm of the losslessness property: an [`EngineCore`] over a
/// [`LossStep`] at the given draft depth (`0` = plain decode), with a
/// pool resize landing mid-run. Returns per-request outputs in submit
/// order plus the acceptance counters.
fn run_loss_arm(
    trace: &[(usize, usize)],
    cfg: EngineConfig,
    spec_k: usize,
    agree_mod: u64,
    resize: (usize, usize),
) -> Result<(Vec<Vec<i32>>, (u64, u64)), String> {
    let backend = LossStep {
        agree_mod,
        pos: BTreeMap::new(),
    };
    let mut eng: EngineCore<usize> = EngineCore::new(Box::new(backend), cfg);
    eng.set_speculation(spec_k);
    for (i, &(prompt_tokens, max_new)) in trace.iter().enumerate() {
        eng.submit(i, vec![7; prompt_tokens], max_new);
    }
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); trace.len()];
    let mut tick = 0usize;
    while !eng.is_idle() {
        tick += 1;
        if tick > 10_000 {
            return Err("engine failed to drain".into());
        }
        if tick == resize.0 {
            eng.set_pool_pages(resize.1);
        }
        let out = eng.step().expect("deterministic backend cannot fail");
        for f in out.completed {
            outputs[f.payload] = f.output;
        }
    }
    if eng.kv_in_use() != 0 {
        return Err(format!("pool leak: {} pages in use", eng.kv_in_use()));
    }
    Ok((outputs, eng.spec_counts()))
}

/// End-to-end losslessness pin for cross-tier speculation: an
/// [`EngineCore`] running draft→verify speculation emits BIT-IDENTICAL
/// per-request outputs to a plain-decode run of the same deterministic
/// backend — across random draft depths, draft-disagreement patterns,
/// both eviction disciplines, pool contention and a mid-run pool
/// resize — while the acceptance counters prove speculation actually
/// engaged (full acceptance when the draft always agrees, zero when it
/// never does).
#[test]
fn prop_engine_speculation_is_lossless() {
    check_n("engine speculation lossless", 20, |g| {
        let n = g.sized(4, 8);
        let trace: Vec<(usize, usize)> = (0..n)
            .map(|_| (g.sized(24, 140), g.sized(4, 28)))
            .collect();
        let agree_mod = *g.choose(&[0u64, 1, 2, 3, 5]);
        let k = g.sized(1, 4);
        let mode = if g.bool() {
            PreemptionMode::Swap
        } else {
            PreemptionMode::Recompute
        };
        let cfg = EngineConfig {
            pool_pages: g.sized(24, 64),
            page_tokens: 16,
            max_running: g.sized(2, 4),
            prefill_chunk: if g.bool() { usize::MAX } else { 32 },
            share_prefixes: false,
            preemption: PreemptionConfig {
                mode,
                swap_pages: 64,
                prefill_s_per_token: 1e-4,
                swap_s_per_page: 1e-5,
                page_bytes: 1.0,
            },
        };
        let resize = (g.sized(2, 20), g.sized(24, 72));
        let (plain, plain_counts) = run_loss_arm(&trace, cfg, 0, agree_mod, resize)?;
        let (spec, spec_counts) = run_loss_arm(&trace, cfg, k, agree_mod, resize)?;
        if plain_counts != (0, 0) {
            return Err(format!("plain arm speculated: {plain_counts:?}"));
        }
        for (i, &(_, max_new)) in trace.iter().enumerate() {
            if plain[i].len() != max_new {
                return Err(format!(
                    "plain request {i}: {} of {max_new} tokens",
                    plain[i].len()
                ));
            }
            if spec[i] != plain[i] {
                return Err(format!(
                    "request {i} diverged under speculation:\n  plain {:?}\n  spec  {:?}",
                    plain[i], spec[i]
                ));
            }
        }
        let (acc, rej) = spec_counts;
        match agree_mod {
            0 if acc == 0 || rej != 0 => {
                return Err(format!(
                    "always-agreeing draft should fully accept: ({acc}, {rej})"
                ));
            }
            1 if acc != 0 => {
                return Err(format!(
                    "never-agreeing draft should accept nothing: ({acc}, {rej})"
                ));
            }
            _ => {}
        }
        if acc + rej == 0 {
            return Err("speculation never engaged".into());
        }
        Ok(())
    });
}

/// Higher thresholds can only raise (weakly) the cascade's judged
/// quality and the share of requests reaching deeper tiers.
#[test]
fn prop_thresholds_monotone_effects() {
    let cascade = deepseek_cascade();
    let judger = Judger::new(13);
    check_n("threshold monotonicity", 25, |g| {
        let reqs = generate(&paper_trace(2, 5.0), 300, g.int(0, 1 << 30) as u64);
        let span = reqs.last().unwrap().arrival.max(1e-9);
        let lo = g.f64(0.0, 60.0);
        let hi = lo + g.f64(5.0, 40.0);
        let low = route(&cascade, &judger, &reqs, &Thresholds(vec![lo, lo]), span);
        let high = route(&cascade, &judger, &reqs, &Thresholds(vec![hi, hi]), span);
        if high.processing_ratios[2] + 1e-9 < low.processing_ratios[2] {
            return Err(format!(
                "raising thresholds reduced escalation: {} -> {}",
                low.processing_ratios[2], high.processing_ratios[2]
            ));
        }
        Ok(())
    });
}
