//! Property-based tests over the scheduler/router/simulator invariants
//! (DESIGN.md §Testing), using the in-repo `util::prop` harness.

use cascadia::cluster::ClusterSpec;
use cascadia::engine::{prompt_page_hashes, KvPool, SeqId};
use cascadia::judge::Judger;
use cascadia::models::{deepseek_cascade, llama_cascade};
use cascadia::perf::Workload;
use cascadia::router::{route, Thresholds};
use cascadia::sched::inner::{solve_dp, InnerOptions, InnerSolver};
use cascadia::sched::outer::{optimize, pareto_front, tchebycheff, OuterOptions, ParetoPoint};
use cascadia::sim::des::{simulate, SimRequest};
use cascadia::perf::ReplicaModel;
use cascadia::util::prop::{check_n, Gen};
use cascadia::workload::{generate, paper_trace};

fn rand_workloads(g: &mut Gen, tiers: usize) -> Vec<Workload> {
    (0..tiers)
        .map(|_| Workload {
            rate: if g.bool() { g.f64(0.1, 20.0) } else { 0.0 },
            avg_input: g.f64(64.0, 2048.0),
            avg_output: g.f64(32.0, 1024.0),
        })
        .collect()
}

/// Inner solver: the allocation always (a) uses the exact GPU budget,
/// (b) deploys exactly the tiers with traffic, (c) strategies fit their
/// allocations.
#[test]
fn prop_inner_allocation_feasible() {
    let cascade = deepseek_cascade();
    let cluster = ClusterSpec::paper_testbed();
    check_n("inner allocation feasible", 30, |g| {
        let mut tw = rand_workloads(g, 3);
        tw[0].rate = g.f64(0.5, 30.0); // tier 1 always has traffic
        let n_gpus = *g.choose(&[16usize, 24, 32]);
        let solver =
            InnerSolver::new(cascade.clone(), cluster.clone(), InnerOptions::default());
        match solver.solve(&tw, n_gpus) {
            Err(_) => Ok(()), // infeasible combos are allowed to error
            Ok(sol) => {
                if sol.gpus.iter().sum::<usize>() != n_gpus {
                    return Err(format!("budget violated: {:?} != {n_gpus}", sol.gpus));
                }
                for i in 0..3 {
                    let has_traffic = tw[i].rate > 0.0;
                    if has_traffic != (sol.gpus[i] > 0) {
                        return Err(format!(
                            "tier {i} traffic={has_traffic} but f={}",
                            sol.gpus[i]
                        ));
                    }
                    if let Some(s) = &sol.strategies[i] {
                        if s.gpus() > sol.gpus[i] {
                            return Err(format!(
                                "strategy {} exceeds allocation {}",
                                s.gpus(),
                                sol.gpus[i]
                            ));
                        }
                    }
                }
                Ok(())
            }
        }
    });
}

/// MILP optimum equals the exact DP optimum on the same tables.
#[test]
fn prop_milp_equals_dp() {
    let cascade = deepseek_cascade();
    let cluster = ClusterSpec::paper_testbed();
    check_n("milp == dp", 20, |g| {
        let mut tw = rand_workloads(g, 3);
        tw[0].rate = g.f64(0.5, 25.0);
        let n_gpus = *g.choose(&[16usize, 32]);
        let solver =
            InnerSolver::new(cascade.clone(), cluster.clone(), InnerOptions::default());
        let table = solver.tables(&tw, n_gpus);
        let active: Vec<usize> = (0..3).filter(|&i| tw[i].rate > 0.0).collect();
        let milp = solver.solve(&tw, n_gpus);
        let dp = solve_dp(&table, &active, n_gpus, 3);
        match (milp, dp) {
            (Err(_), Err(_)) => Ok(()),
            (Ok(m), Ok(d)) => {
                // Compare objective values, not allocations (ties).
                let obj = |alloc: &[usize]| -> f64 {
                    active
                        .iter()
                        .map(|&i| table.l[i][alloc[i]])
                        .fold(0.0, f64::max)
                };
                let mv = m.max_latency;
                let dv = obj(&d);
                if (mv - dv).abs() > 1e-6 * dv.max(1.0) {
                    return Err(format!("milp {mv} != dp {dv}"));
                }
                Ok(())
            }
            (m, d) => Err(format!(
                "feasibility disagreement: milp ok={} dp ok={}",
                m.is_ok(),
                d.is_ok()
            )),
        }
    });
}

/// Router conservation: every request is accepted at exactly one tier,
/// visits all tiers before it, and ratios are the visit shares.
#[test]
fn prop_router_conservation() {
    let cascade = deepseek_cascade();
    let judger = Judger::new(77);
    check_n("router conservation", 40, |g| {
        let n = g.sized(10, 400);
        let trace_idx = *g.choose(&[1usize, 2, 3]);
        let reqs = generate(&paper_trace(trace_idx, 5.0), n, g.int(0, 1 << 30) as u64);
        let h1 = g.f64(0.0, 100.0);
        let h2 = g.f64(0.0, h1);
        let span = reqs.last().unwrap().arrival.max(1e-9);
        let out = route(&cascade, &judger, &reqs, &Thresholds(vec![h1, h2]), span);
        if out.accepting_tier.len() != n {
            return Err("missing assignments".into());
        }
        // Ratios must be consistent with accepting tiers.
        for t in 0..3 {
            let visits = out
                .accepting_tier
                .iter()
                .filter(|&&a| a as usize >= t)
                .count() as f64
                / n as f64;
            if (visits - out.processing_ratios[t]).abs() > 1e-9 {
                return Err(format!("ratio mismatch at tier {t}"));
            }
        }
        // Monotone non-increasing ratios, p1 == 1.
        if out.processing_ratios[0] != 1.0 {
            return Err("p1 != 1".into());
        }
        if out.processing_ratios[1] > 1.0 || out.processing_ratios[2] > out.processing_ratios[1] {
            return Err("ratios not monotone".into());
        }
        Ok(())
    });
}

/// The Pareto front is mutually non-dominated and every Tchebycheff
/// winner (for any positive weights) lies on it.
#[test]
fn prop_pareto_front_sound() {
    check_n("pareto front sound", 30, |g| {
        // Synthetic point clouds (plans are irrelevant to the math, use
        // a fixed tiny plan).
        let n = g.sized(2, 60);
        let base_plan = {
            let cascade = llama_cascade();
            let cluster = ClusterSpec::paper_testbed();
            let judger = Judger::new(1);
            let reqs = generate(&paper_trace(3, 5.0), 50, 3);
            let opts = OuterOptions {
                threshold_grid: vec![50.0],
                ..Default::default()
            };
            optimize(&cascade, &cluster, &judger, &reqs, 16, &opts)
                .unwrap()
                .explored
                .remove(0)
                .plan
        };
        let points: Vec<ParetoPoint> = (0..n)
            .map(|_| ParetoPoint {
                latency: g.f64(0.1, 100.0),
                quality: g.f64(0.0, 100.0),
                plan: base_plan.clone(),
            })
            .collect();
        let front = pareto_front(&points);
        if front.is_empty() {
            return Err("empty front".into());
        }
        for a in &front {
            for b in &front {
                if a.latency < b.latency - 1e-12 && a.quality >= b.quality + 1e-12 {
                    return Err("front point dominated".into());
                }
            }
        }
        // Tchebycheff winner for random weights must be non-dominated.
        let utopia = (
            points.iter().map(|p| p.latency).fold(f64::INFINITY, f64::min),
            points.iter().map(|p| p.quality).fold(0.0, f64::max),
        );
        let l = (g.f64(0.01, 10.0), g.f64(0.01, 10.0));
        let winner = points
            .iter()
            .min_by(|a, b| {
                tchebycheff(a.latency, a.quality, utopia, l)
                    .partial_cmp(&tchebycheff(b.latency, b.quality, utopia, l))
                    .unwrap()
            })
            .unwrap();
        let strictly_dominated = points.iter().any(|q| {
            q.latency < winner.latency - 1e-12 && q.quality > winner.quality + 1e-12
        });
        if strictly_dominated {
            return Err("tchebycheff winner strictly dominated".into());
        }
        Ok(())
    });
}

/// Simulator sanity over random traces: all requests complete, latency
/// >= the no-queue service floor, completions are time-ordered.
#[test]
fn prop_simulator_conservation() {
    let m = &llama_cascade()[0];
    let cluster = ClusterSpec::paper_testbed();
    check_n("simulator conservation", 30, |g| {
        let replicas: Vec<ReplicaModel> = (0..g.sized(1, 3))
            .map(|_| {
                let tp = *g.choose(&[1usize, 2, 4]);
                ReplicaModel::new(m, &cluster, tp, 1, 768.0)
            })
            .collect();
        let n = g.sized(5, 300);
        let rate = g.f64(0.5, 30.0);
        let mut t = 0.0;
        let trace: Vec<SimRequest> = (0..n)
            .map(|_| {
                t += g.f64(0.0, 2.0 / rate);
                SimRequest::new(t, g.int(8, 2048) as u32, g.int(4, 512) as u32)
            })
            .collect();
        let out = simulate(&replicas, &trace);
        if out.latencies.len() != n {
            return Err(format!("{} of {n} completed", out.latencies.len()));
        }
        for (i, r) in trace.iter().enumerate() {
            let done = out.completions[i];
            if !done.is_finite() || done < r.arrival {
                return Err(format!("request {i} completed before arrival"));
            }
        }
        if out.latencies.iter().any(|l| *l <= 0.0) {
            return Err("non-positive latency".into());
        }
        Ok(())
    });
}

/// Raw KvPool soak under the full op mix — grow / claim / publish /
/// CoW-growth / swap-out / swap-in / release on random sequences:
/// after every op the pool's internal invariants hold (refcounts match
/// table references, free-list closure, trie liveness, shared pages
/// are published pages, swap space within budget), and a full release
/// drains to zero. The scheduler-level twin lives in
/// `rust/tests/swap_preemption.rs`.
#[test]
fn prop_kv_pool_swap_invariants() {
    check_n("kv pool swap invariants", 40, |g| {
        let page_tokens = 16usize;
        let capacity = g.sized(8, 40).max(8);
        let mut p = KvPool::new(capacity, page_tokens);
        let swap_budget = g.sized(0, 32);
        p.set_swap_capacity(swap_budget);
        let shared_prompt: Vec<i32> = (0..64).collect();
        let hashes = prompt_page_hashes(&shared_prompt, page_tokens);
        let mut live: Vec<SeqId> = Vec::new();
        let mut next: SeqId = 0;
        for _ in 0..g.sized(15, 120).max(15) {
            match g.int(0, 5) {
                0 | 1 => {
                    // New sequence: claim the shared prefix half the
                    // time, then grow into (or past) it — CoW path.
                    let id = next;
                    next += 1;
                    let claimed = if g.bool() {
                        p.claim_prefix(id, &hashes, 64)
                    } else {
                        0
                    };
                    let want = claimed + g.sized(1, 60).max(1);
                    if p.grow_to(id, want).is_ok() {
                        if g.bool() {
                            p.publish_prefix(id, &hashes);
                        }
                        live.push(id);
                    } else if claimed > 0 {
                        p.retract_claim(id);
                    } else {
                        p.release(id);
                    }
                }
                2 => {
                    // Grow a random live (unswapped) sequence a little.
                    if let Some(&id) = live.get(g.int(0, 31) as usize % live.len().max(1)) {
                        if !p.is_swapped(id) {
                            let _ = p.grow_to(id, g.sized(1, 80).max(1));
                        }
                    }
                }
                3 => {
                    // Swap a random live (unswapped) sequence out.
                    if !live.is_empty() {
                        let id = live[g.int(0, 31) as usize % live.len()];
                        if !p.is_swapped(id) {
                            let _ = p.swap_out(id);
                        }
                    }
                }
                4 => {
                    // Swap a random parked sequence back in.
                    if !live.is_empty() {
                        let id = live[g.int(0, 31) as usize % live.len()];
                        if p.is_swapped(id) {
                            let _ = p.swap_in(id);
                        }
                    }
                }
                _ => {
                    // Release a random sequence (parked or live).
                    if !live.is_empty() {
                        let idx = g.int(0, 31) as usize % live.len();
                        let id = live.swap_remove(idx);
                        p.release(id);
                    }
                }
            }
            p.validate().map_err(|e| format!("invariant: {e}"))?;
            if p.swapped_pages() > swap_budget {
                return Err(format!(
                    "swap space {} over budget {swap_budget}",
                    p.swapped_pages()
                ));
            }
        }
        for id in live.drain(..) {
            p.release(id);
        }
        p.validate().map_err(|e| format!("post-drain: {e}"))?;
        if p.in_use() != 0 || p.swapped_pages() != 0 || p.trie_len() != 0 {
            return Err(format!(
                "leak: in_use {} swapped {} trie {}",
                p.in_use(),
                p.swapped_pages(),
                p.trie_len()
            ));
        }
        Ok(())
    });
}

/// Higher thresholds can only raise (weakly) the cascade's judged
/// quality and the share of requests reaching deeper tiers.
#[test]
fn prop_thresholds_monotone_effects() {
    let cascade = deepseek_cascade();
    let judger = Judger::new(13);
    check_n("threshold monotonicity", 25, |g| {
        let reqs = generate(&paper_trace(2, 5.0), 300, g.int(0, 1 << 30) as u64);
        let span = reqs.last().unwrap().arrival.max(1e-9);
        let lo = g.f64(0.0, 60.0);
        let hi = lo + g.f64(5.0, 40.0);
        let low = route(&cascade, &judger, &reqs, &Thresholds(vec![lo, lo]), span);
        let high = route(&cascade, &judger, &reqs, &Thresholds(vec![hi, hi]), span);
        if high.processing_ratios[2] + 1e-9 < low.processing_ratios[2] {
            return Err(format!(
                "raising thresholds reduced escalation: {} -> {}",
                low.processing_ratios[2], high.processing_ratios[2]
            ));
        }
        Ok(())
    });
}
