//! Integration tests of the continuous-batching execution engine on
//! the public serving API: the execution discipline must not change
//! routing outcomes, the paged simulator must agree with the legacy
//! continuous simulator when pages never bind, and engine telemetry
//! must hold the page-budget invariant end to end.

use anyhow::Result;
use cascadia::cluster::ClusterSpec;
use cascadia::coordinator::server::{
    CascadeServer, ResponseJudger, ServerConfig, ServerStats, TierBackend,
};
use cascadia::engine::EngineConfig;
use cascadia::models::llama_cascade;
use cascadia::perf::ReplicaModel;
use cascadia::sim::{simulate_mode, DesMode, SimRequest};

/// Tier t answers correctly iff the prompt's difficulty (first token)
/// is <= t; output length runs to max_new so decode actually iterates.
struct DifficultyBackend {
    tier: usize,
}

impl TierBackend for DifficultyBackend {
    fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let difficulty = prompt.first().copied().unwrap_or(0);
        let ok = difficulty <= self.tier as i32;
        Ok(vec![if ok { 1 } else { 0 }; max_new])
    }
}

struct BinaryJudger;

impl ResponseJudger for BinaryJudger {
    fn score(&self, _prompt: &[i32], output: &[i32]) -> f64 {
        if output.first() == Some(&1) {
            90.0
        } else {
            10.0
        }
    }
}

fn factory(tier: usize) -> Result<Box<dyn TierBackend>> {
    Ok(Box::new(DifficultyBackend { tier }))
}

fn accepting_tiers(stats: &ServerStats, n: usize) -> Vec<usize> {
    let mut v = vec![usize::MAX; n];
    for c in &stats.completions {
        v[c.id] = c.accepting_tier;
    }
    v
}

#[test]
fn continuous_and_lockstep_route_identically() {
    // Difficulty i%3 deterministically accepts at tier i%3 under the
    // 50-point bars; the inner-loop discipline must not change that.
    let trace: Vec<(f64, Vec<i32>)> =
        (0..30).map(|i| (0.0, vec![(i % 3) as i32, 5, 6])).collect();
    let base =
        ServerConfig::with_thresholds(vec![2, 1, 1], vec![6, 4, 2], vec![50.0, 50.0], 4)
            .unwrap();

    let lock = CascadeServer::new(base.clone())
        .unwrap()
        .serve(&trace, &factory, &BinaryJudger)
        .unwrap();
    let engines = vec![
        EngineConfig {
            pool_pages: 512,
            page_tokens: 16,
            max_running: 8,
            prefill_chunk: usize::MAX,
            share_prefixes: true,
        };
        3
    ];
    let cont = CascadeServer::new(base.continuous(engines))
        .unwrap()
        .serve(&trace, &factory, &BinaryJudger)
        .unwrap();

    assert_eq!(lock.completions.len(), 30);
    assert_eq!(cont.completions.len(), 30);
    assert_eq!(
        accepting_tiers(&lock, 30),
        accepting_tiers(&cont, 30),
        "execution mode must not change routing outcomes"
    );
    assert_eq!(lock.per_tier_processed, cont.per_tier_processed);

    // Engine telemetry holds the budget invariant; lockstep reports
    // zeros.
    assert!(cont.engine.iter().all(|e| e.peak_pages <= e.peak_pool_pages));
    assert!(cont.engine[0].iterations > 0);
    assert!(lock.engine.iter().all(|e| e.iterations == 0));
    // Queue telemetry reports on both paths.
    assert_eq!(lock.queue.len(), 3);
    assert_eq!(cont.queue.len(), 3);
    assert_eq!(lock.queue[0].admitted, 30);
    assert_eq!(cont.queue[0].admitted, 30);
}

#[test]
fn paged_des_matches_continuous_des_when_pages_never_bind() {
    // Light load on an amply provisioned replica: page-granular
    // admission must reproduce the legacy request-count simulator's
    // timeline (same admissions, same iteration costs).
    let m = &llama_cascade()[0];
    let rm = ReplicaModel::new(m, &ClusterSpec::paper_testbed(), 2, 1, 768.0);
    let trace: Vec<SimRequest> = (0..60)
        .map(|i| SimRequest::new(i as f64 * 0.4, 512, 64))
        .collect();
    let cont = simulate_mode(&[rm.clone()], &trace, DesMode::Continuous);
    let paged = simulate_mode(
        &[rm.clone()],
        &trace,
        DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX },
    );
    assert_eq!(cont.latencies.len(), paged.latencies.len());
    let rel = (paged.p95() - cont.p95()).abs() / cont.p95().max(1e-12);
    assert!(rel < 1e-6, "paged p95 {} vs continuous {}", paged.p95(), cont.p95());
    assert_eq!(paged.preemptions, 0);
    assert!(paged.peak_pages > 0);
    assert!(paged.peak_pages <= rm.kv_pages_total(16));
}
