//! Integration tests of the continuous-batching execution engine on
//! the public serving API: the execution discipline must not change
//! routing outcomes, the paged simulator must agree with the legacy
//! continuous simulator when pages never bind, and engine telemetry
//! must hold the page-budget invariant end to end.

use std::collections::BTreeMap;

use anyhow::Result;
use cascadia::cluster::ClusterSpec;
use cascadia::coordinator::server::{
    CascadeServer, ResponseJudger, ServerConfig, ServerStats, TierBackend,
};
use cascadia::engine::{
    draft_agrees, EngineConfig, EngineCore, EngineRole, PreemptionConfig, PreemptionMode, SeqId,
    StepBackend, VerifyOutcome,
};
use cascadia::models::llama_cascade;
use cascadia::parallel::ACT_RESERVE;
use cascadia::perf::ReplicaModel;
use cascadia::sim::{simulate_disagg, simulate_mode, DesMode, SimRequest, SpecSim};

/// Tier t answers correctly iff the prompt's difficulty (first token)
/// is <= t; output length runs to max_new so decode actually iterates.
struct DifficultyBackend {
    tier: usize,
}

impl TierBackend for DifficultyBackend {
    fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let difficulty = prompt.first().copied().unwrap_or(0);
        let ok = difficulty <= self.tier as i32;
        Ok(vec![if ok { 1 } else { 0 }; max_new])
    }
}

struct BinaryJudger;

impl ResponseJudger for BinaryJudger {
    fn score(&self, _prompt: &[i32], output: &[i32]) -> f64 {
        if output.first() == Some(&1) {
            90.0
        } else {
            10.0
        }
    }
}

fn factory(tier: usize) -> Result<Box<dyn TierBackend>> {
    Ok(Box::new(DifficultyBackend { tier }))
}

fn accepting_tiers(stats: &ServerStats, n: usize) -> Vec<usize> {
    let mut v = vec![usize::MAX; n];
    for c in &stats.completions {
        v[c.id] = c.accepting_tier;
    }
    v
}

#[test]
fn continuous_and_lockstep_route_identically() {
    // Difficulty i%3 deterministically accepts at tier i%3 under the
    // 50-point bars; the inner-loop discipline must not change that.
    let trace: Vec<(f64, Vec<i32>)> =
        (0..30).map(|i| (0.0, vec![(i % 3) as i32, 5, 6])).collect();
    let base =
        ServerConfig::with_thresholds(vec![2, 1, 1], vec![6, 4, 2], vec![50.0, 50.0], 4)
            .unwrap();

    let lock = CascadeServer::new(base.clone())
        .unwrap()
        .serve(&trace, &factory, &BinaryJudger)
        .unwrap();
    let engines = vec![
        EngineConfig {
            pool_pages: 512,
            page_tokens: 16,
            max_running: 8,
            prefill_chunk: usize::MAX,
            share_prefixes: true,
            preemption: cascadia::engine::PreemptionConfig::default(),
        };
        3
    ];
    let cont = CascadeServer::new(base.continuous(engines))
        .unwrap()
        .serve(&trace, &factory, &BinaryJudger)
        .unwrap();

    assert_eq!(lock.completions.len(), 30);
    assert_eq!(cont.completions.len(), 30);
    assert_eq!(
        accepting_tiers(&lock, 30),
        accepting_tiers(&cont, 30),
        "execution mode must not change routing outcomes"
    );
    assert_eq!(lock.per_tier_processed, cont.per_tier_processed);

    // Engine telemetry holds the budget invariant; lockstep reports
    // zeros.
    assert!(cont.engine.iter().all(|e| e.peak_pages <= e.peak_pool_pages));
    assert!(cont.engine[0].iterations > 0);
    assert!(lock.engine.iter().all(|e| e.iterations == 0));
    // Queue telemetry reports on both paths.
    assert_eq!(lock.queue.len(), 3);
    assert_eq!(cont.queue.len(), 3);
    assert_eq!(lock.queue[0].admitted, 30);
    assert_eq!(cont.queue[0].admitted, 30);
}

/// Deterministic token-by-token backend for the equivalence pin.
struct PinStep;

impl StepBackend for PinStep {
    fn prefill_chunk(&mut self, seq: SeqId, _chunk: &[i32], last: bool) -> Result<Option<i32>> {
        Ok(last.then_some(seq as i32))
    }
    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
        Ok(seqs.iter().map(|&s| s as i32).collect())
    }
    fn release(&mut self, _seq: SeqId) {}
}

impl TierBackend for PinStep {
    fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        Ok(vec![0; max_new])
    }
    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        Some(self)
    }
}

/// A replica whose KV budget is exactly `kv_pages` pages of 16 tokens:
/// the GPU memory is shrunk until the weights leave only that much KV
/// room, so a handful of medium requests saturates the pool and the
/// eviction policy actually fires.
fn tiny_pool_replica(kv_pages: usize) -> ReplicaModel {
    let m = &llama_cascade()[0];
    let mut c = ClusterSpec::paper_testbed();
    let kv_bytes = kv_pages as f64 * 16.0 * m.kv_bytes_per_token();
    c.gpu.mem_bytes = (m.weight_bytes() + kv_bytes) / (1.0 - ACT_RESERVE);
    // Small avg_ctx keeps the request-count clamp above the page bound
    // so pages, not slots, are what binds.
    ReplicaModel::new(m, &c, 1, 1, 64.0)
}

/// Drive a real [`EngineCore`] over the same all-at-once trace the
/// paged DES serves: request 0 alone in iteration 1 (mirroring the DES
/// arrival semantics — the first arrival starts an iteration before
/// the rest enqueue), everything else from iteration 2. Returns
/// (per-request finish tick, recompute preemptions, swap counts).
fn drive_engine(
    trace: &[SimRequest],
    cfg: EngineConfig,
    tracer: Option<cascadia::obs::EngineTracer>,
) -> (Vec<usize>, u64, (u64, u64, u64)) {
    let mut eng: EngineCore<usize> = EngineCore::new(Box::new(PinStep), cfg);
    eng.set_tracer(tracer);
    let mut finish = vec![0usize; trace.len()];
    let prompt_of = |r: &SimRequest| -> Vec<i32> { vec![7; r.input_tokens.max(1) as usize] };
    eng.submit(0, prompt_of(&trace[0]), trace[0].output_tokens.max(1) as usize);
    let mut tick = 0usize;
    let mut first = true;
    while !eng.is_idle() {
        tick += 1;
        assert!(tick < 10_000, "engine failed to drain the pin trace");
        let out = eng.step().expect("deterministic backend cannot fail");
        for f in out.completed {
            finish[f.payload] = tick;
        }
        if first {
            // The remaining arrivals land during iteration 1, visible
            // to the scheduler from iteration 2 on — exactly when the
            // DES's queued arrivals are.
            for (i, r) in trace.iter().enumerate().skip(1) {
                eng.submit(i, prompt_of(r), r.output_tokens.max(1) as usize);
            }
            first = false;
        }
    }
    (finish, eng.preemptions(), eng.swap_counts())
}

#[test]
fn paged_des_and_live_engine_agree_tick_for_tick_under_both_policies() {
    // The paged DES drives the engine's own IterationScheduler; a real
    // EngineCore over a deterministic StepBackend must therefore make
    // IDENTICAL decisions: same per-request finish ticks, same
    // preemption counts, same swap counts — for the recompute-only
    // discipline AND the swap-enabled one.
    let rm = tiny_pool_replica(40);
    assert!((39..=41).contains(&rm.kv_pages_total(16)));
    assert!(rm.max_batch >= 8, "slots must not bind before pages");
    let trace: Vec<SimRequest> = (0..8).map(|_| SimRequest::new(0.0, 193, 40)).collect();
    for mode in [PreemptionMode::Recompute, PreemptionMode::Swap] {
        let des = simulate_mode(
            &[rm.clone()],
            &trace,
            DesMode::Paged {
                page_tokens: 16,
                prefill_chunk: usize::MAX,
                swap: mode == PreemptionMode::Swap,
                spec: None,
            },
        );
        let cfg = EngineConfig {
            pool_pages: rm.kv_pages_total(16),
            page_tokens: 16,
            max_running: rm.max_batch.max(1),
            prefill_chunk: usize::MAX,
            share_prefixes: false,
            preemption: match mode {
                PreemptionMode::Recompute => PreemptionConfig::default(),
                PreemptionMode::Swap => PreemptionConfig::from_replica(&rm, 16, mode),
            },
        };
        let (finish, preemptions, (outs, ins, _pages)) = drive_engine(&trace, cfg, None);
        assert_eq!(
            finish, des.finish_iters,
            "{mode:?}: engine and DES must finish every request on the same tick"
        );
        assert_eq!(
            preemptions as usize, des.preemptions,
            "{mode:?}: preemption counts must match exactly"
        );
        assert_eq!(outs as usize, des.swap_outs, "{mode:?}: swap-out counts");
        assert_eq!(ins as usize, des.swap_ins, "{mode:?}: swap-in counts");
        match mode {
            PreemptionMode::Recompute => {
                assert!(des.preemptions > 0, "the tiny pool must preempt");
                assert_eq!(des.swap_outs, 0);
            }
            PreemptionMode::Swap => {
                assert!(des.swap_outs > 0, "the tiny pool must swap");
                assert_eq!(des.swap_outs, des.swap_ins);
                assert_eq!(des.preemptions, 0, "ample host budget: no fallback");
            }
        }
    }
}

#[test]
fn paged_des_and_live_engine_emit_identical_event_timelines() {
    // The schema pin behind `cascadia trace --diff`: the paged DES and
    // a real EngineCore over the same trace must emit IDENTICAL
    // per-request event sequences (signatures — kind + integer
    // payloads; timestamps legitimately differ between the simulated
    // and wall clocks). Runs under both eviction disciplines so
    // preempt/swap events are pinned too.
    use std::sync::Arc;

    use cascadia::obs::{diff_timelines, EngineTracer, EventKind, TraceRecorder};
    use cascadia::sim::simulate_paged_traced;

    let rm = tiny_pool_replica(40);
    let trace: Vec<SimRequest> = (0..8).map(|_| SimRequest::new(0.0, 193, 40)).collect();
    for mode in [PreemptionMode::Recompute, PreemptionMode::Swap] {
        let des_rec = TraceRecorder::new(1, 1 << 16);
        let des = simulate_paged_traced(
            &[rm.clone()],
            &trace,
            16,
            usize::MAX,
            mode == PreemptionMode::Swap,
            &des_rec,
        );
        let cfg = EngineConfig {
            pool_pages: rm.kv_pages_total(16),
            page_tokens: 16,
            max_running: rm.max_batch.max(1),
            prefill_chunk: usize::MAX,
            share_prefixes: false,
            preemption: match mode {
                PreemptionMode::Recompute => PreemptionConfig::default(),
                PreemptionMode::Swap => PreemptionConfig::from_replica(&rm, 16, mode),
            },
        };
        let live_rec = Arc::new(TraceRecorder::new(1, 1 << 16));
        let _ = drive_engine(
            &trace,
            cfg,
            Some(EngineTracer::standalone(Arc::clone(&live_rec))),
        );
        let left = des_rec.snapshot();
        let right = live_rec.snapshot();
        assert!(!left.is_empty() && !right.is_empty());
        let report = diff_timelines(&left, &right);
        assert!(
            report.is_equivalent(),
            "{mode:?}: DES and live timelines diverge: {:?} (only_left {:?}, only_right {:?})",
            report.first_divergence().map(|d| d.to_string()),
            report.only_left,
            report.only_right,
        );
        assert_eq!(report.requests_compared, trace.len());
        // Both sides saw real eviction traffic, not just the happy path.
        let has = |evs: &[cascadia::obs::Event], k: EventKind| evs.iter().any(|e| e.kind == k);
        match mode {
            PreemptionMode::Recompute => {
                assert!(des.preemptions > 0 && has(&left, EventKind::Preempt));
                assert!(has(&right, EventKind::Preempt));
            }
            PreemptionMode::Swap => {
                assert!(des.swap_outs > 0 && has(&left, EventKind::SwapOut));
                assert!(has(&right, EventKind::SwapOut) && has(&right, EventKind::SwapIn));
            }
        }
    }
}

/// Draft/verify extension of [`PinStep`] for the speculative
/// equivalence pin. Tokens stay the constant `seq` stream; draft
/// agreement is the shared pure function [`draft_agrees`] probed at
/// the CUMULATIVE emitted-token position — deliberately NOT reset on
/// `release`, because the paged DES's position counter (`gen_count`)
/// keeps counting across recompute preemption, and the
/// accepted/rejected pin requires both sides to probe identical
/// positions.
struct SpecPinStep {
    agree_mod: u64,
    emitted: BTreeMap<SeqId, usize>,
}

impl StepBackend for SpecPinStep {
    fn prefill_chunk(&mut self, seq: SeqId, _chunk: &[i32], last: bool) -> Result<Option<i32>> {
        if last {
            *self.emitted.entry(seq).or_insert(0) += 1;
            return Ok(Some(seq as i32));
        }
        Ok(None)
    }
    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
        for &s in seqs {
            *self.emitted.entry(s).or_insert(0) += 1;
        }
        Ok(seqs.iter().map(|&s| s as i32).collect())
    }
    fn release(&mut self, _seq: SeqId) {
        // Keep the cumulative position counter (see struct doc).
    }
    fn draft(&mut self, seq: SeqId, k: usize) -> Result<Option<Vec<i32>>> {
        let base = self.emitted.get(&seq).copied().unwrap_or(0);
        let me = seq as i32;
        Ok(Some(
            (0..k)
                .map(|i| {
                    if draft_agrees(seq, base + i, self.agree_mod) {
                        me
                    } else {
                        -1 - me
                    }
                })
                .collect(),
        ))
    }
    fn verify(&mut self, seq: SeqId, draft: &[i32]) -> Result<Option<VerifyOutcome>> {
        let me = seq as i32;
        let accepted = draft.iter().take_while(|&&t| t == me).count();
        *self.emitted.entry(seq).or_insert(0) += accepted + 1;
        Ok(Some(VerifyOutcome { accepted, next: me }))
    }
}

impl TierBackend for SpecPinStep {
    fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        Ok(vec![0; max_new])
    }
    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        Some(self)
    }
}

/// [`drive_engine`] with cross-tier speculation on: a [`SpecPinStep`]
/// at draft depth `k`, returning additionally the engine's
/// accepted/rejected draft-token counters.
fn drive_engine_spec(
    trace: &[SimRequest],
    cfg: EngineConfig,
    k: usize,
    agree_mod: u64,
) -> (Vec<usize>, u64, (u64, u64, u64), (u64, u64)) {
    let backend = SpecPinStep {
        agree_mod,
        emitted: BTreeMap::new(),
    };
    let mut eng: EngineCore<usize> = EngineCore::new(Box::new(backend), cfg);
    eng.set_speculation(k);
    let mut finish = vec![0usize; trace.len()];
    let prompt_of = |r: &SimRequest| -> Vec<i32> { vec![7; r.input_tokens.max(1) as usize] };
    eng.submit(0, prompt_of(&trace[0]), trace[0].output_tokens.max(1) as usize);
    let mut tick = 0usize;
    let mut first = true;
    while !eng.is_idle() {
        tick += 1;
        assert!(tick < 10_000, "engine failed to drain the spec pin trace");
        let out = eng.step().expect("deterministic backend cannot fail");
        for f in out.completed {
            finish[f.payload] = tick;
        }
        if first {
            for (i, r) in trace.iter().enumerate().skip(1) {
                eng.submit(i, prompt_of(r), r.output_tokens.max(1) as usize);
            }
            first = false;
        }
    }
    (finish, eng.preemptions(), eng.swap_counts(), eng.spec_counts())
}

#[test]
fn paged_des_and_live_engine_agree_under_speculation() {
    // The speculative extension of the tick-for-tick pin: with
    // draft→verify speculation on, the paged DES and a real EngineCore
    // must still make IDENTICAL decisions — same per-request finish
    // ticks, same preemption and swap counts, and EXACTLY the same
    // accepted/rejected draft-token split, because both sides probe the
    // shared draft_agrees(sequence, position) function over identical
    // cumulative position streams. Runs under both eviction
    // disciplines and across always-/never-/mixed-agreement drafts so
    // rollback interacts with real eviction traffic.
    let rm = tiny_pool_replica(40);
    let trace: Vec<SimRequest> = (0..8).map(|_| SimRequest::new(0.0, 193, 40)).collect();
    for mode in [PreemptionMode::Recompute, PreemptionMode::Swap] {
        for agree_mod in [0u64, 1, 3] {
            let des = simulate_mode(
                &[rm.clone()],
                &trace,
                DesMode::Paged {
                    page_tokens: 16,
                    prefill_chunk: usize::MAX,
                    swap: mode == PreemptionMode::Swap,
                    spec: Some(SpecSim {
                        draft_k: 3,
                        agree_mod,
                        draft_us_per_token: 40,
                    }),
                },
            );
            let cfg = EngineConfig {
                pool_pages: rm.kv_pages_total(16),
                page_tokens: 16,
                max_running: rm.max_batch.max(1),
                prefill_chunk: usize::MAX,
                share_prefixes: false,
                preemption: match mode {
                    PreemptionMode::Recompute => PreemptionConfig::default(),
                    PreemptionMode::Swap => PreemptionConfig::from_replica(&rm, 16, mode),
                },
            };
            let (finish, preemptions, (outs, ins, _pages), (acc, rej)) =
                drive_engine_spec(&trace, cfg, 3, agree_mod);
            assert_eq!(
                finish, des.finish_iters,
                "{mode:?}/mod {agree_mod}: engine and DES must finish every request on the same tick"
            );
            assert_eq!(
                preemptions as usize, des.preemptions,
                "{mode:?}/mod {agree_mod}: preemption counts must match exactly"
            );
            assert_eq!(
                outs as usize, des.swap_outs,
                "{mode:?}/mod {agree_mod}: swap-out counts"
            );
            assert_eq!(
                ins as usize, des.swap_ins,
                "{mode:?}/mod {agree_mod}: swap-in counts"
            );
            assert_eq!(
                (acc as usize, rej as usize),
                (des.spec_accepted, des.spec_rejected),
                "{mode:?}/mod {agree_mod}: accepted/rejected draft-token counts must match exactly"
            );
            match agree_mod {
                0 => {
                    assert!(acc > 0, "always-agreeing drafts must accept");
                    assert_eq!(rej, 0, "always-agreeing drafts never reject");
                }
                1 => {
                    assert_eq!(acc, 0, "never-agreeing drafts accept nothing");
                    assert!(rej > 0, "never-agreeing drafts must reject");
                }
                _ => {
                    assert!(acc > 0 && rej > 0, "mixed drafts split both ways");
                }
            }
        }
    }
}

#[test]
fn disagg_des_and_live_engines_agree_on_migrations_and_finish_ticks() {
    // The disaggregated DES and a live prefill/decode engine pair over
    // the same all-at-once trace must agree exactly: same handoff
    // count, same private pages over the interconnect, same per-request
    // decode-side finish ticks, exactly-once completion. The regime is
    // clock-free: whole-prompt prefills dwarf a decode iteration, so
    // the decoder is always drained when a handoff batch arrives — the
    // live loop asserts that instead of simulating time, and mirrors
    // the DES delivery rule (the first handoff of a batch wakes an idle
    // decoder immediately; the rest admit at its next iteration
    // boundary).
    let m = &llama_cascade()[0];
    let rm = ReplicaModel::new(m, &ClusterSpec::paper_testbed(), 1, 1, 256.0);
    assert!(rm.max_batch >= 8, "slots must not bind in this regime");
    assert!(rm.kv_pages_total(16) >= 8 * 14, "pages must not bind in this regime");
    let trace: Vec<SimRequest> = (0..8).map(|_| SimRequest::new(0.0, 193, 2)).collect();

    let des = simulate_disagg(&[rm.clone()], &[rm.clone()], &trace, 16, usize::MAX, false);
    assert_eq!(des.migrations, trace.len(), "every request must hand off once");
    assert!(des.migrate_pages > 0);
    assert_eq!(des.ttfts.len(), trace.len());
    assert!(des.ttfts.iter().all(|t| t.is_finite()));

    let cfg = EngineConfig {
        pool_pages: rm.kv_pages_total(16),
        page_tokens: 16,
        max_running: rm.max_batch.max(1),
        prefill_chunk: usize::MAX,
        share_prefixes: false,
        preemption: PreemptionConfig::default(),
    };
    let mut pf: EngineCore<usize> = EngineCore::new(Box::new(PinStep), cfg.clone());
    pf.set_role(EngineRole::Prefill); // opens migration
    let mut dc: EngineCore<usize> = EngineCore::new(Box::new(PinStep), cfg);
    dc.set_role(EngineRole::Decode);

    let prompt_of = |r: &SimRequest| -> Vec<i32> { vec![7; r.input_tokens.max(1) as usize] };
    let mut finish = vec![0usize; trace.len()];
    let mut decode_iters = 0usize;
    let record = |finish: &mut Vec<usize>, f: cascadia::engine::Finished<usize>, it: usize| {
        assert_eq!(finish[f.payload], 0, "request {} completed twice", f.payload);
        finish[f.payload] = it;
    };
    pf.submit(0, prompt_of(&trace[0]), trace[0].output_tokens.max(1) as usize);
    let mut first = true;
    let mut tick = 0usize;
    while !pf.is_idle() {
        tick += 1;
        assert!(tick < 1000, "prefill engine failed to drain the disagg trace");
        let out = pf.step().expect("deterministic backend cannot fail");
        assert!(out.completed.is_empty(), "prefill side must not retire requests");
        let mut handoffs = out.migrated_out.into_iter();
        if let Some(head) = handoffs.next() {
            assert!(dc.is_idle(), "regime: the decoder drains between deliveries");
            dc.submit_migrated(head);
            decode_iters += 1;
            let o = dc.step().expect("deterministic backend cannot fail");
            for f in o.completed {
                record(&mut finish, f, decode_iters);
            }
            for mseq in handoffs {
                dc.submit_migrated(mseq);
            }
            while !dc.is_idle() {
                decode_iters += 1;
                let o = dc.step().expect("deterministic backend cannot fail");
                assert!(o.migrated_out.is_empty(), "decode side must not re-migrate");
                for f in o.completed {
                    record(&mut finish, f, decode_iters);
                }
            }
        }
        if first {
            for (i, r) in trace.iter().enumerate().skip(1) {
                pf.submit(i, prompt_of(r), r.output_tokens.max(1) as usize);
            }
            first = false;
        }
    }
    assert!(dc.is_idle());
    assert!(finish.iter().all(|&t| t > 0), "a request never completed: {finish:?}");
    assert_eq!(
        finish, des.finish_iters,
        "live decode ticks must match the DES tick-for-tick"
    );

    let (pf_out, pf_in, pf_pages_out, pf_pages_in) = pf.migrate_counts();
    let (dc_out, dc_in, dc_pages_out, dc_pages_in) = dc.migrate_counts();
    assert_eq!(pf_out as usize, trace.len());
    assert_eq!((pf_in, pf_pages_in), (0, 0));
    assert_eq!((dc_out, dc_pages_out), (0, 0));
    assert_eq!(dc_in as usize, des.migrations, "handoff counts must match the DES");
    assert_eq!(
        dc_pages_in as usize, des.migrate_pages,
        "interconnect page traffic must match the DES"
    );
    assert_eq!(pf_pages_out, dc_pages_in, "every page sent must land exactly once");
}

#[test]
fn paged_des_matches_continuous_des_when_pages_never_bind() {
    // Light load on an amply provisioned replica: page-granular
    // admission must reproduce the legacy request-count simulator's
    // timeline (same admissions, same iteration costs).
    let m = &llama_cascade()[0];
    let rm = ReplicaModel::new(m, &ClusterSpec::paper_testbed(), 2, 1, 768.0);
    let trace: Vec<SimRequest> = (0..60)
        .map(|i| SimRequest::new(i as f64 * 0.4, 512, 64))
        .collect();
    let cont = simulate_mode(&[rm.clone()], &trace, DesMode::Continuous);
    let paged = simulate_mode(
        &[rm.clone()],
        &trace,
        DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
    );
    assert_eq!(cont.latencies.len(), paged.latencies.len());
    let rel = (paged.p95() - cont.p95()).abs() / cont.p95().max(1e-12);
    assert!(rel < 1e-6, "paged p95 {} vs continuous {}", paged.p95(), cont.p95());
    assert_eq!(paged.preemptions, 0);
    assert!(paged.peak_pages > 0);
    assert!(paged.peak_pages <= rm.kv_pages_total(16));
}
