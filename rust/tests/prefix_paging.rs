//! Integration tests of prefix-sharing paged KV and chunked prefill:
//! refcount-leak accounting after full drains, CoW divergence
//! determinism, the chunked-vs-whole DES pin, and routing invariance
//! of the prefix fast path on the public serving API.

use anyhow::Result;
use cascadia::cluster::ClusterSpec;
use cascadia::coordinator::server::{
    CascadeServer, ResponseJudger, ServerConfig, TierBackend,
};
use cascadia::engine::{
    prompt_page_hashes, EngineConfig, EngineCore, IterationScheduler, KvPool, SeqId,
    StepBackend,
};
use cascadia::models::llama_cascade;
use cascadia::perf::ReplicaModel;
use cascadia::sim::{simulate_mode, DesMode, SimRequest};

/// Minimal native step backend: deterministic tokens, no state.
struct Stepper;

impl StepBackend for Stepper {
    fn prefill_chunk(&mut self, seq: SeqId, _chunk: &[i32], last: bool) -> Result<Option<i32>> {
        Ok(last.then_some(1000 + seq as i32))
    }
    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
        Ok(seqs.iter().map(|&s| 1000 + s as i32).collect())
    }
    fn release(&mut self, _seq: SeqId) {}
}

impl TierBackend for Stepper {
    fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        Ok(vec![0; max_new])
    }
    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        Some(self)
    }
}

fn shared_prompt(group: i32, tail_seed: i32, len: usize, shared: usize) -> Vec<i32> {
    let mut p: Vec<i32> = (0..shared as i32).map(|j| group * 1000 + j).collect();
    p.extend((shared as i32..len as i32).map(|j| tail_seed * 7919 + j));
    p
}

#[test]
fn refcount_leak_free_after_draining_any_trace() {
    // A tight pool serving overlapping shared-prefix sequences with
    // preemptions and mid-prefill restarts: after everything retires,
    // the free-page count returns to the initial value, nothing is in
    // use, and the prefix trie is empty.
    let pool = KvPool::new(24, 16);
    let initial_free = pool.free_pages();
    let mut s = IterationScheduler::new(pool, 8);
    s.set_prefill_chunk(32);
    for i in 0..10u64 {
        // Half the sequences share one 64-token prefix; tails differ.
        let prompt = shared_prompt(1, i as i32, 96, if i % 2 == 0 { 64 } else { 0 });
        s.enqueue_shared(i, prompt.len(), 12, prompt_page_hashes(&prompt, 16));
    }
    let mut iters = 0;
    while !s.is_idle() {
        iters += 1;
        assert!(iters < 2_000, "scheduler failed to drain");
        let plan = s.next_iteration();
        assert!(plan.batch() > 0);
        for id in plan.producers() {
            if s.advance(id) {
                s.retire(id);
            }
        }
    }
    assert!(s.preemptions() > 0, "the tight pool must exercise preemption");
    assert_eq!(s.pool().in_use(), 0, "refcount leak: pages still live");
    assert_eq!(s.pool().trie_len(), 0, "trie leak: entries outlived their pages");
    assert_eq!(s.pool().free_pages(), initial_free, "free list must return to initial");
    let (allocs, frees) = s.pool().alloc_counts();
    assert_eq!(allocs, frees, "every allocated page must be freed");
}

#[test]
fn engine_drain_leaves_no_shared_residue() {
    // Worker-death path: drain() mid-flight with shared pages claimed
    // must free everything, trie included.
    let cfg = EngineConfig {
        pool_pages: 64,
        page_tokens: 16,
        max_running: 8,
        prefill_chunk: usize::MAX,
        share_prefixes: true,
        preemption: cascadia::engine::PreemptionConfig::default(),
    };
    let mut e: EngineCore<usize> = EngineCore::new(Box::new(Stepper), cfg);
    let free0 = e.kv_free_pages();
    let prompt = shared_prompt(2, 0, 64, 64);
    e.submit(0, prompt.clone(), 16);
    let _ = e.step().unwrap();
    let _ = e.step().unwrap(); // publish tick
    e.submit(1, prompt.clone(), 16);
    e.submit(2, prompt, 16);
    let _ = e.step().unwrap(); // claims land
    assert!(e.kv_trie_len() > 0, "pages must be published");
    let drained = e.drain();
    assert_eq!(drained.len(), 3);
    assert_eq!(e.kv_in_use(), 0);
    assert_eq!(e.kv_trie_len(), 0);
    assert_eq!(e.kv_free_pages(), free0);
}

#[test]
fn cow_divergence_is_deterministic() {
    // Two sequences share an identical 40-token prompt (partial tail
    // page): the claimer must CoW on its first decode token. Repeating
    // the run must reproduce identical outputs and identical sharing
    // counters — divergence is deterministic, not timing-dependent.
    let run = || {
        let cfg = EngineConfig {
            pool_pages: 32,
            page_tokens: 16,
            max_running: 8,
            prefill_chunk: usize::MAX,
            share_prefixes: true,
            preemption: cascadia::engine::PreemptionConfig::default(),
        };
        let mut e: EngineCore<usize> = EngineCore::new(Box::new(Stepper), cfg);
        let prompt = shared_prompt(3, 0, 40, 40);
        e.submit(0, prompt.clone(), 6);
        let _ = e.step().unwrap();
        let _ = e.step().unwrap(); // publish
        e.submit(1, prompt, 6);
        let mut outputs = Vec::new();
        let mut steps = 0;
        while !e.is_idle() {
            steps += 1;
            assert!(steps < 64);
            for f in e.step().unwrap().completed {
                outputs.push((f.payload, f.output));
            }
        }
        outputs.sort();
        let (claims, cows) = e.sharing_counts();
        (outputs, claims, cows, e.prefix_hit_tokens(), e.peak_pages())
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "CoW divergence must be bit-deterministic");
    let (outputs, claims, cows, hits, _) = a;
    assert_eq!(outputs.len(), 2);
    assert_eq!(outputs[0].1.len(), 6);
    assert_eq!(outputs[1].1.len(), 6);
    assert!(claims >= 3, "the identical prompt claims its 3 pages");
    assert_eq!(cows, 1, "exactly one divergence copy for the partial tail page");
    assert_eq!(hits, 40, "the full prompt rides shared pages");
}

#[test]
fn des_pins_chunked_prefill_to_whole_plus_interleave() {
    // Single long-prompt request, public API: chunked latency must be
    // the whole-prefill latency plus one interleaved decode iteration
    // per extra chunk — nothing more, nothing less.
    let m = &llama_cascade()[0];
    let rm = ReplicaModel::new(m, &ClusterSpec::paper_testbed(), 2, 1, 768.0);
    let trace = vec![SimRequest::new(0.0, 1536, 16)];
    let whole = simulate_mode(
        &[rm.clone()],
        &trace,
        DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
    );
    let chunked = simulate_mode(
        &[rm.clone()],
        &trace,
        DesMode::Paged { page_tokens: 16, prefill_chunk: 256, swap: false, spec: None },
    );
    let iter1 = rm.decode_iteration(1) / rm.pp_capacity_factor;
    let extra_chunks = (1536f64 / 256.0).ceil() - 1.0;
    let diff = chunked.latencies[0] - whole.latencies[0];
    assert!(
        (diff - extra_chunks * iter1).abs() < 1e-9,
        "chunk interleave cost {diff} != {extra_chunks} x {iter1}"
    );
}

/// Native step backend emitting its tier number — routing outcomes are
/// decided by the judger off the request id in the prompt's last slot
/// (the shared prefix must stay byte-identical across requests).
struct TierStepper {
    tier: i32,
}

impl StepBackend for TierStepper {
    fn prefill_chunk(&mut self, _seq: SeqId, _chunk: &[i32], last: bool) -> Result<Option<i32>> {
        Ok(last.then_some(self.tier))
    }
    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
        Ok(vec![self.tier; seqs.len()])
    }
    fn release(&mut self, _seq: SeqId) {}
}

impl TierBackend for TierStepper {
    fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        Ok(vec![self.tier; max_new])
    }
    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        Some(self)
    }
}

/// Request `id` (prompt's last token) is answerable from tier `id % 3`
/// upward; the output's first token carries the serving tier.
struct ByIdJudger;

impl ResponseJudger for ByIdJudger {
    fn score(&self, prompt: &[i32], output: &[i32]) -> f64 {
        let id = prompt.last().copied().unwrap_or(0);
        let tier = output.first().copied().unwrap_or(0);
        if tier >= id % 3 {
            90.0
        } else {
            10.0
        }
    }
}

#[test]
fn prefix_sharing_does_not_change_routing_outcomes() {
    // Identical trace of shared-prefix prompts served with the trie
    // off and on: per-request accepting tiers must match exactly, and
    // the shared run must claim pages. (Escalations carry their prompt
    // hashes, so deeper-tier re-serves share across escalated
    // requests.)
    let factory = |tier: usize| -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(TierStepper { tier: tier as i32 }))
    };
    // One shared 16-token page + a unique id slot in the tail page.
    let trace: Vec<(f64, Vec<i32>)> = (0..24)
        .map(|i| {
            let mut p = shared_prompt(5, 0, 16, 16);
            p.push(i);
            (0.0, p)
        })
        .collect();
    let engines = |share: bool| {
        vec![
            EngineConfig {
                pool_pages: 256,
                page_tokens: 16,
                max_running: 8,
                prefill_chunk: usize::MAX,
                share_prefixes: share,
                preemption: cascadia::engine::PreemptionConfig::default(),
            };
            3
        ]
    };
    let base =
        ServerConfig::with_thresholds(vec![2, 1, 1], vec![6, 4, 2], vec![50.0, 50.0], 4)
            .unwrap();
    let off = CascadeServer::new(base.clone().continuous(engines(false)))
        .unwrap()
        .serve(&trace, &factory, &ByIdJudger)
        .unwrap();
    let on = CascadeServer::new(base.continuous(engines(true)))
        .unwrap()
        .serve(&trace, &factory, &ByIdJudger)
        .unwrap();
    assert_eq!(off.completions.len(), 24);
    assert_eq!(on.completions.len(), 24);
    let tiers = |s: &cascadia::coordinator::server::ServerStats| {
        let mut v = vec![usize::MAX; 24];
        for c in &s.completions {
            v[c.id] = c.accepting_tier;
        }
        v
    };
    let expect: Vec<usize> = (0..24).map(|i| (i % 3) as usize).collect();
    assert_eq!(tiers(&off), expect, "judger must route by id");
    assert_eq!(tiers(&off), tiers(&on), "sharing must not change routing");
    assert_eq!(off.per_tier_processed, on.per_tier_processed);
    let hits: usize = on.engine.iter().map(|e| e.prefix_hit_tokens).sum();
    assert!(hits > 0, "overlapping shared prompts must hit the trie");
    let off_hits: usize = off.engine.iter().map(|e| e.prefix_hit_tokens).sum();
    assert_eq!(off_hits, 0);
}
