//! Property-based soak of the paged KV pool + iteration scheduler
//! under the full op mix the swap-to-host policy added: seeded random
//! schedules of admit / grow / chunked prefill / preempt-recompute /
//! swap-out / swap-in / cancel / retire interleaved with shared-prefix
//! claims and CoW, with the pool's full-state invariants checked after
//! every tick ([`KvPool::validate`]: refcount/table consistency, free
//! list closure, trie liveness, shared⇒published, swap space within
//! budget) and leak-freedom asserted on drain.

use std::collections::{HashMap, HashSet};

use cascadia::engine::{
    prompt_page_hashes, IterationScheduler, KvPool, PreemptionConfig, PreemptionMode,
    SeqId,
};
use cascadia::util::prop::{check_n, Gen};

/// One randomized soak trial: build a scheduler with a random pool /
/// chunk budget / preemption policy, drive a random interleaving of
/// enqueues, ticks, and cancels, then drain and check for leaks.
fn soak_trial(g: &mut Gen) -> Result<(), String> {
    let page_tokens = *g.choose(&[8usize, 16]);
    let pool_pages = g.sized(6, 48).max(6);
    let max_running = g.sized(2, 12).max(2);
    let mut s =
        IterationScheduler::new(KvPool::new(pool_pages, page_tokens), max_running);
    if g.bool() {
        s.set_prefill_chunk(g.sized(1, 4).max(1) * page_tokens);
    }
    let swap_mode = g.bool();
    let swap_budget = if swap_mode { g.sized(0, 64) } else { 0 };
    s.set_preemption(PreemptionConfig {
        mode: if swap_mode { PreemptionMode::Swap } else { PreemptionMode::Recompute },
        swap_pages: swap_budget,
        // Random cost rates flip the per-victim choice trial to trial
        // (zero rates = always swap while budget remains).
        prefill_s_per_token: if g.bool() { 0.0 } else { g.f64(1e-6, 1e-3) },
        swap_s_per_page: if g.bool() { 0.0 } else { g.f64(1e-6, 1e-2) },
        page_bytes: 0.0,
    });

    // A few shared prompt groups so claims/CoW/publishing happen.
    let groups: Vec<Vec<i32>> = (0..3)
        .map(|k| (0..96).map(|j| (k * 1000 + j) as i32).collect())
        .collect();

    let mut next_id: SeqId = 0;
    let mut live: HashSet<SeqId> = HashSet::new();
    let mut done: HashSet<SeqId> = HashSet::new();

    let ops = g.sized(20, 160).max(20);
    for _ in 0..ops {
        let roll = g.int(0, 9);
        if roll <= 2 && live.len() < 32 {
            // Enqueue, sometimes with a shared-prefix hash chain.
            let id = next_id;
            next_id += 1;
            let prompt_tokens = g.sized(4, 90).max(4);
            let max_new = g.sized(1, 24).max(1);
            if g.bool() {
                let grp = g.choose(&groups).clone();
                let prompt: Vec<i32> =
                    grp.iter().copied().cycle().take(prompt_tokens).collect();
                s.enqueue_shared(
                    id,
                    prompt_tokens,
                    max_new,
                    prompt_page_hashes(&prompt, page_tokens),
                );
            } else {
                s.enqueue(id, prompt_tokens, max_new);
            }
            live.insert(id);
        } else if roll == 3 && !live.is_empty() {
            // Cancel a random tracked sequence — running, waiting, or
            // parked in swap space alike must release cleanly.
            let ids: Vec<SeqId> = live.iter().copied().collect();
            let id = *g.choose(&ids);
            s.retire(id);
            live.remove(&id);
        } else if roll == 4 {
            // Live pool retarget (the hot-swap lever), both directions.
            s.resize_pool(g.sized(4, 64).max(4));
        } else {
            // One engine tick.
            let plan = s.next_iteration();
            // Plan-level sanity: producers are tracked and unique.
            let producers = plan.producers();
            let mut seen = HashSet::new();
            for &id in &producers {
                if !live.contains(&id) {
                    return Err(format!("producer {id} is not a live sequence"));
                }
                if !seen.insert(id) {
                    return Err(format!("sequence {id} produced twice in one tick"));
                }
            }
            for id in producers {
                if s.advance(id) {
                    s.retire(id);
                    live.remove(&id);
                    done.insert(id);
                }
            }
        }
        // Full-state invariants after EVERY op.
        s.pool().validate().map_err(|e| format!("pool invariant: {e}"))?;
        if s.pool().swapped_pages() > swap_budget {
            return Err(format!(
                "swap space over budget: {} > {swap_budget}",
                s.pool().swapped_pages()
            ));
        }
        if !swap_mode && s.n_swapped() > 0 {
            return Err("recompute mode must never park sequences".into());
        }
        if s.n_seqs() != live.len() {
            return Err(format!(
                "scheduler tracks {} sequences but {} are live",
                s.n_seqs(),
                live.len()
            ));
        }
    }

    // Drain everything still in flight: exactly-once, no orphans, no
    // leaked pages or swap space, trie empty, free list restored.
    let drained = s.drain_ids();
    let drained_set: HashSet<SeqId> = drained.iter().copied().collect();
    if drained.len() != drained_set.len() {
        return Err("drain returned duplicates".into());
    }
    if drained_set != live {
        return Err(format!(
            "drain returned {} ids but {} were live",
            drained_set.len(),
            live.len()
        ));
    }
    for id in &drained_set {
        if done.contains(id) {
            return Err(format!("sequence {id} completed AND drained"));
        }
    }
    if !s.is_idle() {
        return Err("scheduler not idle after drain".into());
    }
    s.pool().validate().map_err(|e| format!("post-drain invariant: {e}"))?;
    if s.pool().in_use() != 0 {
        return Err(format!("page leak on drain: {} in use", s.pool().in_use()));
    }
    if s.pool().swapped_pages() != 0 || s.pool().swapped_seqs() != 0 {
        return Err("swap-space leak on drain".into());
    }
    if s.pool().trie_len() != 0 {
        return Err("trie leak on drain".into());
    }
    // The free list returns to the CURRENT capacity (resizes included):
    // device pages held + swapped pages + free list close the books.
    if s.pool().free_pages() != s.pool().capacity() {
        return Err(format!(
            "free list {} != capacity {} after drain",
            s.pool().free_pages(),
            s.pool().capacity()
        ));
    }
    Ok(())
}

#[test]
fn soak_randomized_schedules_hold_every_pool_invariant() {
    check_n("kv+scheduler swap soak", 60, soak_trial);
}

/// Deterministic long-run churn: a tight pool, swap enabled, shared
/// prefixes, cancels mid-flight — every sequence completes or drains
/// exactly once and the checkpoint audit holds (swapped sequences
/// never re-produce a token).
#[test]
fn tight_pool_swap_churn_is_exactly_once_and_checkpointed() {
    let mut s = IterationScheduler::new(KvPool::new(10, 16), 8);
    s.set_prefill_chunk(32);
    s.set_preemption(PreemptionConfig {
        mode: PreemptionMode::Swap,
        swap_pages: 256,
        prefill_s_per_token: 0.0,
        swap_s_per_page: 0.0,
        page_bytes: 0.0,
    });
    let shared: Vec<i32> = (0..64).collect();
    let mut produced: HashMap<SeqId, usize> = HashMap::new();
    let mut budgets: HashMap<SeqId, usize> = HashMap::new();
    for id in 0..24u64 {
        let len = 40 + (id as usize % 3) * 17;
        let max_new = 6 + (id as usize % 5) * 4;
        let prompt: Vec<i32> = shared.iter().copied().cycle().take(len).collect();
        if id % 2 == 0 {
            s.enqueue_shared(id, len, max_new, prompt_page_hashes(&prompt, 16));
        } else {
            s.enqueue(id, len, max_new);
        }
        budgets.insert(id, max_new);
    }
    let mut completed: Vec<SeqId> = Vec::new();
    let mut iters = 0;
    while !s.is_idle() {
        iters += 1;
        assert!(iters < 20_000, "churn must terminate");
        let plan = s.next_iteration();
        assert!(plan.preempted.is_empty(), "ample host budget: swap only");
        for id in plan.producers() {
            *produced.entry(id).or_insert(0) += 1;
            if s.advance(id) {
                s.retire(id);
                completed.push(id);
            }
        }
        s.pool().validate().unwrap();
    }
    assert_eq!(completed.len(), 24, "every sequence completes exactly once");
    let unique: HashSet<SeqId> = completed.iter().copied().collect();
    assert_eq!(unique.len(), 24);
    for (id, n) in produced {
        assert_eq!(
            n, budgets[&id],
            "seq {id}: {n} tokens produced for a {} budget — swap must checkpoint",
            budgets[&id]
        );
    }
    let (outs, ins, _) = s.swap_counts();
    assert!(outs > 0, "the tight pool must swap");
    assert_eq!(outs, ins);
    assert_eq!(s.pool().in_use(), 0);
    assert_eq!(s.pool().swapped_pages(), 0);
    assert_eq!(s.pool().trie_len(), 0);
    s.pool().validate().unwrap();
}
