//! Integration test: the full live serving path over real PJRT
//! artifacts (skipped when `make artifacts` has not run).
//!
//! This is the three-layer proof: Rust coordinator -> threshold router
//! -> continuous batcher -> compiled JAX+Pallas HLO on PJRT CPU -> real
//! task-rule judger -> escalation.

#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use cascadia::coordinator::server::{CascadeServer, ServerConfig};
use cascadia::runtime::{pjrt_factory, Manifest, TaskJudger};
use cascadia::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("CASCADIA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {}", dir.display());
        None
    }
}

fn make_prompt(rng: &mut Rng, m: usize, marker_base: usize, vocab: usize) -> Vec<i32> {
    let mut p = vec![(marker_base + m) as i32];
    for _ in 0..m {
        p.push(rng.below(vocab as u64) as i32);
    }
    for _ in 0..3 {
        let n = p.len();
        let next: i64 =
            p[n - m..].iter().map(|&t| t as i64).sum::<i64>() % vocab as i64;
        p.push(next as i32);
    }
    p
}

/// The cascade routes by real difficulty: easy prompts are answered
/// correctly at tier 1, hard ones escalate and are answered correctly
/// at the large tier. Quality comes from the actual generated tokens.
#[test]
fn live_cascade_routes_by_real_difficulty() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let task = manifest.task.clone();

    let server = CascadeServer::new(
        ServerConfig::with_thresholds(vec![1, 1, 1], vec![4, 4, 4], vec![80.0, 80.0], 6)
            .unwrap(),
    )
    .unwrap();
    let judger = TaskJudger::new(task.clone(), 6);
    let factory = pjrt_factory(dir);

    let mut rng = Rng::new(11);
    // 6 easy (m=1) + 6 medium (m=2) + 4 hard (m=4).
    let mut trace = Vec::new();
    let mut difficulty = Vec::new();
    for &m in &[1usize, 1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 2, 4, 4, 4, 4] {
        difficulty.push(m);
        trace.push((0.0, make_prompt(&mut rng, m, task.marker_base, task.data_vocab)));
    }

    let stats = server.serve(&trace, &factory, &judger).unwrap();
    assert_eq!(stats.completions.len(), trace.len());

    let mean_tier = |m: usize| -> f64 {
        let v: Vec<f64> = stats
            .completions
            .iter()
            .filter(|c| difficulty[c.id] == m)
            .map(|c| c.accepting_tier as f64)
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    let mean_score = |m: usize| -> f64 {
        let v: Vec<f64> = stats
            .completions
            .iter()
            .filter(|c| difficulty[c.id] == m)
            .map(|c| c.score)
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };

    // Easy requests stay at the small tier and are answered well.
    assert!(mean_tier(1) < 0.5, "easy requests escalated: {}", mean_tier(1));
    assert!(mean_score(1) > 90.0, "easy score {}", mean_score(1));
    // Medium requests land at the medium tier on average.
    assert!(
        mean_tier(2) > 0.5 && mean_tier(2) < 1.8,
        "medium tier {}",
        mean_tier(2)
    );
    assert!(mean_score(2) > 80.0, "medium score {}", mean_score(2));
    // Hard requests reach the large tier.
    assert!(mean_tier(4) > 1.5, "hard tier {}", mean_tier(4));
    // Overall quality must beat what the small tier alone achieves on
    // this mix (tier-1-only would fail all m>=2 requests).
    assert!(stats.mean_quality() > 70.0, "quality {}", stats.mean_quality());
}

/// Single-tier serving (standalone baseline on the live path): the
/// small model alone is fast but wrong on hard prompts.
#[test]
fn live_standalone_small_tier_quality_gap() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let task = manifest.task.clone();
    let judger = TaskJudger::new(task.clone(), 6);
    let factory = pjrt_factory(dir);

    // All traffic pinned to tier 0 (thresholds 0 accept everything).
    let server = CascadeServer::new(
        ServerConfig::with_thresholds(vec![1, 1, 1], vec![4, 1, 1], vec![0.0, 0.0], 6)
            .unwrap(),
    )
    .unwrap();
    let mut rng = Rng::new(13);
    let trace: Vec<(f64, Vec<i32>)> = (0..8)
        .map(|i| {
            let m = if i % 2 == 0 { 1 } else { 3 };
            (0.0, make_prompt(&mut rng, m, task.marker_base, task.data_vocab))
        })
        .collect();
    let stats = server.serve(&trace, &factory, &judger).unwrap();
    // Everything accepted at tier 0...
    assert!(stats.completions.iter().all(|c| c.accepting_tier == 0));
    // ...but the hard half is mostly wrong, dragging quality down.
    assert!(
        stats.mean_quality() < 80.0,
        "small tier should fail hard prompts: {}",
        stats.mean_quality()
    );
}
