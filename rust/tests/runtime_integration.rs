//! Integration tests: the PJRT runtime on real AOT artifacts.
//!
//! These need `make artifacts` to have run. They look for the artifacts
//! directory in `CASCADIA_ARTIFACTS` (falling back to `artifacts/` in
//! the repo root) and skip silently when it is absent, so plain
//! `cargo test` works before the Python step.

#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use cascadia::runtime::{Manifest, TierRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("CASCADIA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {}", dir.display());
        None
    }
}

/// Greedy-decode a few tokens and check basic shape/consistency
/// invariants of the prefill/decode contract.
#[test]
fn prefill_then_decode_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let (name, tier) = manifest.tiers.iter().next().unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    let rt = TierRuntime::load(&client, &dir, tier).unwrap();
    let cfg = &rt.manifest.config;
    assert_eq!(name, &cfg.name);

    // Prompt: difficulty-1 marker + two seed tokens.
    let marker = (manifest.task.marker_base + 1) as i32;
    let prompt = vec![marker, 5, 17];
    let true_len = prompt.len();

    let pre = rt.prefill(&prompt).unwrap();
    assert_eq!(pre.logits.len(), cfg.vocab);
    assert!(pre.logits.iter().all(|x| x.is_finite()));

    // Greedy decode 4 tokens, threading the KV cache functionally.
    let mut mask = vec![0f32; cfg.max_seq];
    for m in mask.iter_mut().take(true_len) {
        *m = 1.0;
    }
    let mut k = pre.k_cache;
    let mut v = pre.v_cache;
    let mut logits = pre.logits;
    for i in 0..4 {
        let token = argmax(&logits) as i32;
        let slot = cfg.prefill_len + i;
        mask[slot] = 1.0;
        let (l, k2, v2) = rt
            .decode(token, slot, true_len + i, &mask, &k, &v)
            .unwrap();
        assert_eq!(l.len(), cfg.vocab);
        assert!(l.iter().all(|x| x.is_finite()));
        logits = l;
        k = k2;
        v = v2;
    }
}

/// The same prompt must produce identical logits across calls —
/// the runtime is deterministic and stateless between requests.
#[test]
fn prefill_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let tier = manifest.cascade_order()[0];
    let client = xla::PjRtClient::cpu().unwrap();
    let rt = TierRuntime::load(&client, &dir, tier).unwrap();
    let prompt = vec![60, 1, 2, 3];
    let a = rt.prefill(&prompt).unwrap();
    let b = rt.prefill(&prompt).unwrap();
    assert_eq!(a.logits, b.logits);
}

/// Out-of-range prompts are rejected cleanly, not UB or a PJRT crash.
#[test]
fn prompt_length_validation() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let tier = manifest.cascade_order()[0];
    let client = xla::PjRtClient::cpu().unwrap();
    let rt = TierRuntime::load(&client, &dir, tier).unwrap();
    assert!(rt.prefill(&[]).is_err());
    let too_long = vec![0i32; rt.manifest.config.prefill_len + 1];
    assert!(rt.prefill(&too_long).is_err());
}

/// A malformed HLO file surfaces as a clean error.
#[test]
fn malformed_hlo_is_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let tier = manifest.cascade_order()[0];
    let tmp = cascadia::util::testfs::TempDir::new("hlo").unwrap();
    // Copy manifest layout but corrupt the prefill HLO.
    std::fs::write(tmp.path().join(&tier.prefill_file), "not hlo at all").unwrap();
    std::fs::copy(dir.join(&tier.decode_file), tmp.path().join(&tier.decode_file)).unwrap();
    std::fs::copy(dir.join(&tier.params_file), tmp.path().join(&tier.params_file)).unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    let err = TierRuntime::load(&client, tmp.path(), tier);
    assert!(err.is_err());
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}
