//! Integration tests: the full bi-level scheduler + cascade simulation
//! across scenarios, plus the analytic-vs-DES calibration check.

use cascadia::cluster::ClusterSpec;
use cascadia::harness::{default_rate, Scenario};
use cascadia::models::{deepseek_cascade, llama_cascade};
use cascadia::perf::{ReplicaModel, Workload};
use cascadia::sched::outer::OuterOptions;
use cascadia::sim::analytic::{estimate_p95, pool_capacity};
use cascadia::sim::des::{simulate, SimRequest};
use cascadia::util::rng::Rng;

fn small_opts() -> OuterOptions {
    OuterOptions {
        threshold_grid: vec![0.0, 30.0, 60.0, 90.0],
        ..Default::default()
    }
}

/// Cascadia end-to-end on both cascades: plans exist, quality targets
/// are met on held-out traces, and the cascade beats the standalone
/// large model on p95 when the latter saturates.
#[test]
fn cascadia_beats_saturated_standalone() {
    let scenario = Scenario::new(deepseek_cascade(), 32, 1, default_rate(1), 900, 99);
    let plan = scenario.cascadia_plan(85.0, &small_opts()).unwrap();
    let cascadia = scenario.evaluate(&plan).unwrap();
    assert!(cascadia.quality >= 84.0, "quality {}", cascadia.quality);

    let standalone = scenario.standalone_plan(85.0).unwrap();
    let sa = scenario.evaluate(&standalone).unwrap();
    assert!(
        cascadia.p95() < sa.p95(),
        "cascade p95 {} not better than standalone {}",
        cascadia.p95(),
        sa.p95()
    );
}

#[test]
fn llama_cascade_schedules() {
    let scenario = Scenario::new(llama_cascade(), 32, 2, default_rate(2), 700, 101);
    let plan = scenario.cascadia_plan(75.0, &small_opts()).unwrap();
    assert_eq!(plan.total_gpus(), 32);
    let out = scenario.evaluate(&plan).unwrap();
    assert!(out.quality >= 74.0);
    assert!(out.p95().is_finite());
}

/// Smaller clusters (one server) still schedule — the memory floors
/// force tier-subset deployments.
#[test]
fn single_server_cluster() {
    let scenario = Scenario::new(llama_cascade(), 8, 3, 20.0, 500, 17);
    let plan = scenario.cascadia_plan(70.0, &small_opts()).unwrap();
    assert_eq!(plan.total_gpus(), 8);
    let out = scenario.evaluate(&plan).unwrap();
    assert!(out.quality >= 69.0);
}

/// Calibration: the analytic p95 estimate must track the DES across
/// load levels — same ordering and within a small factor at moderate
/// load (it feeds candidate *ranking*, the DES scores final plans).
#[test]
fn analytic_matches_des_ordering() {
    let m = &llama_cascade()[0];
    let cluster = ClusterSpec::paper_testbed();
    let pool: Vec<ReplicaModel> =
        (0..2).map(|_| ReplicaModel::new(m, &cluster, 2, 1, 768.0)).collect();
    let w0 = Workload { rate: 1.0, avg_input: 512.0, avg_output: 256.0 };
    let cap = pool_capacity(&pool, &w0);

    let mut prev_est = 0.0;
    let mut prev_sim = 0.0;
    for load in [0.3, 0.6, 0.85] {
        let w = Workload { rate: cap * load, ..w0 };
        let est = estimate_p95(&pool, &w);
        // DES with a Poisson trace at the same rate.
        let mut rng = Rng::new(5);
        let mut t = 0.0;
        let trace: Vec<SimRequest> = (0..1500)
            .map(|_| {
                t += rng.exp(w.rate);
                SimRequest::new(t, 512, 256)
            })
            .collect();
        let sim = simulate(&pool, &trace).p95();
        assert!(est > prev_est, "analytic not increasing with load");
        assert!(sim > prev_sim * 0.8, "sim wildly non-monotone");
        let ratio = est / sim;
        assert!(
            (0.2..5.0).contains(&ratio),
            "analytic {est} vs DES {sim} at load {load} (ratio {ratio})"
        );
        prev_est = est;
        prev_sim = sim;
    }
}

/// The ablations can only hurt: full Cascadia <= uniform-parallelism
/// and <= uniform-allocation on predicted latency for the same quality.
#[test]
fn ablations_never_help() {
    let scenario = Scenario::new(deepseek_cascade(), 32, 2, default_rate(2), 700, 23);
    let full = scenario.cascadia_plan(80.0, &small_opts()).unwrap();
    for tweak in [
        |o: &mut OuterOptions| o.inner.uniform_parallelism = true,
        |o: &mut OuterOptions| o.inner.uniform_allocation = true,
    ] {
        let mut opts = small_opts();
        tweak(&mut opts);
        if let Ok(ablated) = scenario.cascadia_plan(80.0, &opts) {
            assert!(
                full.predicted_latency <= ablated.predicted_latency + 1e-9,
                "ablation improved latency: {} < {}",
                ablated.predicted_latency,
                full.predicted_latency
            );
        }
    }
}

/// Re-scheduling responds to a workload shift with a different plan.
#[test]
fn rescheduling_changes_plan() {
    let cascade = deepseek_cascade();
    let easy = Scenario::new(cascade.clone(), 32, 3, default_rate(3), 700, 31);
    let hard = Scenario::new(cascade, 32, 1, default_rate(1), 700, 31);
    let p_easy = easy.cascadia_plan(80.0, &small_opts()).unwrap();
    let p_hard = hard.cascadia_plan(80.0, &small_opts()).unwrap();
    // The hard trace must escalate a larger share of requests past the
    // small tier (the resource split follows the load, but absolute
    // GPU counts also depend on rates, so the ratio is the robust
    // signal).
    assert!(
        p_hard.tiers[1].processing_ratio > p_easy.tiers[1].processing_ratio,
        "hard p2 {} vs easy p2 {}",
        p_hard.tiers[1].processing_ratio,
        p_easy.tiers[1].processing_ratio
    );
}
