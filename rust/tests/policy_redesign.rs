//! Regression + round-trip tests for the RoutingPolicy redesign.
//!
//! 1. `ThresholdPolicy` must reproduce the pre-redesign `route()`
//!    outputs *bit for bit* on the paper traces (the legacy algorithm
//!    is re-implemented inline here as the oracle).
//! 2. A scheduler plan must round-trip through `CascadePlan::to_json`
//!    → text → `CascadePlan::from_json` → `ServerConfig::from_plan` /
//!    `TcpFrontend::from_plan` for every policy family — the
//!    schedule→serve artifact flow of `cascadia schedule | serve`.

use cascadia::cluster::ClusterSpec;
use cascadia::coordinator::net::TcpFrontend;
use cascadia::coordinator::server::ServerConfig;
use cascadia::judge::Judger;
use cascadia::models::{deepseek_cascade, llama_cascade, ModelSpec};
use cascadia::router::{route_with, PolicyKind, RoutingPolicy, ThresholdPolicy};
use cascadia::sched::outer::{optimize, select_plan, OuterOptions};
use cascadia::sched::plan::CascadePlan;
use cascadia::workload::{generate, paper_trace, Request};

/// The seed repository's threshold router, verbatim: visit tiers from
/// the bottom, accept at the first tier whose score clears its bar,
/// last tier always accepts.
fn legacy_route(
    cascade: &[ModelSpec],
    judger: &Judger,
    requests: &[Request],
    thresholds: &[f64],
) -> (Vec<u8>, Vec<f64>, Vec<usize>) {
    let c = cascade.len();
    assert_eq!(thresholds.len(), c - 1);
    let mut accepting = vec![0u8; requests.len()];
    let mut final_scores = vec![0.0f64; requests.len()];
    let mut visits = vec![0usize; c];
    for (idx, req) in requests.iter().enumerate() {
        for tier in 0..c {
            visits[tier] += 1;
            let score = judger.score(&cascade[tier], req, tier);
            let accepted = tier == c - 1 || score >= thresholds[tier];
            if accepted {
                accepting[idx] = tier as u8;
                final_scores[idx] = score;
                break;
            }
        }
    }
    (accepting, final_scores, visits)
}

#[test]
fn threshold_policy_reproduces_legacy_route_bit_for_bit() {
    let cases: &[(&[f64], usize)] = &[
        (&[0.0, 0.0], 1),
        (&[101.0, 101.0], 1),
        (&[70.0, 50.0], 1),
        (&[85.0, 85.0], 2),
        (&[60.0, 40.0], 3),
        (&[101.0, 0.0], 2),
    ];
    let cascade = deepseek_cascade();
    let judger = Judger::new(7);
    for &(thresholds, trace_idx) in cases {
        let reqs = generate(&paper_trace(trace_idx, 5.0), 1200, 13);
        let span = reqs.last().unwrap().arrival;
        let (accepting, scores, visits) =
            legacy_route(&cascade, &judger, &reqs, thresholds);
        let policy = ThresholdPolicy::new(thresholds.to_vec()).unwrap();
        let out = route_with(&cascade, &judger, &reqs, &policy, span).unwrap();
        assert_eq!(out.accepting_tier, accepting, "H={thresholds:?} trace {trace_idx}");
        // Exact float equality is the point: identical judger calls in
        // an identical order.
        assert_eq!(out.final_scores, scores, "H={thresholds:?} trace {trace_idx}");
        let n = reqs.len() as f64;
        for t in 0..cascade.len() {
            assert_eq!(out.processing_ratios[t], visits[t] as f64 / n);
            assert_eq!(out.tier_workloads[t].rate, visits[t] as f64 / span);
        }
        let legacy_quality = scores.iter().sum::<f64>() / n;
        assert_eq!(out.quality, legacy_quality);
    }
}

#[test]
fn legacy_equivalence_holds_on_two_tier_cascade() {
    let cascade = llama_cascade();
    let judger = Judger::new(3);
    let reqs = generate(&paper_trace(2, 6.0), 800, 5);
    let span = reqs.last().unwrap().arrival;
    for h in [0.0, 45.0, 80.0, 101.0] {
        let (accepting, scores, _) = legacy_route(&cascade, &judger, &reqs, &[h]);
        let policy = ThresholdPolicy::new(vec![h]).unwrap();
        let out = route_with(&cascade, &judger, &reqs, &policy, span).unwrap();
        assert_eq!(out.accepting_tier, accepting, "h={h}");
        assert_eq!(out.final_scores, scores, "h={h}");
    }
}

fn scheduled_plan(kind: PolicyKind) -> CascadePlan {
    let cascade = deepseek_cascade();
    let cluster = ClusterSpec::paper_testbed();
    let judger = Judger::new(1);
    let reqs = generate(&paper_trace(2, 4.0), 400, 5);
    let opts = OuterOptions {
        threshold_grid: vec![0.0, 40.0, 80.0],
        policy_kind: kind,
        ..Default::default()
    };
    let sweep = optimize(&cascade, &cluster, &judger, &reqs, 32, &opts).unwrap();
    // Prefer a plan actually carrying the requested family (the two
    // threshold utopia anchors also live in `explored`/`pareto`).
    sweep
        .pareto
        .iter()
        .chain(&sweep.explored)
        .find(|p| p.plan.policy.kind() == kind)
        .map(|p| p.plan.clone())
        .or_else(|| select_plan(&sweep, 70.0))
        .expect("sweep produced no plan of the requested kind")
}

/// The acceptance-criterion flow: schedule → JSON text (what `cascadia
/// schedule` prints) → parse → serve configuration, for all three
/// policy families, with no per-threshold knobs in between.
#[test]
fn plan_json_roundtrips_into_serve_configs_for_all_families() {
    for kind in [PolicyKind::Threshold, PolicyKind::Length, PolicyKind::Margin] {
        let plan = scheduled_plan(kind);
        let text = plan.to_json().to_string();
        let back = CascadePlan::from_json_text(&text).expect("plan JSON round-trip");
        assert_eq!(back.policy, plan.policy, "{kind:?}");
        assert_eq!(back.tiers.len(), plan.tiers.len());

        let cfg = ServerConfig::from_plan(&back, 8).unwrap();
        assert_eq!(cfg.replicas.len(), plan.tiers.len());
        assert_eq!(cfg.policy, plan.policy);
        assert!(cfg.replicas.iter().all(|&r| r >= 1));

        let fe = TcpFrontend::from_plan(&back, 8).unwrap();
        assert_eq!(fe.n_tiers, plan.tiers.len());
        assert_eq!(fe.policy(), plan.policy);
        assert_eq!(fe.policy_label(), plan.policy.label());
    }
}

/// Plan files written to disk load back identically (the actual
/// `schedule > plan.json && serve --plan plan.json` handshake).
#[test]
fn plan_file_roundtrip_via_disk() {
    let plan = scheduled_plan(PolicyKind::Threshold);
    let dir = cascadia::util::testfs::TempDir::new("plan").unwrap();
    let path = dir.path().join("plan.json");
    std::fs::write(&path, plan.to_json().to_string()).unwrap();
    let back = CascadePlan::load(&path).unwrap();
    assert_eq!(back.policy, plan.policy);
    assert_eq!(back.total_gpus(), plan.total_gpus());
    assert_eq!(back.predicted_latency, plan.predicted_latency);
    assert_eq!(back.predicted_quality, plan.predicted_quality);
}
