//! JSON config system for experiments and the launcher.
//!
//! A config file fully describes a run: cascade, cluster size, trace,
//! scheduler knobs, and quality requirement. Every field has a default
//! so partial configs (or none at all) work; see
//! `examples/configs/*.json` for complete samples.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterSpec;
use crate::models::{cascade_by_name, ModelSpec};
use crate::router::PolicyKind;
use crate::sched::inner::InnerOptions;
use crate::sched::outer::OuterOptions;
use crate::util::json::Json;
use crate::workload::{paper_trace, TraceSpec};

/// A full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Cascade name: "deepseek" or "llama".
    pub cascade_name: String,
    /// Total GPUs (must be a multiple of 8 for the paper testbed shape).
    pub n_gpus: usize,
    /// Trace index 1..=3.
    pub trace_index: usize,
    /// Mean arrival rate, requests/s.
    pub rate: f64,
    /// Requests to generate.
    pub n_requests: usize,
    /// RNG seed.
    pub seed: u64,
    /// Target mean judged quality.
    pub quality_requirement: f64,
    /// Scheduler options.
    pub use_milp: bool,
    pub uniform_parallelism: bool,
    pub uniform_allocation: bool,
    /// Threshold grid step (score points).
    pub threshold_step: f64,
    /// Routing-policy family the outer sweep searches.
    pub policy_kind: PolicyKind,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            cascade_name: "deepseek".into(),
            n_gpus: 32,
            trace_index: 2,
            rate: 4.0,
            n_requests: 2000,
            seed: 0,
            quality_requirement: 80.0,
            use_milp: true,
            uniform_parallelism: false,
            uniform_allocation: false,
            threshold_step: 10.0,
            policy_kind: PolicyKind::Threshold,
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON file; missing keys fall back to defaults.
    pub fn load(path: impl AsRef<Path>) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<ExperimentConfig> {
        let j = Json::parse(text).context("parsing config JSON")?;
        let mut c = ExperimentConfig::default();
        if let Some(v) = j.get("cascade") {
            c.cascade_name = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("n_gpus") {
            c.n_gpus = v.as_usize()?;
        }
        if let Some(v) = j.get("trace") {
            c.trace_index = v.as_usize()?;
        }
        if let Some(v) = j.get("rate") {
            c.rate = v.as_f64()?;
        }
        if let Some(v) = j.get("n_requests") {
            c.n_requests = v.as_usize()?;
        }
        if let Some(v) = j.get("seed") {
            c.seed = v.as_i64()? as u64;
        }
        if let Some(v) = j.get("quality_requirement") {
            c.quality_requirement = v.as_f64()?;
        }
        if let Some(v) = j.get("use_milp") {
            c.use_milp = v.as_bool()?;
        }
        if let Some(v) = j.get("uniform_parallelism") {
            c.uniform_parallelism = v.as_bool()?;
        }
        if let Some(v) = j.get("uniform_allocation") {
            c.uniform_allocation = v.as_bool()?;
        }
        if let Some(v) = j.get("threshold_step") {
            c.threshold_step = v.as_f64()?;
        }
        if let Some(v) = j.get("policy") {
            c.policy_kind = PolicyKind::parse(v.as_str()?)?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if cascade_by_name(&self.cascade_name).is_none() {
            bail!("unknown cascade '{}' (expected deepseek|llama)", self.cascade_name);
        }
        if !(1..=3).contains(&self.trace_index) {
            bail!("trace index {} out of range 1..=3", self.trace_index);
        }
        if self.n_gpus == 0 || self.rate <= 0.0 || self.n_requests == 0 {
            bail!("n_gpus, rate, n_requests must be positive");
        }
        if !(0.0..=100.0).contains(&self.quality_requirement) {
            bail!("quality requirement must be in 0..=100");
        }
        if self.threshold_step <= 0.0 || self.threshold_step > 50.0 {
            bail!("threshold_step must be in (0, 50]");
        }
        Ok(())
    }

    pub fn cascade(&self) -> Vec<ModelSpec> {
        cascade_by_name(&self.cascade_name).expect("validated")
    }

    pub fn cluster(&self) -> ClusterSpec {
        ClusterSpec::with_gpus(self.n_gpus)
    }

    pub fn trace_spec(&self) -> TraceSpec {
        paper_trace(self.trace_index, self.rate)
    }

    pub fn outer_options(&self) -> OuterOptions {
        let mut grid = Vec::new();
        let mut h = 0.0;
        while h <= 100.0 {
            grid.push(h);
            h += self.threshold_step;
        }
        OuterOptions {
            threshold_grid: grid,
            policy_kind: self.policy_kind,
            inner: InnerOptions {
                use_milp: self.use_milp,
                uniform_parallelism: self.uniform_parallelism,
                uniform_allocation: self.uniform_allocation,
                ..Default::default()
            },
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_partial_config() {
        let c = ExperimentConfig::from_json_text(
            r#"{"cascade": "llama", "n_gpus": 64, "quality_requirement": 75}"#,
        )
        .unwrap();
        assert_eq!(c.cascade_name, "llama");
        assert_eq!(c.n_gpus, 64);
        assert_eq!(c.quality_requirement, 75.0);
        // Default survives.
        assert_eq!(c.trace_index, 2);
        assert_eq!(c.cascade().len(), 2);
    }

    #[test]
    fn parses_policy_kind() {
        let c = ExperimentConfig::from_json_text(r#"{"policy": "length"}"#).unwrap();
        assert_eq!(c.policy_kind, PolicyKind::Length);
        assert_eq!(c.outer_options().policy_kind, PolicyKind::Length);
        assert_eq!(
            ExperimentConfig::default().policy_kind,
            PolicyKind::Threshold
        );
        assert!(ExperimentConfig::from_json_text(r#"{"policy": "bogus"}"#).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_json_text(r#"{"cascade": "gpt"}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"trace": 9}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"rate": -1}"#).is_err());
        assert!(ExperimentConfig::from_json_text("not json").is_err());
    }

    #[test]
    fn outer_options_grid_respects_step() {
        let mut c = ExperimentConfig::default();
        c.threshold_step = 25.0;
        let opts = c.outer_options();
        assert_eq!(opts.threshold_grid, vec![0.0, 25.0, 50.0, 75.0, 100.0]);
    }
}
