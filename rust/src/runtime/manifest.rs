//! Parsing of `artifacts/manifest.json`, the contract between the
//! Python AOT exporter and the Rust runtime: tier architecture
//! constants, the parameter table (names/shapes in blob order), task
//! metadata for the synthetic-task judger, and artifact file names.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Architecture constants of one served tier (mirrors `ModelConfig`).
#[derive(Debug, Clone)]
pub struct TierConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub n_params: usize,
}

/// One entry of the parameter blob: name + shape, in blob order.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Everything the runtime needs to serve one tier.
#[derive(Debug, Clone)]
pub struct TierManifest {
    pub config: TierConfig,
    pub params: Vec<ParamEntry>,
    pub n_floats: usize,
    /// Teacher-forced next-token accuracy per task difficulty (1..=4),
    /// measured at export time; used to sanity-check the cascade's
    /// quality gradient.
    pub eval_accuracy: BTreeMap<u32, f64>,
    pub prefill_file: String,
    pub decode_file: String,
    pub params_file: String,
}

/// Synthetic-task metadata (see `python/compile/train.py`).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub data_vocab: usize,
    pub marker_base: usize,
    pub max_difficulty: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub task: TaskSpec,
    pub tiers: BTreeMap<String, TierManifest>,
}

fn tier_config(j: &Json) -> Result<TierConfig> {
    Ok(TierConfig {
        name: j.req("name")?.as_str()?.to_string(),
        vocab: j.req("vocab")?.as_usize()?,
        d_model: j.req("d_model")?.as_usize()?,
        n_layers: j.req("n_layers")?.as_usize()?,
        n_q_heads: j.req("n_q_heads")?.as_usize()?,
        n_kv_heads: j.req("n_kv_heads")?.as_usize()?,
        d_ff: j.req("d_ff")?.as_usize()?,
        head_dim: j.req("head_dim")?.as_usize()?,
        max_seq: j.req("max_seq")?.as_usize()?,
        prefill_len: j.req("prefill_len")?.as_usize()?,
        n_params: j.req("n_params")?.as_usize()?,
    })
}

fn tier_manifest(j: &Json) -> Result<TierManifest> {
    let params = j
        .req("params")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamEntry {
                name: p.req("name")?.as_str()?.to_string(),
                shape: p
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let mut eval_accuracy = BTreeMap::new();
    if let Some(acc) = j.get("eval_accuracy") {
        for (k, v) in acc.as_obj()? {
            eval_accuracy.insert(k.parse::<u32>()?, v.as_f64()?);
        }
    }
    let files = j.req("files")?;
    Ok(TierManifest {
        config: tier_config(j.req("config")?)?,
        params,
        n_floats: j.req("n_floats")?.as_usize()?,
        eval_accuracy,
        prefill_file: files.req("prefill")?.as_str()?.to_string(),
        decode_file: files.req("decode")?.as_str()?.to_string(),
        params_file: files.req("params")?.as_str()?.to_string(),
    })
}

impl Manifest {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let task = j.req("task")?;
        let task = TaskSpec {
            data_vocab: task.req("data_vocab")?.as_usize()?,
            marker_base: task.req("marker_base")?.as_usize()?,
            max_difficulty: task.req("max_difficulty")?.as_usize()?,
        };
        let mut tiers = BTreeMap::new();
        for (name, tj) in j.req("tiers")?.as_obj()? {
            tiers.insert(
                name.clone(),
                tier_manifest(tj).with_context(|| format!("tier '{name}'"))?,
            );
        }
        Ok(Manifest { dir, task, tiers })
    }

    /// Tier manifests ordered smallest-to-largest by parameter count —
    /// the cascade order.
    pub fn cascade_order(&self) -> Vec<&TierManifest> {
        let mut v: Vec<&TierManifest> = self.tiers.values().collect();
        v.sort_by_key(|t| t.config.n_params);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
          "task": {"data_vocab": 60, "marker_base": 59, "max_difficulty": 4},
          "tiers": {
            "small": {
              "config": {"name": "small", "vocab": 64, "d_model": 64,
                         "n_layers": 2, "n_q_heads": 4, "n_kv_heads": 2,
                         "d_ff": 128, "head_dim": 16, "max_seq": 160,
                         "prefill_len": 64, "n_params": 82240},
              "params": [{"name": "embed", "shape": [64, 64]}],
              "n_floats": 4096,
              "eval_accuracy": {"1": 0.9, "2": 0.5},
              "files": {"prefill": "small_prefill.hlo.txt",
                        "decode": "small_decode.hlo.txt",
                        "params": "small_params.bin"}
            }
          }
        }"#
    }

    #[test]
    fn parses_sample() {
        let dir = crate::util::testfs::TempDir::new("manifest").unwrap();
        std::fs::write(dir.path().join("manifest.json"), sample()).unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.task.data_vocab, 60);
        let t = &m.tiers["small"];
        assert_eq!(t.config.d_model, 64);
        assert_eq!(t.params[0].numel(), 4096);
        assert_eq!(t.eval_accuracy[&1], 0.9);
        assert_eq!(m.cascade_order()[0].config.name, "small");
    }

    #[test]
    fn missing_file_is_actionable() {
        let dir = crate::util::testfs::TempDir::new("manifest").unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
