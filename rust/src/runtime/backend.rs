//! Live serving backend: implements the coordinator's [`TierBackend`]
//! over the PJRT runtime, plus the *real* response judger for the
//! synthetic task the tiny tiers were trained on.
//!
//! This is the path that proves the three-layer architecture end to
//! end: Rust coordinator -> compiled HLO (JAX + Pallas, AOT) -> PJRT
//! CPU execution, with generation quality actually judged from the
//! model's own output tokens.

use std::path::PathBuf;

use anyhow::Result;

#[cfg(feature = "pjrt")]
use super::engine::TierRuntime;
use super::manifest::TaskSpec;
use crate::coordinator::server::{ResponseJudger, TierBackend};

/// Greedy-decoding backend over one tier's compiled executables.
#[cfg(feature = "pjrt")]
pub struct PjrtTierBackend {
    rt: TierRuntime,
}

#[cfg(feature = "pjrt")]
impl PjrtTierBackend {
    pub fn new(rt: TierRuntime) -> PjrtTierBackend {
        PjrtTierBackend { rt }
    }

    /// Load tier `tier_idx` (cascade order) from an artifacts dir.
    pub fn load(dir: &std::path::Path, tier_idx: usize) -> Result<PjrtTierBackend> {
        let manifest = super::manifest::Manifest::load(dir)?;
        let order = manifest.cascade_order();
        let Some(tier) = order.get(tier_idx) else {
            anyhow::bail!("tier index {tier_idx} out of range ({} tiers)", order.len());
        };
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT client: {e}"))?;
        let rt = TierRuntime::load(&client, dir, tier)?;
        Ok(PjrtTierBackend { rt })
    }
}

#[cfg(feature = "pjrt")]
impl TierBackend for PjrtTierBackend {
    fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let cfg = self.rt.manifest.config.clone();
        let true_len = prompt.len();
        let budget = max_new.min(cfg.max_seq - cfg.prefill_len);
        let pre = self.rt.prefill(prompt)?;

        let mut mask = vec![0f32; cfg.max_seq];
        for m in mask.iter_mut().take(true_len) {
            *m = 1.0;
        }
        let mut k = pre.k_cache;
        let mut v = pre.v_cache;
        let mut logits = pre.logits;
        let mut out = Vec::with_capacity(budget);
        for i in 0..budget {
            let token = argmax(&logits) as i32;
            out.push(token);
            if i + 1 == budget {
                break;
            }
            let slot = cfg.prefill_len + i;
            mask[slot] = 1.0;
            let (l, k2, v2) = self.rt.decode(token, slot, true_len + i, &mask, &k, &v)?;
            logits = l;
            k = k2;
            v = v2;
        }
        Ok(out)
    }
}

#[cfg(feature = "pjrt")]
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

/// Build a backend factory closure for [`crate::coordinator::server`]:
/// each worker thread constructs its own PJRT client + executables
/// (PJRT handles are not `Send`).
#[cfg(feature = "pjrt")]
pub fn pjrt_factory(
    dir: PathBuf,
) -> impl Fn(usize) -> Result<Box<dyn TierBackend>> + Send + Sync {
    move |tier_idx| {
        let b = PjrtTierBackend::load(&dir, tier_idx)?;
        Ok(Box::new(b) as Box<dyn TierBackend>)
    }
}

/// Feature-off stub: keeps every caller compiling on builds without
/// the vendored xla toolchain; backend construction fails with a clear
/// message instead.
#[cfg(not(feature = "pjrt"))]
pub fn pjrt_factory(
    dir: PathBuf,
) -> impl Fn(usize) -> Result<Box<dyn TierBackend>> + Send + Sync {
    move |_tier_idx| {
        anyhow::bail!(
            "cascadia was built without the `pjrt` feature; rebuild with \
             `--features pjrt` (requires the vendored xla crate) to serve \
             artifacts from {}",
            dir.display()
        )
    }
}

/// The REAL judger for the e2e cascade: the synthetic task's rule is
/// known (`t[i] = sum of previous m tokens mod V`, with the difficulty
/// marker as token 0), so the ground-truth continuation is computable
/// and the score is simply 100x the fraction of correct generated
/// tokens — no LLM-judge simulation involved.
#[derive(Debug, Clone)]
pub struct TaskJudger {
    pub task: TaskSpec,
    /// Number of leading generated tokens scored.
    pub horizon: usize,
}

impl TaskJudger {
    pub fn new(task: TaskSpec, horizon: usize) -> TaskJudger {
        TaskJudger { task, horizon }
    }

    /// Ground-truth continuation of `prompt` for `n` steps.
    pub fn expected_continuation(&self, prompt: &[i32], n: usize) -> Option<Vec<i32>> {
        let marker_base = self.task.marker_base as i32;
        let m = (prompt.first()? - marker_base) as usize;
        if m == 0 || m > self.task.max_difficulty || prompt.len() < 1 + m {
            return None;
        }
        let v = self.task.data_vocab as i64;
        let mut seq: Vec<i64> = prompt.iter().map(|&t| t as i64).collect();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let next: i64 = seq[seq.len() - m..].iter().sum::<i64>().rem_euclid(v);
            out.push(next as i32);
            seq.push(next);
        }
        Some(out)
    }
}

impl ResponseJudger for TaskJudger {
    fn score(&self, prompt: &[i32], output: &[i32]) -> f64 {
        let n = self.horizon.min(output.len());
        if n == 0 {
            return 0.0;
        }
        match self.expected_continuation(prompt, n) {
            None => 0.0,
            Some(expected) => {
                let correct = expected
                    .iter()
                    .zip(output)
                    .filter(|(e, o)| e == o)
                    .count();
                100.0 * correct as f64 / n as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> TaskSpec {
        TaskSpec { data_vocab: 16, marker_base: 59, max_difficulty: 4 }
    }

    #[test]
    fn expected_continuation_follows_rule() {
        let j = TaskJudger::new(task(), 8);
        // m=2, seeds 3, 5: 3,5 -> 8 -> 13 -> 21%16=5 -> 18%16=2 ...
        let prompt = vec![61, 3, 5];
        let cont = j.expected_continuation(&prompt, 4).unwrap();
        assert_eq!(cont, vec![8, 13, 5, 2]);
    }

    #[test]
    fn perfect_output_scores_100() {
        let j = TaskJudger::new(task(), 4);
        let prompt = vec![60, 7]; // m=1: 7 -> 7 -> 7 ...
        assert_eq!(j.score(&prompt, &[7, 7, 7, 7]), 100.0);
    }

    #[test]
    fn garbage_scores_low() {
        let j = TaskJudger::new(task(), 4);
        let prompt = vec![60, 7];
        assert!(j.score(&prompt, &[1, 2, 3, 4]) <= 25.0);
    }

    #[test]
    fn malformed_prompt_scores_zero() {
        let j = TaskJudger::new(task(), 4);
        assert_eq!(j.score(&[5, 5], &[1, 2]), 0.0); // no marker
        assert_eq!(j.score(&[60], &[1]), 0.0); // missing seeds
    }
}
