//! Execution engine: compiled prefill/decode executables per tier plus
//! the parameter literals, with typed entry points used by the serving
//! hot path.
//!
//! The KV cache is threaded *functionally* through calls as XLA
//! literals (PJRT execution is stateless); the coordinator owns one
//! cache pair per in-flight request.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::TierManifest;

/// One compiled HLO module on the PJRT CPU client.
pub struct ModelExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Number of leading non-parameter inputs (diagnostics only).
    pub name: String,
}

impl ModelExecutable {
    /// Load HLO text from `path` and compile it.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
        Ok(ModelExecutable {
            exe,
            name: path.file_name().unwrap().to_string_lossy().into_owned(),
        })
    }

    /// Execute with literal inputs; returns the untupled outputs.
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.name))?;
        let first = outs
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .context("executable produced no outputs")?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching output of {}: {e}", self.name))?;
        // aot.py lowers with return_tuple=True, so the root is a tuple.
        Ok(lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {}: {e}", self.name))?)
    }
}

/// Result of a prefill call.
pub struct PrefillResult {
    /// Next-token logits at position `true_len - 1`, length `vocab`.
    pub logits: Vec<f32>,
    /// KV cache literals, shape (L, Hkv, max_seq, head_dim) each.
    pub k_cache: xla::Literal,
    pub v_cache: xla::Literal,
}

/// A fully loaded tier: compiled prefill + decode and parameter
/// literals (built once, reused on every call).
pub struct TierRuntime {
    pub manifest: TierManifest,
    prefill: ModelExecutable,
    decode: ModelExecutable,
    params: Vec<xla::Literal>,
}

impl TierRuntime {
    /// Load a tier's artifacts (HLO text + parameter blob) and compile.
    pub fn load(client: &xla::PjRtClient, dir: &Path, tier: &TierManifest) -> Result<Self> {
        let prefill = ModelExecutable::load(client, &dir.join(&tier.prefill_file))?;
        let decode = ModelExecutable::load(client, &dir.join(&tier.decode_file))?;
        let params = load_params(&dir.join(&tier.params_file), tier)?;
        Ok(TierRuntime { manifest: tier.clone(), prefill, decode, params })
    }

    /// Run prefill on a prompt (padded internally to `prefill_len`).
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillResult> {
        let cfg = &self.manifest.config;
        if prompt.is_empty() || prompt.len() > cfg.prefill_len {
            bail!(
                "prompt length {} out of range 1..={}",
                prompt.len(),
                cfg.prefill_len
            );
        }
        let mut tokens = prompt.to_vec();
        tokens.resize(cfg.prefill_len, 0);
        let tokens_lit = xla::Literal::vec1(&tokens);
        let len_lit = xla::Literal::scalar(prompt.len() as i32);
        let mut args: Vec<&xla::Literal> = vec![&tokens_lit, &len_lit];
        args.extend(self.params.iter());
        let mut outs = self.prefill.run(&args)?;
        if outs.len() != 3 {
            bail!("prefill returned {} outputs, expected 3", outs.len());
        }
        let v_cache = outs.pop().unwrap();
        let k_cache = outs.pop().unwrap();
        let logits = outs.pop().unwrap().to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("logits fetch: {e}"))?;
        Ok(PrefillResult { logits, k_cache, v_cache })
    }

    /// Run one decode step.
    ///
    /// * `token` — previously generated token to feed in.
    /// * `pos` — cache slot to write (`prefill_len + i`).
    /// * `rope_pos` — logical position (`true_len + i`).
    /// * `mask` — validity mask over `max_seq` slots (must already
    ///   include slot `pos`).
    ///
    /// Returns next logits and the updated cache literals.
    pub fn decode(
        &self,
        token: i32,
        pos: usize,
        rope_pos: usize,
        mask: &[f32],
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
    ) -> Result<(Vec<f32>, xla::Literal, xla::Literal)> {
        let cfg = &self.manifest.config;
        if mask.len() != cfg.max_seq {
            bail!("mask length {} != max_seq {}", mask.len(), cfg.max_seq);
        }
        if pos >= cfg.max_seq {
            bail!("cache slot {pos} out of range (max_seq {})", cfg.max_seq);
        }
        let token_lit = xla::Literal::scalar(token);
        let pos_lit = xla::Literal::scalar(pos as i32);
        let rope_lit = xla::Literal::scalar(rope_pos as i32);
        let mask_lit = xla::Literal::vec1(mask);
        let mut args: Vec<&xla::Literal> =
            vec![&token_lit, &pos_lit, &rope_lit, &mask_lit, k_cache, v_cache];
        args.extend(self.params.iter());
        let mut outs = self.decode.run(&args)?;
        if outs.len() != 3 {
            bail!("decode returned {} outputs, expected 3", outs.len());
        }
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap().to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("logits fetch: {e}"))?;
        Ok((logits, k_new, v_new))
    }
}

/// Read the f32-LE parameter blob and split it into shaped literals per
/// the manifest's parameter table.
fn load_params(path: &Path, tier: &TierManifest) -> Result<Vec<xla::Literal>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let expected = tier.n_floats * 4;
    if bytes.len() != expected {
        bail!(
            "param blob {} is {} bytes, manifest says {}",
            path.display(),
            bytes.len(),
            expected
        );
    }
    let mut out = Vec::with_capacity(tier.params.len());
    let mut off = 0usize;
    for entry in &tier.params {
        let nbytes = entry.numel() * 4;
        let slice = &bytes[off..off + nbytes];
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &entry.shape,
            slice,
        )
        .map_err(|e| anyhow::anyhow!("literal for {}: {e}", entry.name))?;
        out.push(lit);
        off += nbytes;
    }
    if off != bytes.len() {
        bail!("param blob has {} trailing bytes", bytes.len() - off);
    }
    Ok(out)
}
