//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path.
//!
//! This is the only place the `xla` crate is touched, and that crate
//! is only compiled under the `pjrt` cargo feature (it needs the
//! vendored xla-rs + libxla toolchain; the default build must work on
//! a bare container). With the feature off, [`backend::pjrt_factory`]
//! still exists but returns backends that error at generation time, so
//! every caller compiles unchanged and the artifact-gated tests skip.
//!
//! The flow under `pjrt` is `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`;
//! artifacts are produced once by `python/compile/aot.py`
//! (`make artifacts`) and Python never runs on the request path.

pub mod backend;
#[cfg(feature = "pjrt")]
mod engine;
mod manifest;

#[cfg(feature = "pjrt")]
pub use backend::PjrtTierBackend;
pub use backend::{pjrt_factory, TaskJudger};
#[cfg(feature = "pjrt")]
pub use engine::{ModelExecutable, PrefillResult, TierRuntime};
pub use manifest::{Manifest, ParamEntry, TaskSpec, TierConfig, TierManifest};
