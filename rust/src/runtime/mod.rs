//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path.
//!
//! This is the only place the `xla` crate is touched. The flow is
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`; artifacts are produced once by
//! `python/compile/aot.py` (`make artifacts`) and Python never runs on
//! the request path.

pub mod backend;
mod engine;
mod manifest;

pub use backend::{pjrt_factory, PjrtTierBackend, TaskJudger};
pub use engine::{ModelExecutable, PrefillResult, TierRuntime};
pub use manifest::{Manifest, ParamEntry, TaskSpec, TierConfig, TierManifest};
