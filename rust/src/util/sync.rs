//! Poison-aware lock acquisition helpers.
//!
//! The coordinator and adaptation layers treat a poisoned lock as fatal:
//! a worker that panicked while holding a guard has already corrupted
//! the batch bookkeeping it protects, so limping on would serve wrong
//! answers. `.lock().unwrap()` expresses that policy but trips the
//! `hot-path-unwrap` lint and loses context; these extension traits
//! centralize the panic with a message that names the poisoned lock
//! site. `cascadia-lint` tracks `plock`/`pread`/`pwrite` exactly like
//! the `std` acquisition methods, so converted call sites stay covered
//! by the `lock-order` and `blocking-under-lock` rules.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Poison-panicking [`Mutex::lock`].
pub trait LockExt<T> {
    /// Acquire the mutex, panicking with context if a previous holder
    /// panicked (lock poisoning).
    fn plock(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn plock(&self) -> MutexGuard<'_, T> {
        match self.lock() {
            Ok(g) => g,
            Err(e) => panic!("mutex poisoned: a thread panicked while holding it: {e}"),
        }
    }
}

/// Poison-panicking [`RwLock::read`] / [`RwLock::write`].
pub trait RwLockExt<T> {
    /// Acquire a shared read guard, panicking on poison.
    fn pread(&self) -> RwLockReadGuard<'_, T>;
    /// Acquire an exclusive write guard, panicking on poison.
    fn pwrite(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T> RwLockExt<T> for RwLock<T> {
    fn pread(&self) -> RwLockReadGuard<'_, T> {
        match self.read() {
            Ok(g) => g,
            Err(e) => panic!("rwlock poisoned: a writer panicked while holding it: {e}"),
        }
    }

    fn pwrite(&self) -> RwLockWriteGuard<'_, T> {
        match self.write() {
            Ok(g) => g,
            Err(e) => panic!("rwlock poisoned: a holder panicked while holding it: {e}"),
        }
    }
}

/// Poison-panicking [`Condvar::wait`].
pub trait CondvarExt {
    /// Block on the condvar, re-acquiring the guard on wake and
    /// panicking on poison. This is the blessed block-while-holding
    /// pattern: `wait` atomically releases the mutex, so it is exempt
    /// from the `blocking-under-lock` rule.
    fn pwait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T>;
}

impl CondvarExt for Condvar {
    fn pwait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        match self.wait(guard) {
            Ok(g) => g,
            Err(e) => panic!("condvar wait poisoned: a holder panicked: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex, RwLock};

    #[test]
    fn plock_round_trip() {
        let m = Mutex::new(3usize);
        *m.plock() += 1;
        assert_eq!(*m.plock(), 4);
    }

    #[test]
    fn pread_pwrite_round_trip() {
        let l = RwLock::new(vec![1, 2]);
        l.pwrite().push(3);
        assert_eq!(l.pread().len(), 3);
    }

    #[test]
    fn pwait_wakes() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            *m.plock() = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut ready = m.plock();
        while !*ready {
            ready = cv.pwait(ready);
        }
        h.join().unwrap();
        assert!(*ready);
    }

    #[test]
    #[should_panic(expected = "mutex poisoned")]
    fn plock_panics_on_poison() {
        let m = Arc::new(Mutex::new(0usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.plock();
            panic!("poison it");
        })
        .join();
        let _ = m.plock();
    }
}
