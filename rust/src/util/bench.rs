//! Timing harness for the `[[bench]]` targets (criterion is not in the
//! vendored crate set; benches are built with `harness = false`).
//!
//! Each measurement warms up, then runs timed iterations until both a
//! minimum iteration count and a minimum wall-clock budget are met, and
//! reports mean / p50 / p95 per-iteration times plus derived
//! throughput. Used by `rust/benches/*` and the perf pass
//! (EXPERIMENTS.md §Perf).

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            f64::INFINITY
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }
}

/// Benchmark runner with fixed warmup/measure budgets.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            min_iters: 5,
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE iteration of the workload and
    /// returns a value that is black-boxed to keep the optimizer honest.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters || start.elapsed() < self.budget {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= 1_000_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            mean: Duration::from_secs_f64(stats::mean(&samples)),
            p50: Duration::from_secs_f64(stats::percentile_sorted(&samples, 0.50)),
            p95: Duration::from_secs_f64(stats::percentile_sorted(&samples, 0.95)),
            min: Duration::from_secs_f64(samples[0]),
        };
        println!(
            "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
            m.name, m.iters, m.mean, m.p50, m.p95
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Adopt a measurement taken by another Bencher (e.g. a `quick()`
    /// sub-run), so one CSV collects everything.
    pub fn push_external(&mut self, m: Measurement) {
        self.results.push(m);
    }

    /// Write results as CSV (appends rows: name,iters,mean_s,p50_s,p95_s).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::from("name,iters,mean_s,p50_s,p95_s,min_s\n");
        for m in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                m.name,
                m.iters,
                m.mean.as_secs_f64(),
                m.p50.as_secs_f64(),
                m.p95.as_secs_f64(),
                m.min.as_secs_f64()
            ));
        }
        std::fs::write(path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(10),
            min_iters: 3,
            results: Vec::new(),
        };
        let m = b.bench("noop-ish", || (0..100).sum::<u64>());
        assert!(m.iters >= 3);
        assert!(m.mean.as_nanos() > 0);
        assert!(m.p95 >= m.p50);
    }

    #[test]
    fn csv_roundtrip() {
        let mut b = Bencher::quick();
        b.warmup = Duration::from_millis(1);
        b.budget = Duration::from_millis(5);
        b.bench("x", || 1 + 1);
        let dir = crate::util::testfs::TempDir::new("bench").unwrap();
        let path = dir.path().join("out.csv");
        b.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,iters"));
        assert!(text.contains("x,"));
    }
}
