//! Seedable PRNG + the distributions the workload generator and judger
//! need (uniform, normal, exponential, gamma, lognormal, Poisson).
//!
//! The `rand` crate is not in the vendored set, so this implements
//! xoshiro256++ (Blackman & Vigna) seeded via SplitMix64, plus standard
//! sampling transforms: Box–Muller for normals and Marsaglia–Tsang for
//! gamma. Everything is deterministic given the seed — all experiments
//! in EXPERIMENTS.md are reproducible bit-for-bit.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller, with caching of the pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang; k may be < 1.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^(1/k).
            let g = self.gamma(shape + 1.0, 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / shape) * scale;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * scale;
            }
        }
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with zero total");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.exp(2.0);
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(5);
        let (shape, scale) = (3.0, 2.0);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.gamma(shape, scale);
        }
        let mean = sum / n as f64;
        assert!((mean - shape * scale).abs() < 0.15, "mean {mean}");
        // Shape < 1 path.
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.gamma(0.5, 1.0);
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.05);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(6);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0] / 2);
    }

    #[test]
    fn forked_streams_diverge() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
