//! Minimal JSON value model, parser and serializer.
//!
//! serde is not available in the vendored crate set, so config files and
//! the Python-produced `artifacts/manifest.json` are handled by this
//! module. It implements the full JSON grammar (RFC 8259) minus some
//! exotic float corner cases, which is all the repo needs.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object keys are kept sorted (BTreeMap) for deterministic output.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field access; returns `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like [`Json::get`] but returns an error naming the missing key.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 {
            bail!("expected integer, got {x}");
        }
        Ok(x as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_i64()?;
        usize::try_from(x).context("expected non-negative integer")
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Builder helpers for emitting results.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, lit: &str) -> Result<()> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            bail!("expected '{lit}' at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'n' => {
                self.expect("null")?;
                Ok(Json::Null)
            }
            b't' => {
                self.expect("true")?;
                Ok(Json::Bool(true))
            }
            b'f' => {
                self.expect("false")?;
                Ok(Json::Bool(false))
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            other => bail!("unexpected byte '{}' at {}", other as char, self.pos),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.bump()?; // '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                other => bail!("expected ',' or ']' got '{}'", other as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.bump()?; // '{'
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            if self.bump()? != b':' {
                bail!("expected ':' after object key");
            }
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                other => bail!("expected ',' or '}}' got '{}'", other as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        if self.bump()? != b'"' {
            bail!("expected '\"'");
        }
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let code = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&code) {
                            self.expect("\\u")?;
                            let low = self.hex4()?;
                            let c = 0x10000
                                + ((code - 0xD800) << 10)
                                + (low - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(code)
                        };
                        out.push(c.ok_or_else(|| anyhow!("bad \\u escape"))?);
                    }
                    other => bail!("bad escape '\\{}'", other as char),
                },
                // Raw UTF-8 passthrough: collect the full code point.
                b if b < 0x80 => out.push(b as char),
                b => {
                    let extra = if b >= 0xF0 {
                        3
                    } else if b >= 0xE0 {
                        2
                    } else {
                        1
                    };
                    let start = self.pos - 1;
                    for _ in 0..extra {
                        self.bump()?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .context("invalid UTF-8 in string")?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump()?;
            let d = (b as char).to_digit(16).ok_or_else(|| anyhow!("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 = s.parse().with_context(|| format!("bad number '{s}'"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"nested":{"t":true}}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integer_accessors() {
        let v = Json::parse("[3, 3.5]").unwrap();
        assert_eq!(v.as_arr().unwrap()[0].as_i64().unwrap(), 3);
        assert!(v.as_arr().unwrap()[1].as_i64().is_err());
    }
}
