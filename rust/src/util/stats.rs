//! Descriptive statistics used by the metrics and simulator layers.

/// Percentile by linear interpolation on a *sorted* slice, q in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    let q = q.clamp(0.0, 1.0);
    let idx = q * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = idx - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Percentile on unsorted data (copies and sorts).
pub fn percentile(values: &[f64], q: f64) -> f64 {
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

pub fn stddev(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    let var = values.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (values.len() - 1) as f64;
    var.sqrt()
}

/// Fraction of values <= threshold (SLO attainment for latencies).
pub fn fraction_within(values: &[f64], threshold: f64) -> f64 {
    if values.is_empty() {
        return 1.0;
    }
    values.iter().filter(|&&x| x <= threshold).count() as f64 / values.len() as f64
}

/// Online mean/min/max/count accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn add(&mut self, x: f64) {
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.count += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert!((percentile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((percentile(&v, 0.95) - 3.85).abs() < 1e-12);
    }

    #[test]
    fn percentile_singleton() {
        assert_eq!(percentile(&[7.0], 0.95), 7.0);
    }

    #[test]
    fn fraction_within_basic() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(fraction_within(&v, 2.5), 0.5);
        assert_eq!(fraction_within(&v, 0.0), 0.0);
        assert_eq!(fraction_within(&v, 10.0), 1.0);
    }

    #[test]
    fn summary_tracks_extrema() {
        let mut s = Summary::default();
        for x in [3.0, -1.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count, 3);
        assert_eq!(s.min, -1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean() - 7.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_matches_hand_calc() {
        let v = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&v) - 2.138089935).abs() < 1e-6);
    }
}
