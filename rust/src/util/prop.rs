//! Minimal property-based testing harness (proptest is not in the
//! vendored crate set).
//!
//! A property is a closure over a [`Gen`] (seeded RNG wrapper with
//! sized generators). [`check`] runs it over many seeds; on failure it
//! re-runs the property at the failing seed with progressively smaller
//! size bounds — a cheap form of shrinking — and reports the smallest
//! seed/size that still fails so the case is reproducible.

use super::rng::Rng;

/// Number of cases per property (override with `CASCADIA_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("CASCADIA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Random-input generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Current size bound; generators should scale with it.
    pub size: usize,
}

impl Gen {
    /// Integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    /// usize in [lo, hi] inclusive, additionally capped by `size`.
    pub fn sized(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo.max(self.size));
        self.rng.range_i64(lo as i64, hi as i64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector with length in [min_len, max_len∧size].
    pub fn vec<T>(&mut self, min_len: usize, max_len: usize,
                  mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.sized(min_len, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len() as u64) as usize]
    }
}

/// Run `prop` for [`default_cases`] random cases. The property returns
/// `Err(message)` (or panics) to signal failure.
#[track_caller]
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    check_n(name, default_cases(), prop)
}

/// Run `prop` for `cases` random cases.
#[track_caller]
pub fn check_n<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    let base_seed = 0xCA5CAD1Au64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let size = 4 + (case as usize * 96 / cases.max(1) as usize);
        if let Some(msg) = run_once(&prop, seed, size) {
            // Shrink: retry the failing seed at smaller sizes, keep the
            // smallest size that still fails.
            let mut fail_size = size;
            let mut fail_msg = msg;
            let mut s = size / 2;
            while s >= 1 {
                match run_once(&prop, seed, s) {
                    Some(m) => {
                        fail_size = s;
                        fail_msg = m;
                        s /= 2;
                    }
                    None => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 size {fail_size}): {fail_msg}\n\
                 reproduce: run_once at that seed/size"
            );
        }
    }
}

fn run_once<F>(prop: &F, seed: u64, size: usize) -> Option<String>
where
    F: Fn(&mut Gen) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen { rng: Rng::new(seed), size };
        prop(&mut g)
    });
    match result {
        Ok(Ok(())) => None,
        Ok(Err(msg)) => Some(msg),
        Err(panic) => Some(
            panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".to_string()),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sorted vec is sorted", |g| {
            let mut v = g.vec(0, 50, |g| g.int(-100, 100));
            v.sort();
            for w in v.windows(2) {
                if w[0] > w[1] {
                    return Err(format!("{} > {}", w[0], w[1]));
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        check("always fails", |_| Err("nope".to_string()));
    }

    #[test]
    fn shrinks_to_small_size() {
        // A property failing only for vectors longer than 3 should be
        // reported near that boundary; just assert it fails.
        let result = std::panic::catch_unwind(|| {
            check("len <= 3", |g| {
                let v = g.vec(0, 100, |g| g.int(0, 1));
                if v.len() > 3 {
                    Err(format!("len {}", v.len()))
                } else {
                    Ok(())
                }
            })
        });
        assert!(result.is_err());
    }
}
