//! Tiny CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments; used by the main launcher and every figure
//! binary.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: flags/options by name plus positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an iterator of argument strings.
    ///
    /// A token starting with `--` is an option; if it contains `=`, the
    /// value is inline, otherwise the *next* token is its value unless
    /// that token itself starts with `--` (then it is a bare flag).
    pub fn parse<I: IntoIterator<Item = S>, S: Into<String>>(items: I) -> Self {
        let tokens: Vec<String> = items.into_iter().map(Into::into).collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    args.opts.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        match self.get(name) {
            Some(s) => Ok(s),
            None => bail!("missing required option --{name}"),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(["trace1", "--n", "32", "--mode=fast", "--verbose"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 32);
        assert_eq!(a.str_or("mode", ""), "fast");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["trace1".to_string()]);
        // NOTE: `--flag value` binds value to the flag (greedy); put
        // positionals first or use `--flag` last.
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("x", 1.5).unwrap(), 1.5);
        assert!(!a.flag("anything"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(["--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(["--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
        assert!(a.required("missing").is_err());
    }
}
