//! Shared substrates built from scratch for the offline environment:
//! JSON parsing/serialization, a seedable PRNG with the distributions
//! the workload generator needs, descriptive statistics, and a tiny
//! CLI argument parser.

pub mod cli;
pub mod json;
pub mod rng;
pub mod bench;
pub mod prop;
pub mod stats;
pub mod sync;
pub mod testfs;
