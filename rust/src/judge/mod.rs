//! Response quality judger.
//!
//! The paper uses GPT-4o (LLM-as-a-Judge) to score each tier's response
//! 0-100; thresholds on that score drive cascade routing. Here the
//! judger is a calibrated synthetic model (DESIGN.md "Substitutions")
//! with the *bimodal* structure real LLM-judge scores exhibit: a model
//! either answers a request well (score ~ N(94, 5)) or fails it
//! (score ~ N(35, 12)), and the success probability is
//!
//!   p_success = sigmoid(STEEPNESS * (capability - complexity))
//!
//! Capability is derived from the model's `quality_mean` anchor
//! (Figure 1). Bimodality is what makes cascades efficient: a threshold
//! between the two modes catches failures almost surely while passing
//! successes, so high end-to-end quality is reachable with *light*
//! escalation — the paper's Table 1 regime. The e2e example replaces
//! this judger with a real one (task-rule correctness of the tiny
//! tiers' actual output tokens).

use crate::models::ModelSpec;
use crate::util::rng::Rng;
use crate::workload::Request;

/// How sharply success probability degrades past a model's capability.
pub const STEEPNESS: f64 = 2.0;
/// Mean/std of the success score mode.
pub const SUCCESS_MEAN: f64 = 94.0;
pub const SUCCESS_STD: f64 = 5.0;
/// Mean/std of the failure score mode.
pub const FAIL_MEAN: f64 = 35.0;
pub const FAIL_STD: f64 = 12.0;

/// Reference complexity at which a model's mean score equals its
/// Figure-1 `quality_mean` anchor (roughly the evaluation workload's
/// mean complexity).
pub const X_REF: f64 = 0.45;

/// Success probability that reproduces the anchor mean at `X_REF`.
fn anchor_success_prob(quality_mean: f64) -> f64 {
    ((quality_mean - FAIL_MEAN) / (SUCCESS_MEAN - FAIL_MEAN)).clamp(0.02, 0.98)
}

/// Map a model's Figure-1 quality anchor (0-100) to capability in the
/// complexity space, such that
/// `E[score | x = X_REF] == quality_mean`.
pub fn capability(model: &ModelSpec) -> f64 {
    let p = anchor_success_prob(model.quality_mean);
    X_REF + (p / (1.0 - p)).ln() / STEEPNESS
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// Probability that `model` answers a request of complexity `x` well.
pub fn success_prob(model: &ModelSpec, x: f64) -> f64 {
    sigmoid(STEEPNESS * (capability(model) - x))
}

/// Noise-free expected score of `model` on a request of complexity `x`.
pub fn expected_score(model: &ModelSpec, x: f64) -> f64 {
    let p = success_prob(model, x);
    SUCCESS_MEAN * p + FAIL_MEAN * (1.0 - p)
}

/// The judger: scores responses; deterministic given its seed and the
/// (request, tier) pair, so routing decisions are reproducible across
/// simulation and serving runs.
#[derive(Debug, Clone)]
pub struct Judger {
    seed: u64,
}

impl Judger {
    pub fn new(seed: u64) -> Judger {
        Judger { seed }
    }

    /// Score of `model`'s response to `req`, in [0, 100].
    pub fn score(&self, model: &ModelSpec, req: &Request, tier_idx: usize) -> f64 {
        // Per-(request, tier) deterministic stream.
        let mut rng = Rng::new(
            self.seed
                ^ (req.id as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (tier_idx as u64).wrapping_mul(0xD1B54A32D192ED03),
        );
        let p = success_prob(model, req.complexity);
        let score = if rng.chance(p) {
            rng.normal_ms(SUCCESS_MEAN, SUCCESS_STD)
        } else {
            rng.normal_ms(FAIL_MEAN, FAIL_STD)
        };
        score.clamp(0.0, 100.0)
    }

    /// Monte-Carlo accept probability of threshold `h` for `model` over
    /// a set of requests (used by tests and diagnostics; the scheduler
    /// routes the actual trace instead).
    pub fn accept_prob(&self, model: &ModelSpec, reqs: &[Request], tier_idx: usize, h: f64) -> f64 {
        if reqs.is_empty() {
            return 1.0;
        }
        let n = reqs
            .iter()
            .filter(|r| self.score(model, r, tier_idx) >= h)
            .count();
        n as f64 / reqs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;
    use crate::workload::{generate, paper_trace, Request};

    fn reqs() -> Vec<Request> {
        generate(&paper_trace(2, 4.0), 2000, 11)
    }

    #[test]
    fn bigger_models_score_higher() {
        let cascade = deepseek_cascade();
        let reqs = reqs();
        let j = Judger::new(0);
        let mean = |m: &ModelSpec, t: usize| {
            reqs.iter().map(|r| j.score(m, r, t)).sum::<f64>() / reqs.len() as f64
        };
        let m0 = mean(&cascade[0], 0);
        let m1 = mean(&cascade[1], 1);
        let m2 = mean(&cascade[2], 2);
        assert!(m0 < m1 && m1 < m2, "{m0} {m1} {m2}");
    }

    #[test]
    fn harder_requests_score_lower() {
        let m = &deepseek_cascade()[0];
        let easy = expected_score(m, 0.1);
        let hard = expected_score(m, 0.9);
        assert!(easy > hard + 15.0, "easy {easy} hard {hard}");
    }

    #[test]
    fn scores_bounded_and_bimodal() {
        let j = Judger::new(3);
        let cascade = deepseek_cascade();
        let mut mid = 0usize;
        let mut total = 0usize;
        for r in reqs().iter().take(500) {
            for (t, m) in cascade.iter().enumerate() {
                let s = j.score(m, r, t);
                assert!((0.0..=100.0).contains(&s));
                total += 1;
                if (62.0..80.0).contains(&s) {
                    mid += 1;
                }
            }
        }
        // The valley between the modes is sparsely populated.
        assert!(
            (mid as f64) < 0.08 * total as f64,
            "too many mid scores: {mid}/{total}"
        );
    }

    #[test]
    fn scoring_is_deterministic() {
        let j = Judger::new(5);
        let m = &deepseek_cascade()[1];
        let reqs = reqs();
        let a: Vec<f64> = reqs.iter().take(50).map(|r| j.score(m, r, 1)).collect();
        let b: Vec<f64> = reqs.iter().take(50).map(|r| j.score(m, r, 1)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn accept_prob_monotone_in_threshold() {
        let j = Judger::new(7);
        let m = &deepseek_cascade()[0];
        let reqs = reqs();
        let mut prev = 1.0;
        for h in [0.0, 25.0, 50.0, 75.0, 100.1] {
            let p = j.accept_prob(m, &reqs, 0, h);
            assert!(p <= prev + 1e-12, "h {h}: {p} > {prev}");
            prev = p;
        }
    }

    #[test]
    fn a_threshold_separates_the_modes() {
        // h = 65 should accept nearly all successes and reject nearly
        // all failures: accept_prob ~ mean success_prob.
        let j = Judger::new(9);
        let m = &deepseek_cascade()[1];
        let reqs = reqs();
        let accept = j.accept_prob(m, &reqs, 1, 65.0);
        let p_succ = reqs.iter().map(|r| success_prob(m, r.complexity)).sum::<f64>()
            / reqs.len() as f64;
        assert!((accept - p_succ).abs() < 0.06, "accept {accept} vs p {p_succ}");
    }

    #[test]
    fn figure1_anchors_recovered() {
        let j = Judger::new(9);
        let reqs = reqs();
        for (t, m) in deepseek_cascade().iter().enumerate() {
            let mean = reqs.iter().map(|r| j.score(m, r, t)).sum::<f64>() / reqs.len() as f64;
            assert!(
                (mean - m.quality_mean).abs() < 15.0,
                "{}: mean {mean} anchor {}",
                m.name,
                m.quality_mean
            );
        }
    }
}
