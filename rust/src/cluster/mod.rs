//! Hardware description of the (simulated) GPU cluster.
//!
//! The paper's testbed — 4 servers × 8 H100-80GB, NVLink 400 GB/s
//! intra-server, InfiniBand 200 Gb/s-class inter-server — is modelled
//! parametrically: the scheduler and simulator consume only the numbers
//! here, so alternative clusters (64/128 GPUs for Figure 12) are just
//! different `ClusterSpec` values.

/// One GPU's capability envelope.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    /// HBM capacity in bytes.
    pub mem_bytes: f64,
    /// Dense bf16 peak, FLOP/s.
    pub peak_flops: f64,
    /// Achievable fraction of peak in serving kernels (MFU ceiling).
    pub mfu: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Achievable fraction of HBM bandwidth in decode kernels.
    pub mbu: f64,
}

impl GpuSpec {
    pub fn h100() -> GpuSpec {
        GpuSpec {
            name: "H100-80GB",
            mem_bytes: 80e9,
            peak_flops: 989e12, // dense bf16, no sparsity
            mfu: 0.55,
            hbm_bw: 3.35e12,
            mbu: 0.70,
        }
    }

    /// Effective compute throughput (FLOP/s) after the MFU ceiling.
    pub fn eff_flops(&self) -> f64 {
        self.peak_flops * self.mfu
    }

    /// Effective memory bandwidth (bytes/s) after the MBU ceiling.
    pub fn eff_hbm_bw(&self) -> f64 {
        self.hbm_bw * self.mbu
    }
}

/// Interconnect description (alpha-beta model per link class).
#[derive(Debug, Clone)]
pub struct LinkSpec {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Bandwidth, bytes/s.
    pub beta_bw: f64,
}

/// The cluster: homogeneous servers of homogeneous GPUs.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    pub n_servers: usize,
    pub gpus_per_server: usize,
    /// Intra-server link (NVLink).
    pub intra: LinkSpec,
    /// Inter-server link (InfiniBand).
    pub inter: LinkSpec,
    /// Host link (PCIe) each GPU moves KV pages over when the engine
    /// swaps preempted sequences to host memory.
    pub pcie: LinkSpec,
    /// Pinned host memory backing swap-to-host, per GPU (bytes). The
    /// engine's host swap space is bounded by this budget.
    pub host_swap_bytes_per_gpu: f64,
}

impl ClusterSpec {
    /// The paper's testbed: 4 × 8 H100, NVLink 400 GB/s, IB 200 Gb/s.
    pub fn paper_testbed() -> ClusterSpec {
        ClusterSpec {
            gpu: GpuSpec::h100(),
            n_servers: 4,
            gpus_per_server: 8,
            intra: LinkSpec { alpha: 3e-6, beta_bw: 400e9 },
            inter: LinkSpec { alpha: 10e-6, beta_bw: 25e9 }, // 200 Gb/s
            // PCIe 5.0 x16 at achievable (not peak) bandwidth, and a
            // conservative per-transfer setup latency.
            pcie: LinkSpec { alpha: 20e-6, beta_bw: 50e9 },
            // H100 hosts carry ~1-2 TB of DRAM for 8 GPUs; reserve a
            // pinned slice per GPU for swapped KV.
            host_swap_bytes_per_gpu: 128e9,
        }
    }

    /// Scaled clusters for the Figure 12 runtime study.
    pub fn with_gpus(total: usize) -> ClusterSpec {
        let mut c = ClusterSpec::paper_testbed();
        assert!(total % c.gpus_per_server == 0,
                "total GPUs must be a multiple of {}", c.gpus_per_server);
        c.n_servers = total / c.gpus_per_server;
        c
    }

    pub fn total_gpus(&self) -> usize {
        self.n_servers * self.gpus_per_server
    }

    /// The link a group of `n` GPUs communicates over: NVLink while the
    /// group fits in one server, InfiniBand once it spans servers.
    pub fn link_for_group(&self, n: usize) -> &LinkSpec {
        if n <= self.gpus_per_server {
            &self.intra
        } else {
            &self.inter
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_has_32_gpus() {
        let c = ClusterSpec::paper_testbed();
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.gpu.mem_bytes, 80e9);
    }

    #[test]
    fn scaled_clusters() {
        assert_eq!(ClusterSpec::with_gpus(64).n_servers, 8);
        assert_eq!(ClusterSpec::with_gpus(128).total_gpus(), 128);
    }

    #[test]
    #[should_panic]
    fn non_multiple_scaling_panics() {
        ClusterSpec::with_gpus(33);
    }

    #[test]
    fn link_selection_crosses_server_boundary() {
        let c = ClusterSpec::paper_testbed();
        assert!((c.link_for_group(8).beta_bw - 400e9).abs() < 1.0);
        assert!((c.link_for_group(9).beta_bw - 25e9).abs() < 1.0);
    }

    #[test]
    fn pcie_is_slower_than_every_device_link() {
        // Swap-to-host must never look cheaper than staying on-device
        // interconnects in the cost model.
        let c = ClusterSpec::paper_testbed();
        assert!(c.pcie.beta_bw < c.intra.beta_bw);
        assert!(c.pcie.beta_bw > c.inter.beta_bw, "PCIe 5 outruns the IB fabric");
        assert!(c.host_swap_bytes_per_gpu > c.gpu.mem_bytes, "host swap outsizes HBM");
    }

    #[test]
    fn effective_rates_below_peak() {
        let g = GpuSpec::h100();
        assert!(g.eff_flops() < g.peak_flops);
        assert!(g.eff_hbm_bw() < g.hbm_bw);
    }
}
