//! Result emission: aligned text tables (what the figure binaries
//! print) and CSV files under `results/` (what EXPERIMENTS.md records).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

/// Format seconds compactly (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_escapes_commas() {
        let dir = crate::util::testfs::TempDir::new("report").unwrap();
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["v,w".into(), "plain".into()]);
        let p = dir.path().join("t.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"v,w\",plain"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(0.0000005), "0.5µs");
        assert_eq!(fmt_secs(0.012), "12.0ms");
        assert_eq!(fmt_secs(2.5), "2.50s");
    }
}
