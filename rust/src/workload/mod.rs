//! Workload traces: synthetic MT-Bench-style request streams.
//!
//! The paper subsamples MT-Bench into traces with "different workload
//! characteristics and different complexities" (§4.1). We generate
//! equivalent streams directly from the statistics that matter to the
//! scheduler: prompt/output length distributions (lognormal), request
//! *complexity* (Beta-distributed latent in [0,1] consumed by the
//! judger), and the arrival process (Poisson or bursty gamma renewal).
//! Everything is seeded and reproducible.

use crate::perf::Workload;
use crate::util::rng::Rng;

/// One request class inside a trace (e.g. "coding", "conversation").
#[derive(Debug, Clone)]
pub struct ClassSpec {
    pub name: &'static str,
    /// Mixture weight (unnormalized).
    pub weight: f64,
    /// Lognormal (mu, sigma) of prompt tokens.
    pub input_lognorm: (f64, f64),
    /// Lognormal (mu, sigma) of output tokens.
    pub output_lognorm: (f64, f64),
    /// Beta(a, b) of latent complexity in [0, 1].
    pub complexity_beta: (f64, f64),
}

/// A full trace specification.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub name: &'static str,
    pub classes: Vec<ClassSpec>,
    /// Mean arrival rate, requests/s.
    pub rate: f64,
    /// Squared coefficient of variation of inter-arrivals; 1 = Poisson,
    /// >1 = bursty (gamma renewal process).
    pub burstiness: f64,
}

/// One concrete request.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: u32,
    pub arrival: f64,
    pub input_tokens: u32,
    pub output_tokens: u32,
    /// Latent difficulty in [0, 1]; consumed by the judger.
    pub complexity: f64,
}

/// Aggregate statistics of a request stream — what the scheduler's
/// workload monitor extracts (and re-extracts at re-scheduling time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceStats {
    pub rate: f64,
    pub avg_input: f64,
    pub avg_output: f64,
    pub complexity_mean: f64,
}

impl TraceStats {
    pub fn workload(&self) -> Workload {
        Workload {
            rate: self.rate,
            avg_input: self.avg_input,
            avg_output: self.avg_output,
        }
    }

    /// Relative shift between two measured workloads; the coordinator
    /// re-schedules when this exceeds its threshold. The rate term is
    /// down-weighted 2x: arrival-rate estimates from a small window are
    /// far noisier than length/complexity means (especially for bursty
    /// gamma arrivals), and a real rate surge is large anyway.
    pub fn shift_from(&self, other: &TraceStats) -> f64 {
        let rel = |a: f64, b: f64| ((a - b) / b.max(1e-9)).abs();
        (rel(self.rate, other.rate) * 0.5)
            .max(rel(self.avg_input, other.avg_input))
            .max(rel(self.avg_output, other.avg_output))
            .max(rel(self.complexity_mean, other.complexity_mean))
    }
}

/// Estimate stats from a request sample (the re-scheduling subsampler).
pub fn estimate_stats(requests: &[Request]) -> TraceStats {
    assert!(!requests.is_empty());
    let n = requests.len() as f64;
    let span = requests.last().unwrap().arrival - requests[0].arrival;
    TraceStats {
        rate: if span > 0.0 { (n - 1.0) / span } else { n },
        avg_input: requests.iter().map(|r| r.input_tokens as f64).sum::<f64>() / n,
        avg_output: requests.iter().map(|r| r.output_tokens as f64).sum::<f64>() / n,
        complexity_mean: requests.iter().map(|r| r.complexity).sum::<f64>() / n,
    }
}

fn sample_beta(rng: &mut Rng, a: f64, b: f64) -> f64 {
    let x = rng.gamma(a, 1.0);
    let y = rng.gamma(b, 1.0);
    x / (x + y)
}

/// Generate `n` requests from a trace spec.
pub fn generate(spec: &TraceSpec, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let weights: Vec<f64> = spec.classes.iter().map(|c| c.weight).collect();
    // Gamma renewal process with mean 1/rate and SCV = burstiness:
    // shape k = 1/SCV, scale = SCV/rate.
    let shape = 1.0 / spec.burstiness.max(1e-3);
    let scale = spec.burstiness / spec.rate;
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for id in 0..n {
        t += rng.gamma(shape, scale);
        let class = &spec.classes[rng.weighted(&weights)];
        let (imu, isig) = class.input_lognorm;
        let (omu, osig) = class.output_lognorm;
        let (ba, bb) = class.complexity_beta;
        out.push(Request {
            id: id as u32,
            arrival: t,
            input_tokens: (rng.lognormal(imu, isig).round() as u32).clamp(8, 8192),
            output_tokens: (rng.lognormal(omu, osig).round() as u32).clamp(4, 4096),
            complexity: sample_beta(&mut rng, ba, bb),
        });
    }
    out
}

/// lognormal (mu, sigma) with a target mean and multiplicative spread.
fn ln_params(mean: f64, sigma: f64) -> (f64, f64) {
    (mean.ln() - sigma * sigma / 2.0, sigma)
}

/// The three evaluation traces (§4.1): distinct length mixes and
/// complexity profiles, hardest to easiest.
pub fn paper_traces(rate: f64) -> Vec<TraceSpec> {
    vec![
        // Trace 1 — reasoning/coding heavy: long prompts, high complexity.
        TraceSpec {
            name: "trace1",
            rate,
            burstiness: 1.0,
            classes: vec![
                ClassSpec {
                    name: "coding",
                    weight: 0.6,
                    input_lognorm: ln_params(900.0, 0.6),
                    output_lognorm: ln_params(320.0, 0.5),
                    complexity_beta: (3.5, 2.5),
                },
                ClassSpec {
                    name: "reasoning",
                    weight: 0.4,
                    input_lognorm: ln_params(450.0, 0.5),
                    output_lognorm: ln_params(512.0, 0.5),
                    complexity_beta: (3.0, 2.5),
                },
            ],
        },
        // Trace 2 — mixed chat/math: medium lengths, mid complexity.
        TraceSpec {
            name: "trace2",
            rate,
            burstiness: 1.4,
            classes: vec![
                ClassSpec {
                    name: "math",
                    weight: 0.5,
                    input_lognorm: ln_params(350.0, 0.5),
                    output_lognorm: ln_params(384.0, 0.5),
                    complexity_beta: (2.6, 2.6),
                },
                ClassSpec {
                    name: "chat",
                    weight: 0.5,
                    input_lognorm: ln_params(250.0, 0.6),
                    output_lognorm: ln_params(420.0, 0.5),
                    complexity_beta: (2.0, 3.2),
                },
            ],
        },
        // Trace 3 — light conversation/extraction: short, easy.
        TraceSpec {
            name: "trace3",
            rate,
            burstiness: 1.0,
            classes: vec![
                ClassSpec {
                    name: "qa",
                    weight: 0.7,
                    input_lognorm: ln_params(200.0, 0.5),
                    output_lognorm: ln_params(256.0, 0.5),
                    complexity_beta: (1.4, 5.5),
                },
                ClassSpec {
                    name: "extraction",
                    weight: 0.3,
                    input_lognorm: ln_params(600.0, 0.4),
                    output_lognorm: ln_params(128.0, 0.4),
                    complexity_beta: (1.8, 4.5),
                },
            ],
        },
    ]
}

/// Look up one of the paper traces by 1-based index.
pub fn paper_trace(index: usize, rate: f64) -> TraceSpec {
    paper_traces(rate)
        .into_iter()
        .nth(index - 1)
        .unwrap_or_else(|| panic!("trace index {index} out of range 1..=3"))
}

/// A non-stationary trace: the generating distribution switches at
/// phase boundaries (regime changes in rate, length mix, and
/// complexity — the workload shifts §4.4's re-scheduling loop reacts
/// to). Each phase contributes a fixed number of requests.
#[derive(Debug, Clone)]
pub struct PhasedTraceSpec {
    pub phases: Vec<(TraceSpec, usize)>,
}

/// A generated drifting trace: requests in global arrival order plus
/// the index at which each phase begins.
#[derive(Debug, Clone)]
pub struct PhasedTrace {
    pub requests: Vec<Request>,
    /// `phase_starts[p]` is the index of phase `p`'s first request
    /// (`phase_starts[0] == 0`).
    pub phase_starts: Vec<usize>,
}

impl PhasedTrace {
    pub fn n_phases(&self) -> usize {
        self.phase_starts.len()
    }

    /// Which phase request index `id` belongs to.
    pub fn phase_of(&self, id: usize) -> usize {
        match self.phase_starts.binary_search(&id) {
            Ok(p) => p,
            Err(ins) => ins.saturating_sub(1),
        }
    }

    /// The request-index range of phase `p`.
    pub fn phase_range(&self, p: usize) -> std::ops::Range<usize> {
        let start = self.phase_starts[p];
        let end = self
            .phase_starts
            .get(p + 1)
            .copied()
            .unwrap_or(self.requests.len());
        start..end
    }
}

/// Generate a drifting trace: phases are generated independently (each
/// with a phase-derived seed) and concatenated on a continuous arrival
/// clock, so the stream looks like one workload whose regime shifts.
pub fn generate_phased(spec: &PhasedTraceSpec, seed: u64) -> PhasedTrace {
    let mut requests = Vec::new();
    let mut phase_starts = Vec::new();
    let mut t_offset = 0.0;
    for (p, (phase_spec, n)) in spec.phases.iter().enumerate() {
        phase_starts.push(requests.len());
        for r in generate(phase_spec, *n, seed.wrapping_add(1 + p as u64)) {
            let arrival = t_offset + r.arrival;
            requests.push(Request { id: requests.len() as u32, arrival, ..r });
        }
        t_offset = requests.last().map(|r| r.arrival).unwrap_or(t_offset);
    }
    PhasedTrace { requests, phase_starts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let spec = paper_trace(1, 4.0);
        let a = generate(&spec, 100, 7);
        let b = generate(&spec, 100, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.input_tokens, y.input_tokens);
            assert_eq!(x.complexity, y.complexity);
        }
    }

    #[test]
    fn stats_match_spec_roughly() {
        let spec = paper_trace(1, 5.0);
        let reqs = generate(&spec, 4000, 1);
        let stats = estimate_stats(&reqs);
        assert!((stats.rate - 5.0).abs() / 5.0 < 0.1, "rate {}", stats.rate);
        // Mixture mean input: 0.6*900 + 0.4*450 = 720.
        assert!((stats.avg_input - 720.0).abs() / 720.0 < 0.15,
                "avg_input {}", stats.avg_input);
        assert!(stats.complexity_mean > 0.5, "trace1 should be complex");
    }

    #[test]
    fn traces_are_ordered_by_complexity() {
        let mut means = Vec::new();
        for i in 1..=3 {
            let reqs = generate(&paper_trace(i, 4.0), 3000, 2);
            means.push(estimate_stats(&reqs).complexity_mean);
        }
        assert!(means[0] > means[1], "{means:?}");
        assert!(means[1] > means[2], "{means:?}");
    }

    #[test]
    fn complexity_is_in_unit_interval() {
        for i in 1..=3 {
            for r in generate(&paper_trace(i, 2.0), 500, 3) {
                assert!((0.0..=1.0).contains(&r.complexity));
                assert!(r.input_tokens >= 8);
                assert!(r.output_tokens >= 4);
            }
        }
    }

    #[test]
    fn bursty_trace_has_higher_interarrival_variance() {
        let mut poisson = paper_trace(1, 4.0);
        poisson.burstiness = 1.0;
        let mut bursty = poisson.clone();
        bursty.burstiness = 4.0;
        let iat = |reqs: &[Request]| {
            let mut v = Vec::new();
            for w in reqs.windows(2) {
                v.push(w[1].arrival - w[0].arrival);
            }
            let m = crate::util::stats::mean(&v);
            crate::util::stats::stddev(&v) / m
        };
        let cv_p = iat(&generate(&poisson, 3000, 5));
        let cv_b = iat(&generate(&bursty, 3000, 5));
        assert!(cv_b > cv_p * 1.3, "cv_b {cv_b} vs cv_p {cv_p}");
    }

    #[test]
    fn phased_trace_has_monotone_arrivals_and_sequential_ids() {
        let spec = PhasedTraceSpec {
            phases: vec![
                (paper_trace(3, 10.0), 200),
                (paper_trace(1, 5.0), 150),
            ],
        };
        let t = generate_phased(&spec, 9);
        assert_eq!(t.requests.len(), 350);
        assert_eq!(t.phase_starts, vec![0, 200]);
        for (i, r) in t.requests.iter().enumerate() {
            assert_eq!(r.id as usize, i);
        }
        for w in t.requests.windows(2) {
            assert!(w[1].arrival >= w[0].arrival, "arrivals must be monotone");
        }
        assert_eq!(t.phase_of(0), 0);
        assert_eq!(t.phase_of(199), 0);
        assert_eq!(t.phase_of(200), 1);
        assert_eq!(t.phase_of(349), 1);
        assert_eq!(t.phase_range(0), 0..200);
        assert_eq!(t.phase_range(1), 200..350);
    }

    #[test]
    fn phased_trace_phases_have_distinct_stats() {
        // Easy/short trace 3 at 12 rps, then hard/long trace 1 at 4 rps:
        // the per-phase stats must reflect the regime change.
        let spec = PhasedTraceSpec {
            phases: vec![
                (paper_trace(3, 12.0), 400),
                (paper_trace(1, 4.0), 400),
            ],
        };
        let t = generate_phased(&spec, 3);
        let s0 = estimate_stats(&t.requests[t.phase_range(0)]);
        let s1 = estimate_stats(&t.requests[t.phase_range(1)]);
        assert!(s0.rate > 2.0 * s1.rate, "rate shift lost: {} vs {}", s0.rate, s1.rate);
        assert!(s1.avg_input > s0.avg_input, "length shift lost");
        assert!(s1.complexity_mean > s0.complexity_mean, "complexity shift lost");
        assert!(s1.shift_from(&s0) > 0.3, "shift metric should flag the regime change");
    }

    #[test]
    fn shift_detection() {
        let a = TraceStats { rate: 4.0, avg_input: 500.0, avg_output: 200.0, complexity_mean: 0.5 };
        let same = a;
        assert!(a.shift_from(&same) < 1e-12);
        let faster = TraceStats { rate: 6.0, ..a };
        // rate term is down-weighted 2x: |6-4|/4 * 0.5 = 0.25.
        assert!((faster.shift_from(&a) - 0.25).abs() < 1e-9);
    }
}
