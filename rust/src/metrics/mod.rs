//! Evaluation metrics: SLO attainment curves and the paper's headline
//! "minimum SLO scale at 95% attainment" (§4.1), latency percentile
//! summaries shared by the server/replay reports, and the counters of
//! the online adaptation loop (§4.4).

use crate::util::stats;

/// p50/p95/p99 + mean of a latency sample (seconds). The server's
/// summary used to be mean-only; every consumer now reports the tail.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl LatencySummary {
    /// Summarize a latency sample; all-zero for an empty sample.
    pub fn of(latencies: &[f64]) -> LatencySummary {
        if latencies.is_empty() {
            return LatencySummary::default();
        }
        let mut v = latencies.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        LatencySummary {
            mean: stats::mean(&v),
            p50: stats::percentile_sorted(&v, 0.50),
            p95: stats::percentile_sorted(&v, 0.95),
            p99: stats::percentile_sorted(&v, 0.99),
        }
    }
}

/// Counters of the monitor → re-schedule → hot-swap loop, surfaced by
/// the adaptation controller and the replay harness.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdaptCounters {
    /// Workload shifts the monitor flagged.
    pub drifts_detected: usize,
    /// Re-schedules acknowledged (`Monitor::reschedules`).
    pub reschedules: usize,
    /// Drifts resolved from the precomputed-plan cache (no scheduler
    /// run).
    pub plan_cache_hits: usize,
    /// Plans queued for hot-swap. The serve loop applies the latest
    /// queued plan, so the count of swaps *actually applied* is the
    /// server-side `ServeControl::hot_swaps` (the replay report uses
    /// that one).
    pub hot_swaps: usize,
}

impl std::fmt::Display for AdaptCounters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "drifts={} reschedules={} cache_hits={} hot_swaps={}",
            self.drifts_detected, self.reschedules, self.plan_cache_hits, self.hot_swaps
        )
    }
}

/// An SLO attainment curve: attainment at each SLO scale multiple.
#[derive(Debug, Clone)]
pub struct SloCurve {
    /// The unit SLO in seconds (empirical single-request latency).
    pub unit: f64,
    pub scales: Vec<f64>,
    pub attainment: Vec<f64>,
}

impl SloCurve {
    /// Build from raw latencies; `unit` is the SLO base (the paper uses
    /// the system's average single-request processing latency).
    pub fn from_latencies(latencies: &[f64], unit: f64, scales: &[f64]) -> SloCurve {
        let attainment = scales
            .iter()
            .map(|s| stats::fraction_within(latencies, unit * s))
            .collect();
        SloCurve { unit, scales: scales.to_vec(), attainment }
    }

    /// Smallest listed scale reaching `target` attainment (None if the
    /// curve never gets there).
    pub fn min_scale_reaching(&self, target: f64) -> Option<f64> {
        self.scales
            .iter()
            .zip(&self.attainment)
            .find(|(_, &a)| a >= target)
            .map(|(&s, _)| s)
    }

    /// Exact scale where attainment hits `target` (by quantile), not
    /// limited to the listed grid.
    pub fn exact_scale(latencies: &[f64], unit: f64, target: f64) -> f64 {
        stats::percentile(latencies, target) / unit
    }
}

/// The standard SLO-scale grid used across figures.
pub fn default_scales() -> Vec<f64> {
    let mut v = Vec::new();
    let mut s = 0.25;
    while s <= 64.0 {
        v.push(s);
        s *= 1.25;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_is_monotone() {
        let lats = vec![1.0, 2.0, 3.0, 4.0, 10.0];
        let curve = SloCurve::from_latencies(&lats, 1.0, &default_scales());
        for w in curve.attainment.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn min_scale_reaching_target() {
        let lats = vec![1.0, 1.0, 1.0, 1.0, 8.0];
        let curve = SloCurve::from_latencies(&lats, 1.0, &[1.0, 2.0, 4.0, 8.0, 16.0]);
        // 80% within scale 1; 95% needs the 8.0 outlier -> scale 8.
        assert_eq!(curve.min_scale_reaching(0.8), Some(1.0));
        assert_eq!(curve.min_scale_reaching(0.95), Some(8.0));
        assert_eq!(curve.min_scale_reaching(1.01), None);
    }

    #[test]
    fn exact_scale_matches_quantile() {
        let lats: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = SloCurve::exact_scale(&lats, 2.0, 0.95);
        assert!((s - 95.05 / 2.0).abs() < 0.5, "{s}");
    }

    #[test]
    fn latency_summary_percentiles_are_ordered() {
        let lats: Vec<f64> = (1..=200).map(|i| i as f64 / 10.0).collect();
        let s = LatencySummary::of(&lats);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        assert!((s.mean - 10.05).abs() < 1e-9);
        assert!((s.p50 - 10.05).abs() < 0.1);
        assert!((s.p99 - 19.8).abs() < 0.1, "{}", s.p99);
    }

    #[test]
    fn latency_summary_of_empty_is_zero() {
        assert_eq!(LatencySummary::of(&[]), LatencySummary::default());
    }

    #[test]
    fn adapt_counters_display_is_compact() {
        let c = AdaptCounters { drifts_detected: 2, reschedules: 1, plan_cache_hits: 1, hot_swaps: 2 };
        assert_eq!(c.to_string(), "drifts=2 reschedules=1 cache_hits=1 hot_swaps=2");
    }
}
