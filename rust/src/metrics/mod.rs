//! Evaluation metrics: SLO attainment curves and the paper's headline
//! "minimum SLO scale at 95% attainment" (§4.1), plus summary rows
//! shared by the figure harnesses.

use crate::util::stats;

/// An SLO attainment curve: attainment at each SLO scale multiple.
#[derive(Debug, Clone)]
pub struct SloCurve {
    /// The unit SLO in seconds (empirical single-request latency).
    pub unit: f64,
    pub scales: Vec<f64>,
    pub attainment: Vec<f64>,
}

impl SloCurve {
    /// Build from raw latencies; `unit` is the SLO base (the paper uses
    /// the system's average single-request processing latency).
    pub fn from_latencies(latencies: &[f64], unit: f64, scales: &[f64]) -> SloCurve {
        let attainment = scales
            .iter()
            .map(|s| stats::fraction_within(latencies, unit * s))
            .collect();
        SloCurve { unit, scales: scales.to_vec(), attainment }
    }

    /// Smallest listed scale reaching `target` attainment (None if the
    /// curve never gets there).
    pub fn min_scale_reaching(&self, target: f64) -> Option<f64> {
        self.scales
            .iter()
            .zip(&self.attainment)
            .find(|(_, &a)| a >= target)
            .map(|(&s, _)| s)
    }

    /// Exact scale where attainment hits `target` (by quantile), not
    /// limited to the listed grid.
    pub fn exact_scale(latencies: &[f64], unit: f64, target: f64) -> f64 {
        stats::percentile(latencies, target) / unit
    }
}

/// The standard SLO-scale grid used across figures.
pub fn default_scales() -> Vec<f64> {
    let mut v = Vec::new();
    let mut s = 0.25;
    while s <= 64.0 {
        v.push(s);
        s *= 1.25;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_is_monotone() {
        let lats = vec![1.0, 2.0, 3.0, 4.0, 10.0];
        let curve = SloCurve::from_latencies(&lats, 1.0, &default_scales());
        for w in curve.attainment.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn min_scale_reaching_target() {
        let lats = vec![1.0, 1.0, 1.0, 1.0, 8.0];
        let curve = SloCurve::from_latencies(&lats, 1.0, &[1.0, 2.0, 4.0, 8.0, 16.0]);
        // 80% within scale 1; 95% needs the 8.0 outlier -> scale 8.
        assert_eq!(curve.min_scale_reaching(0.8), Some(1.0));
        assert_eq!(curve.min_scale_reaching(0.95), Some(8.0));
        assert_eq!(curve.min_scale_reaching(1.01), None);
    }

    #[test]
    fn exact_scale_matches_quantile() {
        let lats: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = SloCurve::exact_scale(&lats, 2.0, 0.95);
        assert!((s - 95.05 / 2.0).abs() < 0.5, "{s}");
    }
}
