//! Closed-form p95 latency estimate for a replica pool.
//!
//! Model: the pool is a set of heterogeneous servers under
//! probabilistic routing proportional to capacity (so every replica
//! runs at the same utilization rho). Each replica's base service time
//! is the no-queueing request latency (prefill + decode iterations at
//! the replica's steady batch size); queueing inflates the tail by the
//! M/G/1-PS-like factor 1/(1-rho). p95 of a roughly lognormal latency
//! distribution sits ~1.6 sigma above the mean; we fold that and the
//! inflation into:
//!
//!   p95 ≈ base_p95 * (1 + K_QUEUE * rho / (1 - rho))
//!
//! with `base_p95 = base_mean * P95_OVER_MEAN`. The constants were
//! calibrated once against the discrete-event simulator (see
//! `analytic_matches_des_ordering` in `rust/tests/scheduler_integration.rs`) and
//! are deliberately simple: the scheduler only needs correct *ordering*
//! of candidate strategies; final plans are re-scored by the DES.

use crate::engine::PreemptionMode;
use crate::perf::{ReplicaModel, Workload, DEFAULT_PAGE_TOKENS};

/// Tail inflation applied on top of the mean under queueing.
pub const K_QUEUE: f64 = 0.8;
/// p95/mean ratio of the per-request latency distribution at low load.
pub const P95_OVER_MEAN: f64 = 1.2;
/// Latency assigned to infeasible/overloaded configurations (seconds).
pub const OVERLOAD_LATENCY: f64 = 1e6;
/// Pool utilization at which eviction overhead starts to appear: a
/// lightly loaded paged pool never preempts, a saturated one evicts
/// its newest co-runners as contexts grow.
pub const RHO_EVICT_ONSET: f64 = 0.6;
/// Eviction probability per request at full saturation (the ramp from
/// [`RHO_EVICT_ONSET`] is linear up to this).
pub const K_EVICT: f64 = 0.5;

/// Estimated p95 latency (seconds) of `replicas` serving `w`.
///
/// Returns [`OVERLOAD_LATENCY`] when the pool cannot sustain the
/// arrival rate (rho >= 1) or has no usable replica.
pub fn estimate_p95(replicas: &[ReplicaModel], w: &Workload) -> f64 {
    let groups: Vec<(&ReplicaModel, usize)> = replicas.iter().map(|r| (r, 1)).collect();
    estimate_p95_groups(&groups, w)
}

/// Execution-engine semantics the estimate should model: the prompt
/// prefix every request shares (pages held once via the engine's
/// prefix trie — raises the KV-limited steady batch and capacity) and
/// the prefill chunk budget (bounds TTFT via
/// [`ReplicaModel::ttft_chunked`]). [`EngineSemantics::default`] —
/// no sharing, unbounded chunk — reproduces the pre-engine estimate
/// exactly.
#[derive(Debug, Clone, Copy)]
pub struct EngineSemantics {
    /// Prompt tokens every request shares as a common prefix.
    pub shared_prefix_tokens: f64,
    /// Prefill tokens charged per iteration (`INFINITY` = whole-prompt
    /// admission).
    pub prefill_chunk: f64,
    /// Eviction discipline to charge overhead for under saturation:
    /// `None` models no preemption at all (the legacy estimate);
    /// `Some(Recompute)` charges a full re-prefill of the mean context
    /// per evicted victim; `Some(Swap)` charges the cheaper of that
    /// and the PCIe round trip of the victim's pages — the runtime
    /// scheduler's own per-victim comparison.
    pub preemption: Option<PreemptionMode>,
    /// Cross-tier speculative decoding on this pool: the decode leg
    /// collapses to `tokens / E` verify steps of expected progress
    /// `E = (1 - α^(k+1)) / (1 - α)` tokens each, every step also
    /// paying `k` draft tokens on the shallow tier — see
    /// [`spec_decode_cost`]. `None` reproduces the plain decode term
    /// bit-for-bit.
    pub speculation: Option<SpecSem>,
}

impl Default for EngineSemantics {
    fn default() -> Self {
        EngineSemantics {
            shared_prefix_tokens: 0.0,
            prefill_chunk: f64::INFINITY,
            preemption: None,
            speculation: None,
        }
    }
}

/// Speculative-decoding semantics for the closed-form estimate: the
/// scheduler's draft depth, the modeled per-position acceptance rate
/// α ∈ [0, 1], and the shallow tier's per-token draft cost (seconds).
#[derive(Debug, Clone, Copy)]
pub struct SpecSem {
    /// Tokens drafted per verify step.
    pub draft_k: usize,
    /// Probability a drafted token matches the verify model's choice.
    pub acceptance: f64,
    /// Seconds per drafted token on the draft tier's replica.
    pub draft_s_per_token: f64,
}

/// Cost of emitting `tokens` decode tokens at `iter_s` seconds per
/// verify/decode iteration. Without speculation this is exactly the
/// legacy `tokens * iter_s`. With speculation, each verify step emits
/// `E = (1 - α^(k+1)) / (1 - α)` tokens in expectation (the standard
/// speculative-decoding progress formula; `k + 1` at α = 1) and costs
/// one verify iteration plus `k` draft tokens. Speculation is charged
/// into service time only — the rho/capacity screen stays at the plain
/// decode rate, a deliberately conservative credit (the DES re-scores
/// final plans with the real discipline).
pub fn spec_decode_cost(tokens: f64, iter_s: f64, sp: Option<SpecSem>) -> f64 {
    match sp {
        None => tokens * iter_s,
        Some(s) => {
            let k = s.draft_k.max(1) as f64;
            let a = s.acceptance.clamp(0.0, 1.0);
            let e = if a >= 1.0 - 1e-12 {
                k + 1.0
            } else {
                (1.0 - a.powf(k + 1.0)) / (1.0 - a)
            };
            let steps = tokens / e.max(1.0);
            steps * (iter_s + k * s.draft_s_per_token)
        }
    }
}

/// Like [`estimate_p95`] but over (design, replica-count) groups, so
/// identical replicas are modeled once — the strategy-enumeration hot
/// path (EXPERIMENTS.md §Perf).
pub fn estimate_p95_groups(groups: &[(&ReplicaModel, usize)], w: &Workload) -> f64 {
    estimate_p95_groups_engine(groups, w, &EngineSemantics::default())
}

/// [`estimate_p95_groups`] under explicit [`EngineSemantics`]: the
/// feasibility screen and steady-batch clamp credit shared-prefix
/// pages, and the base latency charges chunk-limited TTFT — the same
/// page-lifetime and prefill-cost model the execution engine enforces
/// at runtime.
pub fn estimate_p95_groups_engine(
    groups: &[(&ReplicaModel, usize)],
    w: &Workload,
    sem: &EngineSemantics,
) -> f64 {
    if groups.is_empty() {
        return OVERLOAD_LATENCY;
    }
    // Page-granular memory feasibility (the inner scheduler's screen):
    // a design whose KV budget cannot hold even ONE full-length
    // request is infeasible, even though the request-count clamp would
    // round its fractional budget up to a single slot. (A shared
    // prefix does not help a single request — all its pages must be
    // resident either way.)
    for (r, _) in groups {
        if !r.fits_context(w.avg_input + w.avg_output) {
            return OVERLOAD_LATENCY;
        }
    }
    let capacities: Vec<f64> = groups
        .iter()
        .map(|(r, n)| r.capacity_shared(w, sem.shared_prefix_tokens) * *n as f64)
        .collect();
    let total_capacity: f64 = capacities.iter().sum();
    if total_capacity <= 0.0 {
        return OVERLOAD_LATENCY;
    }
    let rho = w.rate / total_capacity;
    if rho >= 0.995 {
        return OVERLOAD_LATENCY;
    }

    // Capacity-proportional routing: replica r sees rate rho * cap_r and
    // contributes its base latency weighted by its share of traffic.
    let mut base_mean = 0.0;
    for ((r, n), cap_group) in groups.iter().zip(&capacities) {
        if *cap_group <= 0.0 {
            continue;
        }
        // Per-replica share within the pool.
        let share = cap_group / total_capacity / *n as f64;
        // Steady batch at this replica under its share of the load:
        // b ≈ rate_r * avg_output * iter_time solved self-consistently;
        // a fixed-point iteration converges in a few steps.
        // Steady batch via Little's law: requests resident in decode =
        // arrival rate x decode residence time (avg_output iterations);
        // the fixed point converges in a few rounds.
        let rate_r = w.rate * share;
        // The batch clamp credits shared-prefix pages: a fleet sharing
        // a system prompt fits more concurrent sequences. Without
        // sharing the clamp is exactly the legacy `max_batch`.
        let b_max = if sem.shared_prefix_tokens > 0.0 {
            r.max_batch_shared(
                w.avg_input + w.avg_output,
                sem.shared_prefix_tokens,
                DEFAULT_PAGE_TOKENS,
            )
            .max(r.max_batch)
        } else {
            r.max_batch
        }
        .max(1);
        let mut b = 1usize;
        for _ in 0..8 {
            let iter = r.decode_iteration(b);
            let in_flight = rate_r * w.avg_output * iter;
            b = (in_flight.ceil() as usize).clamp(1, b_max);
        }
        // Chunk-limited TTFT (the engine interleaves one decode
        // iteration per prefill chunk) plus the remaining decode; a
        // shared prefix shrinks the prompt span actually prefilled.
        let prefilled = (w.avg_input - sem.shared_prefix_tokens).max(0.0);
        let mut base = r.ttft_chunked(prefilled, sem.prefill_chunk, b)
            + spec_decode_cost(
                (w.avg_output - 1.0).max(0.0),
                r.decode_iteration(b),
                sem.speculation,
            );
        // Preemption-overhead term: as the pool saturates, context
        // growth evicts newest co-runners; each victim pays either a
        // full recompute of the mean resident context or a PCIe round
        // trip of its pages, per the configured discipline. The onset
        // is rho-gated so lightly loaded pools charge nothing.
        if let Some(mode) = sem.preemption {
            let p_evict =
                ((rho - RHO_EVICT_ONSET) / (1.0 - RHO_EVICT_ONSET)).clamp(0.0, 1.0) * K_EVICT;
            if p_evict > 0.0 {
                let ctx = w.avg_input + w.avg_output;
                let recompute = r.prefill_latency(ctx);
                let swap = r.swap_round_trip_seconds(ctx, DEFAULT_PAGE_TOKENS);
                let victim_cost = match mode {
                    PreemptionMode::Recompute => recompute,
                    // Per-victim choice: the runtime swaps only when
                    // it is the cheaper move (and recomputes when the
                    // host budget is dry — which the budget-less
                    // min() here optimistically ignores).
                    PreemptionMode::Swap => swap.min(recompute),
                };
                base += p_evict * victim_cost;
            }
        }
        // Weight by the whole group's traffic share (share is per replica).
        base_mean += share * *n as f64 * base;
    }

    base_mean * P95_OVER_MEAN * (1.0 + K_QUEUE * rho / (1.0 - rho))
}

/// Closed-form p95 estimate for a *disaggregated* tier pool:
/// `n_prefill` replicas of design `rm` run chunked prefill and the
/// first token only, `n_decode` replicas run the remaining decode, and
/// every request pays the one-way interconnect transfer of its private
/// KV pages ([`ReplicaModel::migrate_seconds`]) on the decode side —
/// the same charge the runtime engine bills through its migrate hook.
/// The two legs queue independently (a handed-off sequence leaves the
/// prefill replica's batch entirely), so the estimate is the sum of
/// the two inflated stage latencies.
///
/// Returns [`OVERLOAD_LATENCY`] when either pool saturates — a split
/// must stand on both legs — or the design cannot hold the context.
pub fn estimate_p95_disagg(
    rm: &ReplicaModel,
    n_prefill: usize,
    n_decode: usize,
    w: &Workload,
    sem: &EngineSemantics,
) -> f64 {
    if n_prefill == 0 || n_decode == 0 {
        return OVERLOAD_LATENCY;
    }
    if !rm.fits_context(w.avg_input + w.avg_output) {
        return OVERLOAD_LATENCY;
    }
    let prefilled = (w.avg_input - sem.shared_prefix_tokens).max(0.0);

    // Prefill leg: compute-bound and short-lived — pages are released
    // at handoff, so the KV clamp never binds and the natural batch is
    // the number of prompts resident during one prefill.
    let svc_p = rm.prefill_latency(prefilled) + rm.decode_iteration(1);
    let cap_p = n_prefill as f64 / svc_p.max(1e-9);
    let rho_p = w.rate / cap_p;
    if rho_p >= 0.995 {
        return OVERLOAD_LATENCY;
    }
    let b_p = ((w.rate / n_prefill as f64 * svc_p).ceil() as usize).clamp(1, rm.max_batch.max(1));
    let ttft = rm.ttft_chunked(prefilled, sem.prefill_chunk, b_p);

    // Decode leg: memory-bound; the handoff pulls the private pages
    // (unshared prompt span plus the first generated token) over the
    // link before the sequence joins the decode batch.
    let dec_tokens = (w.avg_output - 1.0).max(0.0);
    let migrate = rm.migrate_seconds(prefilled + 1.0, DEFAULT_PAGE_TOKENS);
    let b_max = rm.max_batch.max(1);
    let rate_d = w.rate / n_decode as f64;
    let mut b = 1usize;
    for _ in 0..8 {
        let resident = rate_d * (dec_tokens * rm.decode_iteration(b) + migrate);
        b = (resident.ceil() as usize).clamp(1, b_max);
    }
    let svc_d = spec_decode_cost(dec_tokens, rm.decode_iteration(b), sem.speculation) + migrate;
    let cap_d =
        n_decode as f64 * b_max as f64 / (dec_tokens * rm.decode_iteration(b_max) + migrate).max(1e-9);
    let rho_d = w.rate / cap_d;
    if rho_d >= 0.995 {
        return OVERLOAD_LATENCY;
    }
    let mut decode_leg = svc_d;
    // Same rho-gated eviction term as the unified estimate, judged at
    // the decode pool's utilization (prefill replicas never evict —
    // their residents leave at the first token).
    if let Some(mode) = sem.preemption {
        let p_evict =
            ((rho_d - RHO_EVICT_ONSET) / (1.0 - RHO_EVICT_ONSET)).clamp(0.0, 1.0) * K_EVICT;
        if p_evict > 0.0 {
            let ctx = w.avg_input + w.avg_output;
            let recompute = rm.prefill_latency(ctx);
            let swap = rm.swap_round_trip_seconds(ctx, DEFAULT_PAGE_TOKENS);
            let victim_cost = match mode {
                PreemptionMode::Recompute => recompute,
                PreemptionMode::Swap => swap.min(recompute),
            };
            decode_leg += p_evict * victim_cost;
        }
    }
    ttft * P95_OVER_MEAN * (1.0 + K_QUEUE * rho_p / (1.0 - rho_p))
        + decode_leg * P95_OVER_MEAN * (1.0 + K_QUEUE * rho_d / (1.0 - rho_d))
}

/// Total sustainable request rate of a pool on workload `w`.
pub fn pool_capacity(replicas: &[ReplicaModel], w: &Workload) -> f64 {
    replicas.iter().map(|r| r.capacity(w)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::models::llama_cascade;

    fn pool(tp: usize, n: usize) -> Vec<ReplicaModel> {
        let m = &llama_cascade()[0];
        let c = ClusterSpec::paper_testbed();
        (0..n).map(|_| ReplicaModel::new(m, &c, tp, 1, 768.0)).collect()
    }

    fn w(rate: f64) -> Workload {
        Workload { rate, avg_input: 512.0, avg_output: 256.0 }
    }

    #[test]
    fn context_beyond_kv_budget_is_overloaded() {
        // A request stream whose mean context cannot fit one replica's
        // KV budget is infeasible regardless of its (tiny) rate.
        let p = pool(1, 1);
        let huge = Workload { rate: 0.01, avg_input: 1e9, avg_output: 1.0 };
        assert_eq!(estimate_p95(&p, &huge), OVERLOAD_LATENCY);
    }

    #[test]
    fn empty_pool_is_overloaded() {
        assert_eq!(estimate_p95(&[], &w(1.0)), OVERLOAD_LATENCY);
    }

    #[test]
    fn latency_increases_with_load() {
        let pool = pool(2, 2);
        let lo = estimate_p95(&pool, &w(0.5));
        let cap = pool_capacity(&pool, &w(0.5));
        let hi = estimate_p95(&pool, &w(cap * 0.9));
        assert!(hi > lo, "hi {hi} <= lo {lo}");
    }

    #[test]
    fn overload_detected() {
        let pool = pool(2, 1);
        let cap = pool_capacity(&pool, &w(1.0));
        assert_eq!(estimate_p95(&pool, &w(cap * 1.1)), OVERLOAD_LATENCY);
    }

    #[test]
    fn more_replicas_cut_latency_at_fixed_rate() {
        let rate = {
            let p = pool(2, 2);
            pool_capacity(&p, &w(1.0)) * 0.8
        };
        let two = estimate_p95(&pool(2, 2), &w(rate));
        let four = estimate_p95(&pool(2, 4), &w(rate));
        assert!(four < two);
    }

    #[test]
    fn engine_semantics_default_reproduces_legacy_estimate() {
        let p = pool(2, 2);
        let groups: Vec<(&ReplicaModel, usize)> = p.iter().map(|r| (r, 1)).collect();
        let legacy = estimate_p95_groups(&groups, &w(1.0));
        let explicit =
            estimate_p95_groups_engine(&groups, &w(1.0), &EngineSemantics::default());
        assert_eq!(legacy, explicit);
    }

    #[test]
    fn shared_prefix_credit_never_raises_the_estimate() {
        let p = pool(2, 2);
        let groups: Vec<(&ReplicaModel, usize)> = p.iter().map(|r| (r, 1)).collect();
        // Light enough that the steady-batch fixed point sits below
        // both clamps: the credit can only shrink prefill and rho.
        let cap = pool_capacity(&p, &w(1.0));
        let load = w(cap * 0.3);
        let plain = estimate_p95_groups(&groups, &load);
        let shared = estimate_p95_groups_engine(
            &groups,
            &load,
            &EngineSemantics { shared_prefix_tokens: 384.0, ..Default::default() },
        );
        assert!(shared <= plain, "sharing must not hurt: {shared} vs {plain}");
    }

    #[test]
    fn chunk_budget_charges_interleaved_iterations() {
        let p = pool(2, 1);
        let groups: Vec<(&ReplicaModel, usize)> = p.iter().map(|r| (r, 1)).collect();
        let light = w(0.05);
        let whole = estimate_p95_groups(&groups, &light);
        let chunked = estimate_p95_groups_engine(
            &groups,
            &light,
            &EngineSemantics { prefill_chunk: 128.0, ..Default::default() },
        );
        assert!(
            chunked > whole,
            "a 512-token prompt in 128-token chunks pays extra interleave: {chunked} vs {whole}"
        );
    }

    #[test]
    fn eviction_overhead_is_rho_gated_and_swap_never_loses() {
        let p = pool(2, 2);
        let groups: Vec<(&ReplicaModel, usize)> = p.iter().map(|r| (r, 1)).collect();
        let cap = pool_capacity(&p, &w(1.0));
        // Light load: below the onset, the term charges nothing.
        let light = w(cap * 0.3);
        let plain = estimate_p95_groups(&groups, &light);
        for mode in [PreemptionMode::Recompute, PreemptionMode::Swap] {
            let with = estimate_p95_groups_engine(
                &groups,
                &light,
                &EngineSemantics { preemption: Some(mode), ..Default::default() },
            );
            assert_eq!(with, plain, "below onset the estimate is untouched");
        }
        // Heavy load: overhead appears, and the swap discipline's
        // per-victim min() can only undercut recompute.
        let heavy = w(cap * 0.9);
        let none = estimate_p95_groups(&groups, &heavy);
        let rec = estimate_p95_groups_engine(
            &groups,
            &heavy,
            &EngineSemantics {
                preemption: Some(PreemptionMode::Recompute),
                ..Default::default()
            },
        );
        let swap = estimate_p95_groups_engine(
            &groups,
            &heavy,
            &EngineSemantics { preemption: Some(PreemptionMode::Swap), ..Default::default() },
        );
        assert!(rec > none, "saturation must charge eviction overhead");
        assert!(swap > none && swap <= rec, "swap {swap} vs recompute {rec}");
    }

    #[test]
    fn speculation_term_is_acceptance_monotone_and_none_is_exact_legacy() {
        let p = pool(2, 2);
        let groups: Vec<(&ReplicaModel, usize)> = p.iter().map(|r| (r, 1)).collect();
        let cap = pool_capacity(&p, &w(1.0));
        let load = w(cap * 0.4);
        let plain = estimate_p95_groups(&groups, &load);
        // Draft cost well under a verify iteration — the cross-tier
        // regime the outer sweep considers.
        let draft_s = p[0].decode_iteration(1) * 0.1;
        let spec = |acceptance| {
            estimate_p95_groups_engine(
                &groups,
                &load,
                &EngineSemantics {
                    speculation: Some(SpecSem { draft_k: 4, acceptance, draft_s_per_token: draft_s }),
                    ..Default::default()
                },
            )
        };
        let perfect = spec(1.0);
        let half = spec(0.5);
        let never = spec(0.0);
        assert!(
            perfect < half && half < never,
            "estimate must fall as acceptance rises: {perfect} vs {half} vs {never}"
        );
        assert!(perfect < plain, "k+1 tokens per verify step must beat plain decode");
        // α = 0: every step still emits the verify token but pays the
        // wasted drafts — strictly worse than not speculating.
        assert!(never > plain, "always-rejected drafts are pure overhead");
        // The closed-form progress at α = 1 is exactly k + 1.
        let cost1 = spec_decode_cost(100.0, 0.01, Some(SpecSem {
            draft_k: 4,
            acceptance: 1.0,
            draft_s_per_token: 0.0,
        }));
        assert!((cost1 - 100.0 / 5.0 * 0.01).abs() < 1e-12, "{cost1}");
        // And `None` is the legacy product, bit for bit.
        assert_eq!(spec_decode_cost(127.0, 0.013, None), 127.0 * 0.013);
    }

    #[test]
    fn disagg_estimate_honors_speculation_on_the_decode_leg() {
        let rm = &pool(2, 1)[0];
        let load = w(0.2);
        let plain = estimate_p95_disagg(rm, 1, 1, &load, &EngineSemantics::default());
        let spec = estimate_p95_disagg(
            rm,
            1,
            1,
            &load,
            &EngineSemantics {
                speculation: Some(SpecSem {
                    draft_k: 4,
                    acceptance: 0.9,
                    draft_s_per_token: rm.decode_iteration(1) * 0.1,
                }),
                ..Default::default()
            },
        );
        assert!(spec < plain, "speculation must cut the decode leg: {spec} vs {plain}");
    }

    #[test]
    fn estimate_is_finite_and_positive_under_light_load() {
        let p = pool(4, 2);
        let est = estimate_p95(&p, &w(0.1));
        assert!(est > 0.0 && est < 100.0, "{est}");
    }

    #[test]
    fn disagg_estimate_is_finite_and_load_monotone() {
        let rm = &pool(2, 1)[0];
        let sem = EngineSemantics::default();
        let lo = estimate_p95_disagg(rm, 1, 1, &w(0.1), &sem);
        assert!(lo > 0.0 && lo < 100.0, "{lo}");
        let hi = estimate_p95_disagg(rm, 1, 1, &w(0.5), &sem);
        assert!(hi >= lo, "more load cannot help: {hi} vs {lo}");
    }

    #[test]
    fn disagg_estimate_overloads_when_either_leg_fails() {
        let rm = &pool(2, 1)[0];
        let sem = EngineSemantics::default();
        assert_eq!(estimate_p95_disagg(rm, 0, 2, &w(0.1), &sem), OVERLOAD_LATENCY);
        assert_eq!(estimate_p95_disagg(rm, 2, 0, &w(0.1), &sem), OVERLOAD_LATENCY);
        // Saturate the whole tier: no split of a drowning pool stands.
        let cap = pool_capacity(&pool(2, 4), &w(1.0));
        assert_eq!(estimate_p95_disagg(rm, 2, 2, &w(cap * 2.0), &sem), OVERLOAD_LATENCY);
        // And an unholdable context is infeasible at any rate.
        let huge = Workload { rate: 0.01, avg_input: 1e9, avg_output: 8.0 };
        assert_eq!(estimate_p95_disagg(rm, 1, 1, &huge, &sem), OVERLOAD_LATENCY);
    }

    #[test]
    fn disagg_estimate_charges_the_migration_term() {
        // A shared prefix shrinks both the prefill span and the private
        // pages migrated at handoff, so the estimate must drop.
        let rm = &pool(2, 1)[0];
        let load = w(0.2);
        let solo = estimate_p95_disagg(rm, 1, 1, &load, &EngineSemantics::default());
        let shared = estimate_p95_disagg(
            rm,
            1,
            1,
            &load,
            &EngineSemantics { shared_prefix_tokens: 384.0, ..Default::default() },
        );
        assert!(shared < solo, "prefix sharing must cut the split's cost: {shared} vs {solo}");
        // The migration charge itself is visible: the decode leg alone
        // exceeds the pure decode time by at least one page transfer.
        let migrate = rm.migrate_seconds(load.avg_input + 1.0, DEFAULT_PAGE_TOKENS);
        assert!(migrate > 0.0);
    }
}
