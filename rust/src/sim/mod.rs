//! The inference latency simulator `S(w, f)` of §3.2.
//!
//! Two fidelities, used at different points of the bi-level scheduler:
//!
//! * [`analytic`] — closed-form queueing estimate of p95 latency for a
//!   replica pool under a workload. O(1); used inside the strategy
//!   enumeration loop where millions of candidate evaluations happen.
//! * [`des`] — discrete-event simulation of continuous batching
//!   (iteration-level admission, Sarathi-style prefill accounting,
//!   least-work dispatch across replicas). Used to score final
//!   candidate plans and to generate every end-to-end figure. Also
//!   simulates the paged-KV discipline (through the live engine's own
//!   [`crate::engine::IterationScheduler`]) and the whole-batch
//!   lockstep baseline — see [`des::DesMode`].
//!
//! The paper uses the ETH EASL "Scratchpad" simulator for the same
//! role; this module is the from-scratch substrate replacing it.

pub mod analytic;
pub mod des;

pub use analytic::estimate_p95;
pub use des::{
    simulate, simulate_disagg, simulate_disagg_traced, simulate_lockstep, simulate_mode,
    simulate_paged, simulate_paged_spec_traced, simulate_paged_traced, DesMode, SimOutcome,
    SimRequest, SpecSim,
};
