//! Discrete-event simulation of a replica pool with continuous
//! batching.
//!
//! Fidelity targets the behaviors the paper's evaluation depends on:
//!
//! * **iteration-level (continuous) batching** — requests join/leave
//!   the running batch between decode iterations (Orca/vLLM semantics);
//! * **prefill accounting** — admitting a request costs its prefill
//!   latency in the iteration where it is admitted (chunked-prefill
//!   approximation à la Sarathi);
//! * **least-outstanding-work dispatch** across a model type's
//!   replicas, matching the coordinator's real dispatcher;
//! * **KV-capacity limits** per replica (`ReplicaModel::max_batch`).
//!
//! Time is f64 seconds on a binary-heap event queue. The simulator is
//! deterministic given the request trace.
//!
//! Three execution disciplines are simulated ([`DesMode`]) so
//! schedule-time estimates can match whichever inner loop the live
//! server runs: the classic request-count-bounded continuous batching,
//! **paged** continuous batching driven by the *same*
//! [`IterationScheduler`] the live engine runs (KV pages, preemption,
//! FIFO admission — see [`crate::engine`]), and whole-batch
//! **lockstep** (the pre-engine worker discipline, kept as the
//! measurable baseline).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

use crate::engine::{
    draft_agrees, EngineRole, IterationScheduler, KvPool, PreemptionConfig, PreemptionMode,
    SpecTask,
};
use crate::obs::{
    emit_plan_events, emit_spec_events, Event as ObsEvent, EventKind as ObsEventKind,
    SpecResult, TraceRecorder,
};
use crate::perf::ReplicaModel;
use crate::util::stats;

/// Which inner-loop discipline the simulation models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesMode {
    /// Iteration-level continuous batching bounded by
    /// `ReplicaModel::max_batch` (request count) — the legacy default.
    Continuous,
    /// Continuous batching against a paged KV pool sized from the
    /// replica's memory budget; admission/preemption/chunked
    /// prefill/prefix claims run through the live engine's
    /// [`IterationScheduler`].
    Paged {
        /// Tokens per KV page.
        page_tokens: usize,
        /// Prefill token budget per iteration (`usize::MAX` =
        /// whole-prompt admission, the pre-chunking discipline).
        prefill_chunk: usize,
        /// Swap-to-host preemption: evicted victims park their KV in a
        /// host swap space sized from the replica's pinned budget
        /// ([`ReplicaModel::swap_pages_total`]) when the PCIe round
        /// trip beats recompute, and every page moved charges
        /// [`ReplicaModel::page_swap_seconds`] into the iteration —
        /// the same per-victim policy the live engine runs. `false` =
        /// the recompute-only discipline.
        swap: bool,
        /// Cross-tier speculative decoding: `Some` plans per-tick
        /// draft→verify tasks through the same [`IterationScheduler`]
        /// spec path the live engine runs (opportunistic draft-slack
        /// growth, verify at the planned batch, rejected-page
        /// rollback). Acceptance is the deterministic
        /// [`draft_agrees`] function of (sequence, position), which
        /// the deterministic live test backends share — the DES↔live
        /// pin extends to accepted/rejected draft-token counts.
        spec: Option<SpecSim>,
    },
    /// Whole-batch lockstep: admit a batch, run every request to
    /// completion serially, then admit again.
    Lockstep,
}

/// Speculative-decoding parameters for [`DesMode::Paged`]. All-integer
/// so [`DesMode`] stays `Copy + Eq`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecSim {
    /// Draft depth the scheduler plans per steady decoder (the live
    /// `IterationScheduler::set_spec_k` knob; per-task `k` still caps
    /// at the sequence's remaining budget).
    pub draft_k: usize,
    /// Disagreement modulus fed to [`draft_agrees`]: 0 = the draft
    /// model always agrees, m > 1 = roughly one position in m
    /// disagrees (per-sequence phase).
    pub agree_mod: u64,
    /// Draft-model cost charged into the tick, microseconds per
    /// drafted token.
    pub draft_us_per_token: u64,
}

/// One request as the simulator sees it.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimRequest {
    /// Arrival time, seconds from simulation start.
    pub arrival: f64,
    /// Prompt tokens.
    pub input_tokens: u32,
    /// Tokens to generate.
    pub output_tokens: u32,
    /// Prompt-identity group for [`DesMode::Paged`] prefix sharing
    /// (0 = unique prompt). Requests in one group share a
    /// `shared_tokens`-token prompt prefix; requests of a group must
    /// carry the same `input_tokens` when `shared_tokens` covers the
    /// whole prompt (identical re-serves).
    pub prefix_group: u64,
    /// Prompt tokens shared within `prefix_group` (page-aligned
    /// portions become claimable; a value >= `input_tokens` models an
    /// identical prompt, tail page included).
    pub shared_tokens: u32,
}

impl SimRequest {
    /// A unique-prompt request (no prefix sharing).
    pub fn new(arrival: f64, input_tokens: u32, output_tokens: u32) -> SimRequest {
        SimRequest { arrival, input_tokens, output_tokens, prefix_group: 0, shared_tokens: 0 }
    }
}

/// Aggregate outcome of a simulation run.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Per-request end-to-end latencies (completion - arrival), in
    /// completion order.
    pub latencies: Vec<f64>,
    /// Completed requests / makespan.
    pub throughput_rps: f64,
    /// Generated tokens / makespan.
    pub tokens_per_sec: f64,
    /// Total wall-clock of the run.
    pub makespan: f64,
    /// Mean busy fraction across replicas.
    pub utilization: f64,
    /// Absolute completion time per request, aligned with the input
    /// trace order (used to chain cascade tiers).
    pub completions: Vec<f64>,
    /// Max KV pages any one replica had allocated at once (0 outside
    /// [`DesMode::Paged`]).
    pub peak_pages: usize,
    /// Sequences preempted-and-requeued across the pool (0 outside
    /// [`DesMode::Paged`]).
    pub preemptions: usize,
    /// Prompt tokens served from shared prefix pages instead of being
    /// prefilled (0 outside [`DesMode::Paged`]).
    pub prefix_hit_tokens: usize,
    /// Copy-on-write page copies across the pool (0 outside
    /// [`DesMode::Paged`]).
    pub cow_copies: usize,
    /// Per-request engine-iteration index (1-based, per replica) at
    /// completion, aligned with the input trace — the tick-level pin
    /// the DES↔live-engine equivalence tests compare. Empty outside
    /// [`DesMode::Paged`].
    pub finish_iters: Vec<usize>,
    /// Sequences swapped out to host across the pool (0 unless
    /// [`DesMode::Paged`] ran with `swap`).
    pub swap_outs: usize,
    /// Sequences resumed from host swap across the pool.
    pub swap_ins: usize,
    /// KV pages moved across PCIe, both directions.
    pub swap_pages: usize,
    /// Per-request time-to-first-token (first token - arrival),
    /// aligned with the input trace order. Empty outside
    /// [`DesMode::Paged`] and [`simulate_disagg`].
    pub ttfts: Vec<f64>,
    /// Prefill→decode handoffs across the pool
    /// ([`simulate_disagg`] only).
    pub migrations: usize,
    /// Private KV pages that crossed the prefill→decode interconnect.
    pub migrate_pages: usize,
    /// Draft tokens accepted by verify steps across the pool (0 unless
    /// [`DesMode::Paged`] ran with `spec`).
    pub spec_accepted: usize,
    /// Draft tokens rejected (and their pages rolled back) across the
    /// pool.
    pub spec_rejected: usize,
}

impl SimOutcome {
    pub fn p95(&self) -> f64 {
        stats::percentile(&self.latencies, 0.95)
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.latencies, 0.50)
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.latencies)
    }

    /// Fraction of requests within `slo` seconds.
    pub fn slo_attainment(&self, slo: f64) -> f64 {
        stats::fraction_within(&self.latencies, slo)
    }

    /// p95 time-to-first-token (NaN when the run did not track TTFT).
    pub fn p95_ttft(&self) -> f64 {
        if self.ttfts.is_empty() {
            return f64::NAN;
        }
        stats::percentile(&self.ttfts, 0.95)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival(usize),
    IterDone(usize),
    /// Lockstep: one request of a replica's serial batch finished.
    ReqDone(usize, usize),
    /// Lockstep: a replica's whole batch finished; admit the next.
    BatchEnd(usize),
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed compare; ties broken by sequence for
        // determinism.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Clone, Copy)]
struct ActiveReq {
    id: usize,
    remaining: u32,
}

/// Least-outstanding-work dispatch shared by every simulation mode:
/// pick the replica with the smallest backlog normalized by its decode
/// speed, so faster replicas attract proportionally more work (matches
/// the coordinator's real dispatcher). `reps` yields each replica's
/// (backlog_tokens, model) in pool order.
fn pick_least_loaded(reps: impl Iterator<Item = (f64, &ReplicaModel)>) -> usize {
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    for (i, (backlog, model)) in reps.enumerate() {
        let speed = model.decode_throughput(model.max_batch).max(1e-9);
        let score = backlog / speed;
        if score < best_score {
            best_score = score;
            best = i;
        }
    }
    best
}

struct Replica<'a> {
    model: &'a ReplicaModel,
    queue: VecDeque<usize>,
    active: Vec<ActiveReq>,
    busy_until: f64,
    busy_time: f64,
    /// Outstanding work estimate (tokens), for dispatch.
    backlog_tokens: f64,
}

impl<'a> Replica<'a> {
    fn idle(&self, now: f64) -> bool {
        self.busy_until <= now
    }
}

/// Simulate `replicas` over `trace` under the given execution
/// discipline.
pub fn simulate_mode(
    replicas: &[ReplicaModel],
    trace: &[SimRequest],
    mode: DesMode,
) -> SimOutcome {
    match mode {
        DesMode::Continuous => simulate(replicas, trace),
        DesMode::Paged { page_tokens, prefill_chunk, swap, spec } => {
            simulate_paged_inner(replicas, trace, page_tokens, prefill_chunk, swap, spec, None)
        }
        DesMode::Lockstep => simulate_lockstep(replicas, trace),
    }
}

/// Run the simulation of `replicas` (one model type's pool) over a
/// request trace sorted by arrival time.
pub fn simulate(replicas: &[ReplicaModel], trace: &[SimRequest]) -> SimOutcome {
    assert!(!replicas.is_empty(), "simulate() with no replicas");
    let usable: Vec<&ReplicaModel> =
        replicas.iter().filter(|r| r.max_batch > 0).collect();
    assert!(!usable.is_empty(), "no replica has KV capacity");

    let mut pool: Vec<Replica> = usable
        .iter()
        .map(|m| Replica {
            model: m,
            queue: VecDeque::new(),
            active: Vec::new(),
            busy_until: 0.0,
            busy_time: 0.0,
            backlog_tokens: 0.0,
        })
        .collect();

    let mut heap = BinaryHeap::new();
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<Event>, seq: &mut u64, time: f64, kind: EventKind| {
        *seq += 1;
        heap.push(Event { time, seq: *seq, kind });
    };
    for (id, r) in trace.iter().enumerate() {
        push(&mut heap, &mut seq, r.arrival, EventKind::Arrival(id));
    }

    let mut latencies_by_id: Vec<f64> = vec![f64::NAN; trace.len()];
    let mut completions: Vec<f64> = vec![f64::NAN; trace.len()];
    let mut completion_order: Vec<usize> = Vec::with_capacity(trace.len());
    let mut completed = 0usize;
    let mut now = 0.0f64;
    let mut total_tokens = 0u64;

    while let Some(ev) = heap.pop() {
        now = ev.time;
        match ev.kind {
            EventKind::Arrival(id) => {
                let req = &trace[id];
                let best =
                    pick_least_loaded(pool.iter().map(|r| (r.backlog_tokens, r.model)));
                let rep = &mut pool[best];
                rep.queue.push_back(id);
                rep.backlog_tokens += req.output_tokens as f64
                    + req.input_tokens as f64 * 0.2; // prefill work weight
                if rep.idle(now) {
                    start_iteration(rep, best, now, trace, &mut heap, &mut seq);
                }
            }
            EventKind::IterDone(ri) => {
                let rep = &mut pool[ri];
                // Every active request produced one token.
                let mut still_active = Vec::with_capacity(rep.active.len());
                for mut a in rep.active.drain(..) {
                    a.remaining -= 1;
                    total_tokens += 1;
                    rep.backlog_tokens = (rep.backlog_tokens - 1.0).max(0.0);
                    if a.remaining == 0 {
                        latencies_by_id[a.id] = now - trace[a.id].arrival;
                        completions[a.id] = now;
                        completion_order.push(a.id);
                        completed += 1;
                    } else {
                        still_active.push(a);
                    }
                }
                rep.active = still_active;
                if !rep.active.is_empty() || !rep.queue.is_empty() {
                    start_iteration(rep, ri, now, trace, &mut heap, &mut seq);
                }
            }
            EventKind::ReqDone(..) | EventKind::BatchEnd(..) => {
                unreachable!("lockstep-only event in continuous simulation")
            }
        }
    }

    assert_eq!(completed, trace.len(), "simulation lost requests");
    let makespan = now.max(1e-9);
    let utilization = stats::mean(
        &pool.iter().map(|r| r.busy_time / makespan).collect::<Vec<_>>(),
    );
    SimOutcome {
        latencies: completion_order
            .iter()
            .map(|&id| latencies_by_id[id])
            .collect(),
        throughput_rps: completed as f64 / makespan,
        tokens_per_sec: total_tokens as f64 / makespan,
        makespan,
        utilization,
        completions,
        peak_pages: 0,
        preemptions: 0,
        prefix_hit_tokens: 0,
        cow_copies: 0,
        finish_iters: Vec::new(),
        swap_outs: 0,
        swap_ins: 0,
        swap_pages: 0,
        ttfts: Vec::new(),
        migrations: 0,
        migrate_pages: 0,
        spec_accepted: 0,
        spec_rejected: 0,
    }
}

fn start_iteration(
    rep: &mut Replica,
    idx: usize,
    now: f64,
    trace: &[SimRequest],
    heap: &mut BinaryHeap<Event>,
    seq: &mut u64,
) {
    // Admit waiting requests up to capacity; each admission charges its
    // prefill into this iteration (chunked-prefill approximation).
    let mut prefill_cost = 0.0;
    while rep.active.len() < rep.model.max_batch {
        let Some(id) = rep.queue.pop_front() else { break };
        prefill_cost += rep.model.prefill_latency(trace[id].input_tokens as f64);
        rep.active.push(ActiveReq { id, remaining: trace[id].output_tokens.max(1) });
    }
    debug_assert!(!rep.active.is_empty());
    // decode_iteration() already carries the pipeline-depth latency;
    // dividing by the capacity factor makes the DES's sustained
    // token rate equal ReplicaModel::decode_throughput (pipelined
    // microbatches recover stage concurrency).
    let iter = rep.model.decode_iteration(rep.active.len())
        / rep.model.pp_capacity_factor;
    let dt = iter + prefill_cost;
    rep.busy_until = now + dt;
    rep.busy_time += dt;
    *seq += 1;
    heap.push(Event { time: rep.busy_until, seq: *seq, kind: EventKind::IterDone(idx) });
    let _ = idx;
}

/// One request's service time under whole-batch lockstep: the request
/// runs alone (no batchmates amortize the per-iteration weight read),
/// exactly like a worker calling `TierBackend::generate` serially.
fn lockstep_service(m: &ReplicaModel, req: &SimRequest) -> f64 {
    m.prefill_latency(req.input_tokens as f64)
        + req.output_tokens.max(1) as f64 * m.decode_iteration(1)
}

/// Whole-batch lockstep simulation: a replica admits up to `max_batch`
/// requests, serves them serially to completion, and only then admits
/// more — the pre-engine server discipline, kept as the measurable
/// baseline for `cascadia bench`.
pub fn simulate_lockstep(replicas: &[ReplicaModel], trace: &[SimRequest]) -> SimOutcome {
    assert!(!replicas.is_empty(), "simulate() with no replicas");
    let usable: Vec<&ReplicaModel> =
        replicas.iter().filter(|r| r.max_batch > 0).collect();
    assert!(!usable.is_empty(), "no replica has KV capacity");

    struct Rep<'a> {
        model: &'a ReplicaModel,
        queue: VecDeque<usize>,
        busy: bool,
        busy_time: f64,
        backlog_tokens: f64,
    }

    /// Admit one batch and schedule its serial completions.
    fn start_batch(
        rep: &mut Rep<'_>,
        ri: usize,
        now: f64,
        trace: &[SimRequest],
        heap: &mut BinaryHeap<Event>,
        seq: &mut u64,
    ) {
        let mut t = now;
        let mut n = 0usize;
        while n < rep.model.max_batch {
            let Some(id) = rep.queue.pop_front() else { break };
            t += lockstep_service(rep.model, &trace[id]);
            *seq += 1;
            heap.push(Event { time: t, seq: *seq, kind: EventKind::ReqDone(ri, id) });
            n += 1;
        }
        if n == 0 {
            rep.busy = false;
            return;
        }
        rep.busy = true;
        rep.busy_time += t - now;
        *seq += 1;
        heap.push(Event { time: t, seq: *seq, kind: EventKind::BatchEnd(ri) });
    }

    let mut pool: Vec<Rep> = usable
        .iter()
        .map(|m| Rep {
            model: m,
            queue: VecDeque::new(),
            busy: false,
            busy_time: 0.0,
            backlog_tokens: 0.0,
        })
        .collect();

    let mut heap = BinaryHeap::new();
    let mut seq = 0u64;
    for (id, r) in trace.iter().enumerate() {
        seq += 1;
        heap.push(Event { time: r.arrival, seq, kind: EventKind::Arrival(id) });
    }

    let mut latencies_by_id: Vec<f64> = vec![f64::NAN; trace.len()];
    let mut completions: Vec<f64> = vec![f64::NAN; trace.len()];
    let mut completion_order: Vec<usize> = Vec::with_capacity(trace.len());
    let mut completed = 0usize;
    let mut now = 0.0f64;
    let mut total_tokens = 0u64;

    while let Some(ev) = heap.pop() {
        now = ev.time;
        match ev.kind {
            EventKind::Arrival(id) => {
                let req = &trace[id];
                let best =
                    pick_least_loaded(pool.iter().map(|r| (r.backlog_tokens, r.model)));
                let rep = &mut pool[best];
                rep.queue.push_back(id);
                rep.backlog_tokens +=
                    req.output_tokens as f64 + req.input_tokens as f64 * 0.2;
                if !rep.busy {
                    start_batch(rep, best, now, trace, &mut heap, &mut seq);
                }
            }
            EventKind::ReqDone(ri, id) => {
                let rep = &mut pool[ri];
                let out = trace[id].output_tokens.max(1) as u64;
                total_tokens += out;
                rep.backlog_tokens = (rep.backlog_tokens - out as f64).max(0.0);
                latencies_by_id[id] = now - trace[id].arrival;
                completions[id] = now;
                completion_order.push(id);
                completed += 1;
            }
            EventKind::BatchEnd(ri) => {
                let rep = &mut pool[ri];
                rep.busy = false;
                if !rep.queue.is_empty() {
                    start_batch(rep, ri, now, trace, &mut heap, &mut seq);
                }
            }
            EventKind::IterDone(_) => {
                unreachable!("continuous-only event in lockstep simulation")
            }
        }
    }

    assert_eq!(completed, trace.len(), "simulation lost requests");
    let makespan = now.max(1e-9);
    let utilization = stats::mean(
        &pool.iter().map(|r| r.busy_time / makespan).collect::<Vec<_>>(),
    );
    SimOutcome {
        latencies: completion_order.iter().map(|&id| latencies_by_id[id]).collect(),
        throughput_rps: completed as f64 / makespan,
        tokens_per_sec: total_tokens as f64 / makespan,
        makespan,
        utilization,
        completions,
        peak_pages: 0,
        preemptions: 0,
        prefix_hit_tokens: 0,
        cow_copies: 0,
        finish_iters: Vec::new(),
        swap_outs: 0,
        swap_ins: 0,
        swap_pages: 0,
        ttfts: Vec::new(),
        migrations: 0,
        migrate_pages: 0,
        spec_accepted: 0,
        spec_rejected: 0,
    }
}

/// Synthetic chained page hashes mirroring the engine's content-hash
/// chain: shared-prefix pages hash off the group key, divergent tails
/// off the request id, so trie hits reproduce exactly the sharing the
/// trace declares.
fn hash_mix(a: u64, b: u64) -> u64 {
    let mut x = a ^ b.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^ (x >> 27)
}

/// Page-hash chain for one trace request (empty when it shares
/// nothing — see [`SimRequest::prefix_group`]).
fn synthetic_hashes(id: usize, req: &SimRequest, page_tokens: usize) -> Vec<u64> {
    if req.prefix_group == 0 {
        return Vec::new();
    }
    let pages = (req.input_tokens.max(1) as usize).div_ceil(page_tokens);
    let shared_pages = if req.shared_tokens >= req.input_tokens {
        pages
    } else {
        (req.shared_tokens as usize) / page_tokens
    };
    (0..pages)
        .map(|i| {
            if i < shared_pages {
                hash_mix(req.prefix_group, i as u64)
            } else {
                hash_mix(0x5bd1_e995 ^ ((id as u64 + 1) << 20), i as u64)
            }
        })
        .collect()
}

/// Paged continuous-batching simulation: admission, growth, chunked
/// prefill, prefix claims, and preemption run through the live
/// engine's [`IterationScheduler`] against a [`KvPool`] sized from
/// each replica's memory budget ([`ReplicaModel::kv_pages_total`]) —
/// schedule-time estimates and the runtime share one page-lifetime and
/// prefill-cost policy by construction.
///
/// Requests with a [`SimRequest::prefix_group`] share synthetic page
/// hashes over their `shared_tokens` prompt prefix, so later
/// group-mates claim published pages exactly like the engine's trie
/// path (claimed tokens cost no prefill latency and no pages).
pub fn simulate_paged(
    replicas: &[ReplicaModel],
    trace: &[SimRequest],
    page_tokens: usize,
    prefill_chunk: usize,
    swap: bool,
) -> SimOutcome {
    simulate_paged_inner(replicas, trace, page_tokens, prefill_chunk, swap, None, None)
}

/// [`simulate_paged`] with trace emission: every iteration's plan
/// events ([`emit_plan_events`] — the same pure function the live
/// engine calls from `EngineCore::step`) and every retirement's
/// `finished` are recorded at **simulated** timestamps, shard =
/// replica index, `req` = trace index. This is the DES side of
/// `cascadia trace --diff`: identical plans produce identical
/// per-request event sequences on both sides by construction.
pub fn simulate_paged_traced(
    replicas: &[ReplicaModel],
    trace: &[SimRequest],
    page_tokens: usize,
    prefill_chunk: usize,
    swap: bool,
    recorder: &TraceRecorder,
) -> SimOutcome {
    simulate_paged_inner(
        replicas,
        trace,
        page_tokens,
        prefill_chunk,
        swap,
        None,
        Some(recorder),
    )
}

/// [`simulate_paged_traced`] with speculative decoding enabled —
/// `DesMode::Paged { spec: Some(..) }` plus trace emission (the spec
/// tasks emit the same `draft_iter`/`verify_accept`/`decode_iter`
/// vocabulary the live engine does, via the shared
/// [`emit_spec_events`]).
pub fn simulate_paged_spec_traced(
    replicas: &[ReplicaModel],
    trace: &[SimRequest],
    page_tokens: usize,
    prefill_chunk: usize,
    swap: bool,
    spec: Option<SpecSim>,
    recorder: &TraceRecorder,
) -> SimOutcome {
    simulate_paged_inner(
        replicas,
        trace,
        page_tokens,
        prefill_chunk,
        swap,
        spec,
        Some(recorder),
    )
}

fn simulate_paged_inner(
    replicas: &[ReplicaModel],
    trace: &[SimRequest],
    page_tokens: usize,
    prefill_chunk: usize,
    swap: bool,
    spec: Option<SpecSim>,
    recorder: Option<&TraceRecorder>,
) -> SimOutcome {
    assert!(!replicas.is_empty(), "simulate() with no replicas");
    let page_tokens = page_tokens.max(1);
    let usable: Vec<&ReplicaModel> = replicas
        .iter()
        .filter(|r| r.max_batch > 0 && r.kv_pages_total(page_tokens) > 0)
        .collect();
    assert!(!usable.is_empty(), "no replica has KV capacity");

    struct Rep<'a> {
        model: &'a ReplicaModel,
        sched: IterationScheduler,
        /// Sequences producing one token in the in-flight iteration.
        inflight: Vec<u64>,
        /// Draft→verify tasks of the in-flight iteration (disjoint
        /// from `inflight` — a sequence never decodes and speculates
        /// in one tick).
        inflight_spec: Vec<SpecTask>,
        /// Planned batch of the in-flight iteration (spec tasks
        /// included), for the exec-side event emission.
        inflight_batch: usize,
        busy: bool,
        busy_time: f64,
        backlog_tokens: f64,
        /// Seconds per KV page moved across PCIe (swap accounting).
        swap_s_per_page: f64,
        /// Speculation parameters (`None` = plain decode).
        spec: Option<SpecSim>,
        /// Iterations started (the tick counter finish_iters records).
        iters: usize,
    }

    /// Plan and launch one iteration: the tick charges one decode
    /// iteration at the planned batch plus the prefill latency of the
    /// tick's chunks (prefix-claimed tokens never appear in a chunk
    /// and therefore cost nothing — the engine's fast path) plus the
    /// PCIe time of every KV page the plan swapped in either
    /// direction.
    fn start_iter(
        rep: &mut Rep<'_>,
        ri: usize,
        now: f64,
        heap: &mut BinaryHeap<Event>,
        seq: &mut u64,
        recorder: Option<&TraceRecorder>,
    ) {
        let plan = rep.sched.next_iteration();
        if let Some(rec) = recorder {
            // DES sequence ids ARE the global request ids (trace
            // index), so the key map is the identity.
            emit_plan_events(rec, ri, now, 0, &plan, |id| id);
        }
        if plan.batch() == 0 {
            rep.busy = false;
            rep.inflight.clear();
            rep.inflight_spec.clear();
            return;
        }
        rep.iters += 1;
        let prefill_cost: f64 = plan
            .prefill
            .iter()
            .map(|c| rep.model.prefill_latency(c.len as f64))
            .sum();
        let swap_cost = (plan.swap_out_pages() + plan.swap_in_pages()) as f64
            * rep.swap_s_per_page;
        // Drafting happens on the shallow tier before the verify step;
        // the verify itself rides the decode iteration at the planned
        // batch (one fused multi-token step — the same charge the
        // live calibrated backend makes).
        let draft_cost = match rep.spec {
            Some(sp) => {
                plan.spec.iter().map(|t| t.k).sum::<usize>() as f64
                    * sp.draft_us_per_token as f64
                    * 1e-6
            }
            None => 0.0,
        };
        rep.inflight = plan.producers();
        rep.inflight_spec = plan.spec.clone();
        rep.inflight_batch = plan.batch();
        let iter = rep.model.decode_iteration(plan.batch())
            / rep.model.pp_capacity_factor;
        let dt = iter + prefill_cost + swap_cost + draft_cost;
        rep.busy = true;
        rep.busy_time += dt;
        *seq += 1;
        heap.push(Event { time: now + dt, seq: *seq, kind: EventKind::IterDone(ri) });
    }

    let mut pool: Vec<Rep> = usable
        .iter()
        .map(|m| {
            let mut sched = IterationScheduler::new(
                KvPool::new(m.kv_pages_total(page_tokens), page_tokens),
                m.max_batch.max(1),
            );
            sched.set_prefill_chunk(prefill_chunk);
            if let Some(sp) = spec {
                sched.set_spec_k(sp.draft_k);
            }
            if swap {
                sched.set_preemption(PreemptionConfig {
                    mode: PreemptionMode::Swap,
                    swap_pages: m.swap_pages_total(page_tokens),
                    prefill_s_per_token: m.prefill_seconds_per_token(),
                    swap_s_per_page: m.page_swap_seconds(page_tokens),
                    page_bytes: m.kv_page_bytes(page_tokens),
                });
            }
            Rep {
                model: m,
                sched,
                inflight: Vec::new(),
                inflight_spec: Vec::new(),
                inflight_batch: 0,
                busy: false,
                busy_time: 0.0,
                backlog_tokens: 0.0,
                swap_s_per_page: m.page_swap_seconds(page_tokens),
                spec,
                iters: 0,
            }
        })
        .collect();

    let mut heap = BinaryHeap::new();
    let mut seq = 0u64;
    for (id, r) in trace.iter().enumerate() {
        seq += 1;
        heap.push(Event { time: r.arrival, seq, kind: EventKind::Arrival(id) });
    }

    let mut latencies_by_id: Vec<f64> = vec![f64::NAN; trace.len()];
    let mut completions: Vec<f64> = vec![f64::NAN; trace.len()];
    let mut finish_iters: Vec<usize> = vec![0; trace.len()];
    // First-token time per request, for the traced `finished` TTFT.
    let mut first_tok: Vec<f64> = vec![f64::NAN; trace.len()];
    // Tokens emitted so far per request — mirrors the scheduler's
    // `generated` and feeds `draft_agrees` position-keyed acceptance.
    let mut gen_count: Vec<usize> = vec![0; trace.len()];
    let mut completion_order: Vec<usize> = Vec::with_capacity(trace.len());
    let mut completed = 0usize;
    let mut now = 0.0f64;
    let mut total_tokens = 0u64;

    while let Some(ev) = heap.pop() {
        now = ev.time;
        match ev.kind {
            EventKind::Arrival(id) => {
                let req = &trace[id];
                let best =
                    pick_least_loaded(pool.iter().map(|r| (r.backlog_tokens, r.model)));
                let rep = &mut pool[best];
                rep.sched.enqueue_shared(
                    id as u64,
                    req.input_tokens as usize,
                    req.output_tokens.max(1) as usize,
                    synthetic_hashes(id, req, page_tokens),
                );
                rep.backlog_tokens +=
                    req.output_tokens as f64 + req.input_tokens as f64 * 0.2;
                if !rep.busy {
                    start_iter(rep, best, now, &mut heap, &mut seq, recorder);
                }
            }
            EventKind::IterDone(ri) => {
                let rep = &mut pool[ri];
                let ids = std::mem::take(&mut rep.inflight);
                let spec_tasks = std::mem::take(&mut rep.inflight_spec);
                total_tokens += ids.len() as u64;
                for id in ids {
                    rep.backlog_tokens = (rep.backlog_tokens - 1.0).max(0.0);
                    let uid = id as usize;
                    gen_count[uid] += 1;
                    if first_tok[uid].is_nan() {
                        first_tok[uid] = now;
                    }
                    if rep.sched.advance(id) {
                        rep.sched.retire(id);
                        latencies_by_id[uid] = now - trace[uid].arrival;
                        completions[uid] = now;
                        finish_iters[uid] = rep.iters;
                        completion_order.push(uid);
                        completed += 1;
                        if let Some(rec) = recorder {
                            rec.emit(
                                ri,
                                ObsEvent {
                                    fa: first_tok[uid] - trace[uid].arrival,
                                    fb: now - trace[uid].arrival,
                                    ..ObsEvent::at(now, id, 0, ObsEventKind::Finished)
                                },
                            );
                        }
                    }
                }
                // Draft→verify tasks: acceptance is the shared pure
                // function of (sequence, position) — position j of the
                // draft probes output index `generated + j` — and the
                // scheduler rolls rejected draft slack back exactly
                // like the live engine's `advance_spec`.
                let mut spec_results: Vec<SpecResult> =
                    Vec::with_capacity(spec_tasks.len());
                let agree_mod = rep.spec.map(|s| s.agree_mod).unwrap_or(0);
                for task in &spec_tasks {
                    let uid = task.id as usize;
                    let mut accepted = 0usize;
                    while accepted < task.k
                        && draft_agrees(task.id, gen_count[uid] + accepted, agree_mod)
                    {
                        accepted += 1;
                    }
                    spec_results.push(SpecResult {
                        id: task.id,
                        drafted: task.k,
                        accepted,
                        emitted: accepted + 1,
                    });
                }
                // Exec-side events precede any `finished` of the same
                // tick, matching the live `EngineCore::step` order.
                if let Some(rec) = recorder {
                    if !spec_results.is_empty() {
                        emit_spec_events(
                            rec,
                            ri,
                            now,
                            0,
                            rep.inflight_batch,
                            &spec_results,
                            |id| id,
                        );
                    }
                }
                for r in spec_results {
                    let uid = r.id as usize;
                    total_tokens += r.emitted as u64;
                    gen_count[uid] += r.emitted;
                    rep.backlog_tokens = (rep.backlog_tokens - r.emitted as f64).max(0.0);
                    if rep.sched.advance_spec(r.id, r.drafted, r.emitted) {
                        rep.sched.retire(r.id);
                        latencies_by_id[uid] = now - trace[uid].arrival;
                        completions[uid] = now;
                        finish_iters[uid] = rep.iters;
                        completion_order.push(uid);
                        completed += 1;
                        if let Some(rec) = recorder {
                            rec.emit(
                                ri,
                                ObsEvent {
                                    fa: first_tok[uid] - trace[uid].arrival,
                                    fb: now - trace[uid].arrival,
                                    ..ObsEvent::at(now, r.id, 0, ObsEventKind::Finished)
                                },
                            );
                        }
                    }
                }
                if rep.sched.n_seqs() > 0 {
                    start_iter(rep, ri, now, &mut heap, &mut seq, recorder);
                } else {
                    rep.busy = false;
                }
            }
            EventKind::ReqDone(..) | EventKind::BatchEnd(..) => {
                unreachable!("lockstep-only event in paged simulation")
            }
        }
    }

    assert_eq!(completed, trace.len(), "simulation lost requests");
    let makespan = now.max(1e-9);
    let utilization = stats::mean(
        &pool.iter().map(|r| r.busy_time / makespan).collect::<Vec<_>>(),
    );
    SimOutcome {
        latencies: completion_order.iter().map(|&id| latencies_by_id[id]).collect(),
        throughput_rps: completed as f64 / makespan,
        tokens_per_sec: total_tokens as f64 / makespan,
        makespan,
        utilization,
        completions,
        peak_pages: pool.iter().map(|r| r.sched.pool().peak_in_use()).max().unwrap_or(0),
        preemptions: pool.iter().map(|r| r.sched.preemptions() as usize).sum(),
        prefix_hit_tokens: pool
            .iter()
            .map(|r| r.sched.prefix_hit_tokens() as usize)
            .sum(),
        cow_copies: pool.iter().map(|r| r.sched.pool().cow_copies() as usize).sum(),
        finish_iters,
        swap_outs: pool.iter().map(|r| r.sched.swap_counts().0 as usize).sum(),
        swap_ins: pool.iter().map(|r| r.sched.swap_counts().1 as usize).sum(),
        swap_pages: pool.iter().map(|r| r.sched.swap_counts().2 as usize).sum(),
        ttfts: first_tok
            .iter()
            .zip(trace.iter())
            .map(|(t, r)| t - r.arrival)
            .collect(),
        migrations: 0,
        migrate_pages: 0,
        spec_accepted: pool.iter().map(|r| r.sched.spec_counts().0 as usize).sum(),
        spec_rejected: pool.iter().map(|r| r.sched.spec_counts().1 as usize).sum(),
    }
}

/// Disaggregated prefill/decode simulation: `prefill` replicas run the
/// engine scheduler in [`EngineRole::Prefill`] (chunked prefill, first
/// token, then the stage -1 handoff), `decode` replicas run
/// [`EngineRole::Decode`] and admit handoffs through the scheduler's
/// migrate queue (stage 1.75), re-claiming shared prefix pages from
/// their own trie so only private pages cross the interconnect. Every
/// page received charges [`ReplicaModel::page_migrate_seconds`] into
/// the receiving iteration — one-way, on the decode side, exactly
/// where the live engine's `StepBackend::migrate` hook bills it — so
/// the DES↔live pin extends to migration counts and finish ticks.
///
/// Handoffs route to the decode replica with the fewest resident plus
/// in-flight KV pages (ties to the lowest index), mirroring the live
/// [`crate::engine::MigrationHub`] policy. Arrivals dispatch
/// least-outstanding-work across the prefill replicas only.
pub fn simulate_disagg(
    prefill: &[ReplicaModel],
    decode: &[ReplicaModel],
    trace: &[SimRequest],
    page_tokens: usize,
    prefill_chunk: usize,
    swap: bool,
) -> SimOutcome {
    simulate_disagg_inner(prefill, decode, trace, page_tokens, prefill_chunk, swap, None)
}

/// [`simulate_disagg`] with trace emission: plan events from both
/// sides of the handoff (including `migrate_out`/`migrate_in`) and
/// every retirement's `finished` are recorded at simulated timestamps.
/// Shards number prefill replicas first, then decode replicas.
pub fn simulate_disagg_traced(
    prefill: &[ReplicaModel],
    decode: &[ReplicaModel],
    trace: &[SimRequest],
    page_tokens: usize,
    prefill_chunk: usize,
    swap: bool,
    recorder: &TraceRecorder,
) -> SimOutcome {
    simulate_disagg_inner(
        prefill,
        decode,
        trace,
        page_tokens,
        prefill_chunk,
        swap,
        Some(recorder),
    )
}

fn simulate_disagg_inner(
    prefill: &[ReplicaModel],
    decode: &[ReplicaModel],
    trace: &[SimRequest],
    page_tokens: usize,
    prefill_chunk: usize,
    swap: bool,
    recorder: Option<&TraceRecorder>,
) -> SimOutcome {
    assert!(!prefill.is_empty(), "disagg simulation with no prefill replicas");
    assert!(!decode.is_empty(), "disagg simulation with no decode replicas");
    let page_tokens = page_tokens.max(1);
    for r in prefill.iter().chain(decode.iter()) {
        assert!(
            r.max_batch > 0 && r.kv_pages_total(page_tokens) > 0,
            "disagg replica has no KV capacity"
        );
    }

    struct Rep<'a> {
        model: &'a ReplicaModel,
        sched: IterationScheduler,
        /// Sequences producing one token in the in-flight iteration.
        inflight: Vec<u64>,
        busy: bool,
        busy_time: f64,
        backlog_tokens: f64,
        swap_s_per_page: f64,
        /// Seconds per KV page pulled over the replica-pair link.
        migrate_s_per_page: f64,
        /// Iterations started (the tick counter finish_iters records).
        iters: usize,
        /// Handoffs delivered but not yet admitted (decode side):
        /// their page counts stay in the load metric until stage 1.75
        /// lands them.
        pending: Vec<(u64, usize)>,
    }

    /// Plan and launch one iteration; returns the plan's handoffs for
    /// the caller to deliver (decode replicas never hand off). The
    /// tick charges one decode iteration at the planned batch plus
    /// chunk prefill, PCIe swap traffic, and the one-way transit of
    /// every migrated-in page.
    fn plan_one(
        rep: &mut Rep<'_>,
        ri: usize,
        now: f64,
        heap: &mut BinaryHeap<Event>,
        seq: &mut u64,
        recorder: Option<&TraceRecorder>,
    ) -> Vec<(u64, usize)> {
        let plan = rep.sched.next_iteration();
        if let Some(rec) = recorder {
            // DES sequence ids ARE the global request ids (trace
            // index), so the key map is the identity.
            emit_plan_events(rec, ri, now, 0, &plan, |id| id);
        }
        let handoffs = plan.migrated_out.clone();
        for (id, _) in &plan.migrated_in {
            if let Some(at) = rep.pending.iter().position(|(q, _)| q == id) {
                rep.pending.remove(at);
            }
        }
        if plan.batch() == 0 {
            rep.busy = false;
            rep.inflight.clear();
            return handoffs;
        }
        rep.iters += 1;
        let prefill_cost: f64 = plan
            .prefill
            .iter()
            .map(|c| rep.model.prefill_latency(c.len as f64))
            .sum();
        let swap_cost = (plan.swap_out_pages() + plan.swap_in_pages()) as f64
            * rep.swap_s_per_page;
        let migrate_cost = plan.migrate_in_pages() as f64 * rep.migrate_s_per_page;
        rep.inflight = plan.producers();
        let iter = rep.model.decode_iteration(plan.batch())
            / rep.model.pp_capacity_factor;
        let dt = iter + prefill_cost + swap_cost + migrate_cost;
        rep.busy = true;
        rep.busy_time += dt;
        *seq += 1;
        heap.push(Event { time: now + dt, seq: *seq, kind: EventKind::IterDone(ri) });
        handoffs
    }

    let n_prefill = prefill.len();
    let mut pool: Vec<Rep> = prefill
        .iter()
        .map(|m| (m, EngineRole::Prefill))
        .chain(decode.iter().map(|m| (m, EngineRole::Decode)))
        .map(|(m, role)| {
            let mut sched = IterationScheduler::new(
                KvPool::new(m.kv_pages_total(page_tokens), page_tokens),
                m.max_batch.max(1),
            );
            sched.set_prefill_chunk(prefill_chunk);
            sched.set_role(role);
            if swap {
                sched.set_preemption(PreemptionConfig {
                    mode: PreemptionMode::Swap,
                    swap_pages: m.swap_pages_total(page_tokens),
                    prefill_s_per_token: m.prefill_seconds_per_token(),
                    swap_s_per_page: m.page_swap_seconds(page_tokens),
                    page_bytes: m.kv_page_bytes(page_tokens),
                });
            }
            Rep {
                model: m,
                sched,
                inflight: Vec::new(),
                busy: false,
                busy_time: 0.0,
                backlog_tokens: 0.0,
                swap_s_per_page: m.page_swap_seconds(page_tokens),
                migrate_s_per_page: m.page_migrate_seconds(page_tokens),
                iters: 0,
                pending: Vec::new(),
            }
        })
        .collect();

    let mut heap = BinaryHeap::new();
    let mut seq = 0u64;
    for (id, r) in trace.iter().enumerate() {
        seq += 1;
        heap.push(Event { time: r.arrival, seq, kind: EventKind::Arrival(id) });
    }

    let mut latencies_by_id: Vec<f64> = vec![f64::NAN; trace.len()];
    let mut completions: Vec<f64> = vec![f64::NAN; trace.len()];
    let mut finish_iters: Vec<usize> = vec![0; trace.len()];
    let mut first_tok: Vec<f64> = vec![f64::NAN; trace.len()];
    // Tokens generated so far per request — the `generated` the decode
    // side resumes from at handoff.
    let mut gen_count: Vec<usize> = vec![0; trace.len()];
    let mut completion_order: Vec<usize> = Vec::with_capacity(trace.len());
    let mut completed = 0usize;
    let mut now = 0.0f64;
    let mut total_tokens = 0u64;

    // Route each handoff to the least-loaded live decode replica and
    // wake it if idle — the MigrationHub policy, instantaneous here;
    // the transit time itself is charged into the receiving iteration.
    let deliver = |pool: &mut Vec<Rep>,
                   handoffs: Vec<(u64, usize)>,
                   now: f64,
                   heap: &mut BinaryHeap<Event>,
                   seq: &mut u64,
                   gen_count: &[usize]| {
        for (id, pages) in handoffs {
            let uid = id as usize;
            let req = &trace[uid];
            let mut best = n_prefill;
            let mut best_load = usize::MAX;
            for di in n_prefill..pool.len() {
                let load = pool[di].sched.pool().in_use()
                    + pool[di].pending.iter().map(|&(_, p)| p).sum::<usize>();
                if load < best_load {
                    best_load = load;
                    best = di;
                }
            }
            let d = &mut pool[best];
            d.sched.enqueue_prefilled(
                id,
                req.input_tokens.max(1) as usize,
                gen_count[uid],
                req.output_tokens.max(1) as usize,
                synthetic_hashes(uid, req, page_tokens),
            );
            d.pending.push((id, pages));
            d.backlog_tokens += (req.output_tokens.max(1) as usize)
                .saturating_sub(gen_count[uid]) as f64;
            if !d.busy {
                let h = plan_one(d, best, now, heap, seq, recorder);
                debug_assert!(h.is_empty(), "decode replicas never hand off");
                let _ = h;
            }
        }
    };

    while let Some(ev) = heap.pop() {
        now = ev.time;
        match ev.kind {
            EventKind::Arrival(id) => {
                let req = &trace[id];
                let best = pick_least_loaded(
                    pool[..n_prefill].iter().map(|r| (r.backlog_tokens, r.model)),
                );
                let rep = &mut pool[best];
                rep.sched.enqueue_shared(
                    id as u64,
                    req.input_tokens as usize,
                    req.output_tokens.max(1) as usize,
                    synthetic_hashes(id, req, page_tokens),
                );
                rep.backlog_tokens +=
                    req.output_tokens as f64 + req.input_tokens as f64 * 0.2;
                if !rep.busy {
                    let h = plan_one(rep, best, now, &mut heap, &mut seq, recorder);
                    deliver(&mut pool, h, now, &mut heap, &mut seq, &gen_count);
                }
            }
            EventKind::IterDone(ri) => {
                let rep = &mut pool[ri];
                let ids = std::mem::take(&mut rep.inflight);
                total_tokens += ids.len() as u64;
                for id in ids {
                    let rep = &mut pool[ri];
                    rep.backlog_tokens = (rep.backlog_tokens - 1.0).max(0.0);
                    let uid = id as usize;
                    gen_count[uid] += 1;
                    if first_tok[uid].is_nan() {
                        first_tok[uid] = now;
                    }
                    if rep.sched.advance(id) {
                        rep.sched.retire(id);
                        latencies_by_id[uid] = now - trace[uid].arrival;
                        completions[uid] = now;
                        finish_iters[uid] = rep.iters;
                        completion_order.push(uid);
                        completed += 1;
                        if let Some(rec) = recorder {
                            rec.emit(
                                ri,
                                ObsEvent {
                                    fa: first_tok[uid] - trace[uid].arrival,
                                    fb: now - trace[uid].arrival,
                                    ..ObsEvent::at(now, id, 0, ObsEventKind::Finished)
                                },
                            );
                        }
                    }
                }
                if pool[ri].sched.n_seqs() > 0 {
                    let h = plan_one(&mut pool[ri], ri, now, &mut heap, &mut seq, recorder);
                    deliver(&mut pool, h, now, &mut heap, &mut seq, &gen_count);
                } else {
                    pool[ri].busy = false;
                }
            }
            EventKind::ReqDone(..) | EventKind::BatchEnd(..) => {
                unreachable!("lockstep-only event in disaggregated simulation")
            }
        }
    }

    assert_eq!(completed, trace.len(), "disaggregated simulation lost requests");
    let handed_off: usize =
        pool[..n_prefill].iter().map(|r| r.sched.migrate_counts().0 as usize).sum();
    let migrations: usize =
        pool[n_prefill..].iter().map(|r| r.sched.migrate_counts().1 as usize).sum();
    assert_eq!(migrations, handed_off, "every handoff lands exactly once");
    let migrate_pages: usize =
        pool[n_prefill..].iter().map(|r| r.sched.migrate_counts().3 as usize).sum();
    let makespan = now.max(1e-9);
    let utilization = stats::mean(
        &pool.iter().map(|r| r.busy_time / makespan).collect::<Vec<_>>(),
    );
    SimOutcome {
        latencies: completion_order.iter().map(|&id| latencies_by_id[id]).collect(),
        throughput_rps: completed as f64 / makespan,
        tokens_per_sec: total_tokens as f64 / makespan,
        makespan,
        utilization,
        completions,
        peak_pages: pool.iter().map(|r| r.sched.pool().peak_in_use()).max().unwrap_or(0),
        preemptions: pool.iter().map(|r| r.sched.preemptions() as usize).sum(),
        prefix_hit_tokens: pool
            .iter()
            .map(|r| r.sched.prefix_hit_tokens() as usize)
            .sum(),
        cow_copies: pool.iter().map(|r| r.sched.pool().cow_copies() as usize).sum(),
        finish_iters,
        swap_outs: pool.iter().map(|r| r.sched.swap_counts().0 as usize).sum(),
        swap_ins: pool.iter().map(|r| r.sched.swap_counts().1 as usize).sum(),
        swap_pages: pool.iter().map(|r| r.sched.swap_counts().2 as usize).sum(),
        ttfts: first_tok
            .iter()
            .zip(trace.iter())
            .map(|(t, r)| t - r.arrival)
            .collect(),
        migrations,
        migrate_pages,
        spec_accepted: 0,
        spec_rejected: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::models::llama_cascade;
    use crate::perf::Workload;
    use crate::util::rng::Rng;

    fn replica(tp: usize) -> ReplicaModel {
        let m = &llama_cascade()[0];
        let c = ClusterSpec::paper_testbed();
        ReplicaModel::new(m, &c, tp, 1, 768.0)
    }

    fn poisson_trace(rate: f64, n: usize, seed: u64) -> Vec<SimRequest> {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += rng.exp(rate);
                SimRequest::new(t, 512, 128)
            })
            .collect()
    }

    #[test]
    fn completes_all_requests() {
        let pool = vec![replica(2)];
        let trace = poisson_trace(1.0, 200, 1);
        let out = simulate(&pool, &trace);
        assert_eq!(out.latencies.len(), 200);
        assert!(out.latencies.iter().all(|l| *l > 0.0 && l.is_finite()));
    }

    #[test]
    fn latency_grows_with_load() {
        let pool = vec![replica(2)];
        let cap = pool[0].capacity(&Workload { rate: 1.0, avg_input: 512.0, avg_output: 128.0 });
        let light = simulate(&pool, &poisson_trace(cap * 0.3, 400, 2));
        let heavy = simulate(&pool, &poisson_trace(cap * 0.9, 400, 2));
        assert!(
            heavy.p95() > light.p95(),
            "heavy {} <= light {}",
            heavy.p95(),
            light.p95()
        );
    }

    #[test]
    fn two_replicas_beat_one() {
        let one = vec![replica(2)];
        let cap = one[0].capacity(&Workload { rate: 1.0, avg_input: 512.0, avg_output: 128.0 });
        let trace = poisson_trace(cap * 0.8, 500, 3);
        let a = simulate(&one, &trace);
        let b = simulate(&vec![replica(2), replica(2)], &trace);
        assert!(b.p95() < a.p95());
        assert!(b.utilization < a.utilization);
    }

    #[test]
    fn deterministic() {
        let pool = vec![replica(2), replica(4)];
        let trace = poisson_trace(2.0, 300, 4);
        let a = simulate(&pool, &trace);
        let b = simulate(&pool, &trace);
        assert_eq!(a.latencies, b.latencies);
        assert_eq!(a.makespan, b.makespan);
    }

    #[test]
    fn slo_attainment_monotone_in_scale() {
        let pool = vec![replica(2)];
        let out = simulate(&pool, &poisson_trace(2.0, 300, 5));
        let base = out.mean();
        let mut prev = 0.0;
        for scale in [0.5, 1.0, 2.0, 4.0, 8.0] {
            let att = out.slo_attainment(base * scale);
            assert!(att >= prev);
            prev = att;
        }
    }

    #[test]
    fn heterogeneous_pool_faster_replica_does_more_work() {
        // tp4 is faster than tp1; with least-work dispatch it should
        // finish more requests. We proxy via utilization balance: both
        // should be busy, neither starved.
        let pool = vec![replica(1), replica(4)];
        let trace = poisson_trace(4.0, 600, 6);
        let out = simulate(&pool, &trace);
        assert!(out.utilization > 0.05);
        assert_eq!(out.latencies.len(), 600);
    }

    #[test]
    #[should_panic(expected = "no replicas")]
    fn empty_pool_panics() {
        simulate(&[], &[]);
    }

    // ---- Execution-discipline modes ----

    #[test]
    fn single_request_pins_continuous_and_paged_to_lockstep() {
        // With one request there is nothing to batch: all three
        // disciplines must charge exactly prefill + out x iter(1).
        let pool = vec![replica(2)];
        let trace = vec![SimRequest::new(0.0, 512, 64)];
        let lock = simulate_mode(&pool, &trace, DesMode::Lockstep);
        let expected = pool[0].prefill_latency(512.0) + 64.0 * pool[0].decode_iteration(1);
        assert!(
            (lock.latencies[0] - expected).abs() < 1e-9,
            "lockstep {} vs closed form {}",
            lock.latencies[0],
            expected
        );
        for mode in [
            DesMode::Continuous,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
        ] {
            let out = simulate_mode(&pool, &trace, mode);
            assert_eq!(out.latencies.len(), 1);
            let rel = (out.latencies[0] - lock.latencies[0]).abs()
                / lock.latencies[0].max(1e-12);
            assert!(rel < 1e-6, "{mode:?}: {} vs lockstep {}", out.latencies[0], lock.latencies[0]);
        }
    }

    #[test]
    fn paged_mode_tracks_pages_within_budget_and_completes() {
        let pool = vec![replica(2)];
        let trace = poisson_trace(2.0, 300, 7);
        let out = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
        );
        assert_eq!(out.latencies.len(), 300);
        assert!(out.latencies.iter().all(|l| *l > 0.0 && l.is_finite()));
        assert!(out.peak_pages > 0, "page accounting must be live");
        assert!(
            out.peak_pages <= pool[0].kv_pages_total(16),
            "occupancy {} exceeds the pool budget {}",
            out.peak_pages,
            pool[0].kv_pages_total(16)
        );
        assert_eq!(out.preemptions, 0, "an amply sized pool never preempts");
        // Deterministic like the other modes.
        let again = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
        );
        assert_eq!(out.latencies, again.latencies);
        assert_eq!(out.makespan, again.makespan);
    }

    #[test]
    fn lockstep_is_slower_than_continuous_under_load() {
        // Without batch amortization the lockstep discipline must lose
        // on the same trace — the gap `cascadia bench` measures live.
        let pool = vec![replica(2)];
        let cap = pool[0]
            .capacity(&Workload { rate: 1.0, avg_input: 512.0, avg_output: 128.0 });
        let trace = poisson_trace(cap * 0.6, 300, 8);
        let cont = simulate_mode(&pool, &trace, DesMode::Continuous);
        let lock = simulate_mode(&pool, &trace, DesMode::Lockstep);
        assert!(
            lock.p95() > cont.p95(),
            "lockstep p95 {} should exceed continuous {}",
            lock.p95(),
            cont.p95()
        );
        assert!(lock.makespan >= cont.makespan * 0.99);
    }

    #[test]
    fn chunked_prefill_pins_to_closed_form_on_one_long_prompt() {
        // Single request, no batchmates: chunked prefill must cost
        // exactly the whole-prompt latency plus one interleaved
        // iteration per extra chunk — the DES-level pin of the chunk
        // budget's TTFT semantics.
        let pool = vec![replica(2)];
        let m = &pool[0];
        let trace = vec![SimRequest::new(0.0, 2048, 32)];
        let whole = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
        );
        let chunked = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: 512, swap: false, spec: None },
        );
        let iter1 = m.decode_iteration(1) / m.pp_capacity_factor;
        let expect_whole = m.prefill_latency(2048.0) + 32.0 * iter1;
        let n_chunks = 2048f64 / 512.0; // 4 chunks
        let expect_chunked = expect_whole + (n_chunks - 1.0) * iter1;
        assert!(
            (whole.latencies[0] - expect_whole).abs() < 1e-9,
            "whole {} vs closed form {}",
            whole.latencies[0],
            expect_whole
        );
        assert!(
            (chunked.latencies[0] - expect_chunked).abs() < 1e-9,
            "chunked {} vs closed form {}",
            chunked.latencies[0],
            expect_chunked
        );
    }

    #[test]
    fn swap_mode_beats_recompute_on_a_preemption_heavy_long_context_trace() {
        // Long contexts at a concurrency the pool cannot hold to
        // completion: growth must evict. Recompute-only burns a full
        // re-prefill (and re-decode) per victim; swap pays the PCIe
        // round trip and resumes from the checkpoint — exactly the
        // regime the deployment level prices (§4.2).
        let pool = vec![replica(1)];
        let m = &pool[0];
        // Saturate the request-count bound so page growth, not
        // admission, is the binding constraint.
        let n = (m.max_batch + m.max_batch / 3).max(8);
        let trace: Vec<SimRequest> = (0..n)
            .map(|i| SimRequest::new(i as f64 * 1e-4, 3600, 600))
            .collect();
        let recompute = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
        );
        let swapped = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: true, spec: None },
        );
        assert!(recompute.preemptions > 0, "the trace must be preemption-heavy");
        assert_eq!(recompute.swap_outs, 0);
        assert!(swapped.swap_outs > 0, "swap mode must park victims");
        assert_eq!(swapped.swap_outs, swapped.swap_ins, "every park resumes");
        assert!(swapped.swap_pages > 0);
        assert_eq!(swapped.preemptions, 0, "ample host budget: no recompute fallback");
        assert!(
            swapped.p95() < recompute.p95(),
            "swap p95 {} must beat recompute {}",
            swapped.p95(),
            recompute.p95()
        );
        assert!(swapped.makespan <= recompute.makespan);
        // Both complete everything and stay within the device budget.
        assert_eq!(swapped.latencies.len(), n);
        assert!(swapped.peak_pages <= m.kv_pages_total(16));
        // Deterministic like every other mode.
        let again = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: true, spec: None },
        );
        assert_eq!(swapped.latencies, again.latencies);
        assert_eq!(swapped.swap_outs, again.swap_outs);
        assert_eq!(swapped.finish_iters, again.finish_iters);
    }

    #[test]
    fn finish_iters_align_with_completions() {
        let pool = vec![replica(2)];
        let trace = poisson_trace(2.0, 60, 11);
        let out = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
        );
        assert_eq!(out.finish_iters.len(), 60);
        assert!(out.finish_iters.iter().all(|&t| t > 0), "every request gets a tick");
        // A request's finish tick is at least its decode length (one
        // token per iteration).
        for (i, r) in trace.iter().enumerate() {
            assert!(out.finish_iters[i] >= r.output_tokens as usize);
        }
    }

    #[test]
    fn speculative_paged_mode_cuts_ticks_and_stays_lossless_on_counts() {
        let pool = vec![replica(2)];
        let trace = poisson_trace(2.0, 80, 13);
        let plain = simulate_mode(
            &pool,
            &trace,
            DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None },
        );
        let mode = |agree_mod| DesMode::Paged {
            page_tokens: 16,
            prefill_chunk: usize::MAX,
            swap: false,
            spec: Some(SpecSim { draft_k: 4, agree_mod, draft_us_per_token: 5 }),
        };
        let perfect = simulate_mode(&pool, &trace, mode(0));
        assert_eq!(perfect.latencies.len(), 80, "spec mode completes everything");
        assert!(perfect.spec_accepted > 0, "perfect drafts must be accepted");
        assert_eq!(perfect.spec_rejected, 0, "agree_mod 0 never rejects");
        assert_eq!(plain.spec_accepted + plain.spec_rejected, 0);
        // Multi-token verify steps finish each request in strictly
        // fewer engine ticks than one-token-per-tick decode.
        for (i, (s, p)) in
            perfect.finish_iters.iter().zip(plain.finish_iters.iter()).enumerate()
        {
            assert!(s < p, "req {i}: spec tick {s} must beat plain {p}");
        }
        assert!(
            perfect.makespan < plain.makespan,
            "spec makespan {} must beat plain {}",
            perfect.makespan,
            plain.makespan
        );
        // Imperfect agreement: rejections happen, everything still
        // completes, and rollback keeps occupancy inside the budget.
        let lossy = simulate_mode(&pool, &trace, mode(3));
        assert_eq!(lossy.latencies.len(), 80);
        assert!(lossy.spec_accepted > 0);
        assert!(lossy.spec_rejected > 0, "agree_mod 3 must reject some drafts");
        assert!(lossy.peak_pages <= pool[0].kv_pages_total(16));
        // Deterministic like every other mode.
        let again = simulate_mode(&pool, &trace, mode(3));
        assert_eq!(lossy.latencies, again.latencies);
        assert_eq!(lossy.finish_iters, again.finish_iters);
        assert_eq!(lossy.spec_accepted, again.spec_accepted);
        assert_eq!(lossy.spec_rejected, again.spec_rejected);
    }

    #[test]
    fn traced_spec_run_emits_draft_and_verify_events_per_tick() {
        use crate::obs::EventKind as K;
        let pool = vec![replica(2)];
        let trace = poisson_trace(2.0, 24, 14);
        let rec = TraceRecorder::new(pool.len(), 65_536);
        let spec = Some(SpecSim { draft_k: 3, agree_mod: 3, draft_us_per_token: 5 });
        let traced =
            simulate_paged_spec_traced(&pool, &trace, 16, usize::MAX, false, spec, &rec);
        assert_eq!(traced.latencies.len(), 24);
        assert!(traced.spec_accepted > 0);
        let by_req = rec.per_request();
        assert_eq!(by_req.len(), 24);
        let mut drafts = 0usize;
        let mut verifies = 0usize;
        for (req, evs) in &by_req {
            let d = evs.iter().filter(|e| e.kind == K::DraftIter).count();
            let v = evs.iter().filter(|e| e.kind == K::VerifyAccept).count();
            assert_eq!(d, v, "req {req}: every draft batch gets verified");
            // A verify's decode_iter reports accepted + 1 tokens.
            for e in evs.iter().filter(|e| e.kind == K::VerifyAccept) {
                assert!(e.a as usize <= 3, "req {req}: accepted beyond draft depth");
            }
            drafts += d;
            verifies += v;
        }
        assert!(drafts > 0, "steady decoders must speculate");
        assert_eq!(
            traced.spec_accepted + traced.spec_rejected,
            by_req
                .values()
                .flatten()
                .filter(|e| e.kind == K::VerifyAccept)
                .map(|e| (e.a + e.b) as usize)
                .sum::<usize>(),
            "event stream and scheduler counters agree"
        );
        let _ = verifies;
        assert_eq!(rec.dropped_events(), 0);
    }

    #[test]
    fn traced_paged_run_is_byte_identical_and_emits_one_finished_per_request() {
        use crate::obs::EventKind as K;
        let pool = vec![replica(2)];
        let trace = poisson_trace(2.0, 40, 12);
        let rec = TraceRecorder::new(pool.len(), 65_536);
        let traced = simulate_paged_traced(&pool, &trace, 16, usize::MAX, false, &rec);
        let plain = simulate_paged(&pool, &trace, 16, usize::MAX, false);
        assert_eq!(traced.latencies, plain.latencies, "tracing must not perturb the sim");
        assert_eq!(traced.makespan, plain.makespan);
        let by_req = rec.per_request();
        assert_eq!(by_req.len(), 40, "every request leaves a timeline");
        for (req, evs) in &by_req {
            let fins = evs.iter().filter(|e| e.kind == K::Finished).count();
            assert_eq!(fins, 1, "exactly one terminal event for req {req}");
            assert!(
                evs.last().map(|e| e.kind.is_terminal()).unwrap_or(false),
                "req {req}: finished must close the timeline"
            );
            assert!(evs.iter().any(|e| e.kind == K::PrefillChunk));
            assert!(evs.iter().any(|e| e.kind == K::DecodeIter));
            let fin = evs.last().unwrap();
            assert!(fin.fa > 0.0 && fin.fb >= fin.fa, "TTFT and e2e are simulated seconds");
        }
        assert_eq!(rec.dropped_events(), 0);
    }

    #[test]
    fn prefix_groups_hit_shared_pages_and_cut_occupancy() {
        // A stream of requests sharing a 256-token system prompt,
        // spaced widely enough that each arrival finds its
        // predecessor's pages published.
        let pool = vec![replica(2)];
        let make = |group: u64| -> Vec<SimRequest> {
            (0..24)
                .map(|i| SimRequest {
                    arrival: i as f64 * 0.1,
                    input_tokens: 512,
                    output_tokens: 64,
                    prefix_group: group,
                    shared_tokens: if group == 0 { 0 } else { 256 },
                })
                .collect()
        };
        let mode = DesMode::Paged { page_tokens: 16, prefill_chunk: usize::MAX, swap: false, spec: None };
        let solo = simulate_mode(&pool, &make(0), mode);
        let shared = simulate_mode(&pool, &make(7), mode);
        assert_eq!(solo.prefix_hit_tokens, 0);
        assert!(shared.prefix_hit_tokens > 0, "group-mates must claim the prefix");
        assert!(
            shared.peak_pages < solo.peak_pages,
            "sharing must cut peak occupancy: {} vs {}",
            shared.peak_pages,
            solo.peak_pages
        );
        assert!(
            shared.makespan <= solo.makespan + 1e-9,
            "skipped prefill cannot slow the run"
        );
        // Identical-prompt re-serves (shared == input) ride the tail
        // page too and may CoW on divergence.
        let reserve: Vec<SimRequest> = (0..12)
            .map(|i| SimRequest {
                arrival: i as f64 * 0.1,
                input_tokens: 512,
                output_tokens: 64,
                prefix_group: 9,
                shared_tokens: 512,
            })
            .collect();
        let out = simulate_mode(&pool, &reserve, mode);
        assert!(out.prefix_hit_tokens >= 512 * 8, "full hits skip whole prompts");
        assert_eq!(out.latencies.len(), 12);
    }

    // ---- Disaggregated prefill/decode ----

    const HOGS: usize = 8;

    /// Decode hogs saturate the pool from t≈0; long prompts then probe
    /// TTFT while the hogs are still decoding. Probe spacing is derived
    /// from the model so the prefill side keeps up with margin, and the
    /// probe window stays inside the hogs' decode lifetime.
    fn hog_probe_trace(m: &ReplicaModel) -> Vec<SimRequest> {
        let ppf = m.pp_capacity_factor;
        let iter1 = m.decode_iteration(1) / ppf;
        let hog_out = 768u32;
        // Conservative end of the window in which the hogs are
        // certainly still decoding (their per-token time only grows
        // with batch).
        let covered = hog_out as f64 * iter1 * 0.6;
        let start = (HOGS as f64 * m.prefill_latency(64.0) + 4.0 * iter1).max(0.02);
        let gap = (m.prefill_latency(704.0) * 1.5 + m.decode_iteration(8) / ppf)
            .max(covered / 24.0);
        let n_probes = (((covered - start) / gap) as usize).clamp(4, 24);
        let mut t: Vec<SimRequest> = (0..HOGS)
            .map(|i| SimRequest::new(i as f64 * 1e-3, 64, hog_out))
            .collect();
        t.extend((0..n_probes).map(|i| SimRequest::new(start + i as f64 * gap, 704, 32)));
        t
    }

    #[test]
    fn disagg_completes_everything_exactly_once_and_deterministically() {
        let pf = vec![replica(2)];
        let dc = vec![replica(2)];
        let trace = hog_probe_trace(&pf[0]);
        let out = simulate_disagg(&pf, &dc, &trace, 16, usize::MAX, false);
        assert_eq!(out.latencies.len(), trace.len(), "exactly-once across the handoff");
        assert_eq!(
            out.migrations,
            trace.len(),
            "every request (output >= 2) hands off exactly once"
        );
        assert!(out.migrate_pages > 0, "private pages must cross the interconnect");
        assert_eq!(out.ttfts.len(), trace.len());
        assert!(out.ttfts.iter().all(|t| *t > 0.0 && t.is_finite()));
        assert!(out.finish_iters.iter().all(|&t| t > 0), "every request gets a tick");
        let again = simulate_disagg(&pf, &dc, &trace, 16, usize::MAX, false);
        assert_eq!(out.latencies, again.latencies);
        assert_eq!(out.ttfts, again.ttfts);
        assert_eq!(out.migrations, again.migrations);
        assert_eq!(out.migrate_pages, again.migrate_pages);
        assert_eq!(out.finish_iters, again.finish_iters);
    }

    #[test]
    fn disagg_beats_unified_p95_ttft_under_decode_pressure() {
        // Same total hardware: two unified replicas vs one prefill +
        // one decode. With the pool full of decoding hogs, a unified
        // replica charges the hog batch (and any page-pressure
        // eviction) into every probe's first-token tick; the dedicated
        // prefill replica hands its hogs off and serves probes from an
        // empty pool.
        let m = replica(2);
        let trace = hog_probe_trace(&m);
        let unified =
            simulate_paged(&[replica(2), replica(2)], &trace, 16, usize::MAX, false);
        let split = simulate_disagg(
            &[replica(2)],
            &[replica(2)],
            &trace,
            16,
            usize::MAX,
            false,
        );
        assert_eq!(unified.latencies.len(), trace.len());
        assert_eq!(split.latencies.len(), trace.len());
        let probe_p95 = |o: &SimOutcome| stats::percentile(&o.ttfts[HOGS..], 0.95);
        assert!(
            probe_p95(&split) < probe_p95(&unified),
            "split probe p95 TTFT {} must beat unified {}",
            probe_p95(&split),
            probe_p95(&unified)
        );
    }

    #[test]
    fn migrated_group_mates_reclaim_prefix_on_the_decode_side() {
        let pf = vec![replica(2)];
        let dc = vec![replica(2)];
        let make = |group: u64| -> Vec<SimRequest> {
            (0..16)
                .map(|i| SimRequest {
                    arrival: i as f64 * 0.2,
                    input_tokens: 512,
                    output_tokens: 48,
                    prefix_group: group,
                    shared_tokens: if group == 0 { 0 } else { 256 },
                })
                .collect()
        };
        let solo = simulate_disagg(&pf, &dc, &make(0), 16, usize::MAX, false);
        let shared = simulate_disagg(&pf, &dc, &make(7), 16, usize::MAX, false);
        assert_eq!(solo.prefix_hit_tokens, 0);
        assert!(
            shared.prefix_hit_tokens > 0,
            "later migrants must claim the decode-side trie"
        );
        assert!(
            shared.migrate_pages < solo.migrate_pages,
            "claimed prefix pages must not cross the interconnect: {} vs {}",
            shared.migrate_pages,
            solo.migrate_pages
        );
        assert_eq!(shared.latencies.len(), 16);
        assert_eq!(solo.latencies.len(), 16);
    }

    #[test]
    fn traced_disagg_emits_one_migrate_pair_and_one_finished_per_request() {
        use crate::obs::EventKind as K;
        let pf = vec![replica(2)];
        let dc = vec![replica(2)];
        let trace = hog_probe_trace(&pf[0]);
        let rec = TraceRecorder::new(2, 262_144);
        let traced = simulate_disagg_traced(&pf, &dc, &trace, 16, usize::MAX, false, &rec);
        let plain = simulate_disagg(&pf, &dc, &trace, 16, usize::MAX, false);
        assert_eq!(traced.latencies, plain.latencies, "tracing must not perturb the sim");
        assert_eq!(traced.migrations, plain.migrations);
        let by_req = rec.per_request();
        assert_eq!(by_req.len(), trace.len(), "every request leaves a timeline");
        for (req, evs) in &by_req {
            let outs = evs.iter().filter(|e| e.kind == K::MigrateOut).count();
            let ins = evs.iter().filter(|e| e.kind == K::MigrateIn).count();
            assert_eq!(outs, 1, "req {req}: exactly one handoff");
            assert_eq!(ins, 1, "req {req}: exactly one landing");
            let fins = evs.iter().filter(|e| e.kind == K::Finished).count();
            assert_eq!(fins, 1, "req {req}: exactly one terminal event");
            // The handoff leaves before it lands, and both precede the
            // terminal event.
            let t_out = evs.iter().find(|e| e.kind == K::MigrateOut).unwrap().t;
            let t_in = evs.iter().find(|e| e.kind == K::MigrateIn).unwrap().t;
            assert!(t_out <= t_in, "req {req}: out {t_out} after in {t_in}");
        }
        assert_eq!(rec.dropped_events(), 0);
    }
}
