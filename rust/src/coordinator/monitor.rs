//! Workload monitor and re-scheduling trigger (§4.4 "Re-scheduling to
//! adapt to workload changes").
//!
//! The coordinator subsamples incoming requests (e.g. 100 requests
//! every 10 minutes), estimates their [`TraceStats`], and when the
//! relative shift against the stats the current plan was built for
//! exceeds a threshold, signals that the bi-level scheduler should run
//! again with the recent window.

use crate::workload::{estimate_stats, Request, TraceStats};

#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Number of recent requests kept in the sliding window.
    pub window: usize,
    /// Minimum window fill before shift detection activates.
    pub min_samples: usize,
    /// Relative shift (max over rate/lengths/complexity) that triggers
    /// re-scheduling.
    pub shift_threshold: f64,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig { window: 100, min_samples: 60, shift_threshold: 0.3 }
    }
}

/// Sliding-window workload monitor.
#[derive(Debug)]
pub struct Monitor {
    pub config: MonitorConfig,
    baseline: TraceStats,
    window: Vec<Request>,
    /// A detected shift the caller has not yet resolved (via
    /// [`Monitor::rebased`] or [`Monitor::abort_reschedule`]). While
    /// set, [`Monitor::observe`] keeps sampling but never re-triggers:
    /// without this guard a stale window re-fires on every observation
    /// while the (possibly slow, background) re-schedule is in flight.
    pending: bool,
    /// Number of re-schedules triggered (diagnostics).
    pub reschedules: usize,
}

impl Monitor {
    /// `baseline` is the stats the current plan was computed for.
    pub fn new(config: MonitorConfig, baseline: TraceStats) -> Monitor {
        Monitor { config, baseline, window: Vec::new(), pending: false, reschedules: 0 }
    }

    /// Record an observed request. Returns `Some(new_stats)` when a
    /// significant shift is detected — the caller should re-run the
    /// scheduler with those stats and then call [`Monitor::rebased`]
    /// (or [`Monitor::abort_reschedule`] if the re-schedule failed).
    /// At most one trigger is outstanding at a time.
    pub fn observe(&mut self, req: Request) -> Option<TraceStats> {
        self.window.push(req);
        if self.window.len() > self.config.window {
            let excess = self.window.len() - self.config.window;
            self.window.drain(0..excess);
        }
        if self.pending || self.window.len() < self.config.min_samples {
            return None;
        }
        let current = estimate_stats(&self.window);
        if current.shift_from(&self.baseline) > self.config.shift_threshold {
            self.pending = true;
            Some(current)
        } else {
            None
        }
    }

    /// Acknowledge a re-schedule: the new plan was built for `stats`.
    /// The window is reset so the stale pre-swap sample cannot
    /// immediately re-trigger against the new baseline; detection
    /// resumes once `min_samples` fresh requests arrive.
    pub fn rebased(&mut self, stats: TraceStats) {
        self.baseline = stats;
        self.window.clear();
        self.pending = false;
        self.reschedules += 1;
    }

    /// Give up on an outstanding trigger (the re-schedule failed or the
    /// new plan could not be applied). The window restarts from empty —
    /// re-arming only after fresh samples — instead of re-firing on
    /// every subsequent request.
    pub fn abort_reschedule(&mut self) {
        self.window.clear();
        self.pending = false;
    }

    /// Trigger a re-schedule from an external signal (the SLO burn-rate
    /// alerter) instead of a detected workload shift. Shares the
    /// pending-trigger suppression with [`Monitor::observe`]: while a
    /// re-schedule is outstanding — whichever trigger fired it — this
    /// returns `None`, so the two trigger sources never storm. Also
    /// `None` below `min_samples`: a re-schedule needs a representative
    /// window to plan on.
    pub fn trigger_external(&mut self) -> Option<TraceStats> {
        if self.pending || self.window.len() < self.config.min_samples {
            return None;
        }
        self.pending = true;
        Some(estimate_stats(&self.window))
    }

    /// Whether a trigger is outstanding (re-schedule in flight).
    pub fn is_pending(&self) -> bool {
        self.pending
    }

    /// The recent request sample — what the re-scheduler should re-run
    /// the bi-level optimization on.
    pub fn window_requests(&self) -> &[Request] {
        &self.window
    }

    pub fn baseline(&self) -> &TraceStats {
        &self.baseline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, paper_trace};

    fn baseline() -> TraceStats {
        let reqs = generate(&paper_trace(2, 4.0), 500, 1);
        estimate_stats(&reqs)
    }

    #[test]
    fn stable_workload_never_triggers() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        for req in generate(&paper_trace(2, 4.0), 400, 2) {
            assert!(m.observe(req).is_none(), "false positive reschedule");
        }
    }

    #[test]
    fn rate_surge_triggers() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        // Same mix, 3x the rate.
        let mut triggered = false;
        for req in generate(&paper_trace(2, 12.0), 400, 3) {
            if m.observe(req).is_some() {
                triggered = true;
                break;
            }
        }
        assert!(triggered, "rate surge not detected");
    }

    #[test]
    fn complexity_shift_triggers() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        // Switch to the much harder trace 1 at the same rate.
        let mut triggered = false;
        for req in generate(&paper_trace(1, 4.0), 400, 4) {
            if m.observe(req).is_some() {
                triggered = true;
                break;
            }
        }
        assert!(triggered, "complexity shift not detected");
    }

    #[test]
    fn rebased_resets_detection() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        let mut new_stats = None;
        for req in generate(&paper_trace(1, 12.0), 400, 5) {
            if let Some(s) = m.observe(req) {
                new_stats = Some(s);
                break;
            }
        }
        let s = new_stats.expect("shift detected");
        m.rebased(s);
        assert_eq!(m.reschedules, 1);
        // Continuing with the same (new) workload should not re-trigger.
        for req in generate(&paper_trace(1, 12.0), 200, 6) {
            assert!(m.observe(req).is_none());
        }
    }

    #[test]
    fn window_is_bounded() {
        let base = baseline();
        let cfg = MonitorConfig { window: 50, ..Default::default() };
        let mut m = Monitor::new(cfg, base);
        for req in generate(&paper_trace(2, 4.0), 300, 7) {
            let _ = m.observe(req);
        }
        assert!(m.window.len() <= 50);
    }

    #[test]
    fn underfilled_window_never_triggers() {
        // Wildly shifted traffic, but fewer than min_samples
        // observations: detection must stay silent.
        let base = baseline();
        let cfg = MonitorConfig { window: 100, min_samples: 60, shift_threshold: 0.3 };
        let mut m = Monitor::new(cfg, base);
        for req in generate(&paper_trace(1, 40.0), 59, 8) {
            assert!(m.observe(req).is_none(), "triggered below min_samples");
        }
    }

    #[test]
    fn zero_rate_baseline_is_finite_and_triggers() {
        // A degenerate baseline (e.g. a plan scheduled before any
        // traffic) must not panic or produce non-finite shifts — any
        // real traffic is a drift.
        let zero = TraceStats { rate: 0.0, avg_input: 0.0, avg_output: 0.0, complexity_mean: 0.0 };
        let mut m = Monitor::new(MonitorConfig::default(), zero);
        let mut triggered = None;
        for req in generate(&paper_trace(2, 4.0), 200, 9) {
            if let Some(s) = m.observe(req) {
                triggered = Some(s);
                break;
            }
        }
        let s = triggered.expect("traffic on a zero baseline must trigger");
        assert!(s.rate.is_finite());
        assert!(s.shift_from(m.baseline()).is_finite());
    }

    #[test]
    fn steady_state_after_rebase_stays_silent() {
        // No-drift steady state: a monitor rebased onto the live
        // workload's own stats never re-triggers on that workload.
        let reqs = generate(&paper_trace(2, 4.0), 600, 10);
        let mut m = Monitor::new(MonitorConfig::default(), estimate_stats(&reqs[..300]));
        for req in &reqs[..300] {
            let _ = m.observe(*req);
        }
        m.rebased(estimate_stats(&reqs[..300]));
        for req in &reqs[300..] {
            assert!(m.observe(*req).is_none(), "steady state re-triggered");
        }
    }

    #[test]
    fn pending_trigger_suppresses_refire_until_resolved() {
        // Regression: while a re-schedule is in flight the stale window
        // must not re-trigger on every subsequent request.
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        let reqs = generate(&paper_trace(1, 12.0), 400, 11);
        let mut it = reqs.iter();
        let mut first = None;
        for req in it.by_ref() {
            if let Some(s) = m.observe(*req) {
                first = Some(s);
                break;
            }
        }
        let stats = first.expect("shift detected");
        assert!(m.is_pending());
        // The re-schedule is still running: no re-fires.
        for req in it.by_ref().take(100) {
            assert!(m.observe(*req).is_none(), "re-fired while pending");
        }
        m.rebased(stats);
        assert!(!m.is_pending());
        assert_eq!(m.reschedules, 1);
    }

    #[test]
    fn rebase_resets_window_below_capacity() {
        // Regression: `rebased` must drop the stale window entirely, so
        // detection re-arms only after min_samples *fresh* requests —
        // a stale window would re-trigger immediately after the swap.
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        let reqs = generate(&paper_trace(1, 12.0), 400, 12);
        let mut stats = None;
        for req in &reqs {
            if let Some(s) = m.observe(*req) {
                stats = Some(s);
                break;
            }
        }
        m.rebased(stats.expect("shift detected"));
        assert!(m.window_requests().is_empty(), "window must reset on rebase");
    }

    #[test]
    fn external_trigger_respects_pending_and_min_samples() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        for req in generate(&paper_trace(2, 4.0), 30, 14) {
            let _ = m.observe(req);
        }
        assert!(m.trigger_external().is_none(), "underfilled window must not trigger");
        for req in generate(&paper_trace(2, 4.0), 100, 15) {
            let _ = m.observe(req);
        }
        let stats = m.trigger_external().expect("filled window triggers");
        assert!(m.is_pending());
        assert!(m.trigger_external().is_none(), "pending suppresses re-fire");
        m.rebased(stats);
        assert!(!m.is_pending());
        assert_eq!(m.reschedules, 1);
    }

    #[test]
    fn abort_clears_pending_and_rearms() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        let reqs = generate(&paper_trace(1, 12.0), 800, 13);
        let mut it = reqs.iter();
        for req in it.by_ref() {
            if m.observe(*req).is_some() {
                break;
            }
        }
        assert!(m.is_pending());
        m.abort_reschedule();
        assert!(!m.is_pending());
        assert_eq!(m.reschedules, 0, "aborted re-schedule must not count");
        // The shift persists, so after a fresh window fills it triggers
        // again.
        let mut retriggered = false;
        for req in it {
            if m.observe(*req).is_some() {
                retriggered = true;
                break;
            }
        }
        assert!(retriggered, "shift not re-detected after abort");
    }
}
