//! Workload monitor and re-scheduling trigger (§4.4 "Re-scheduling to
//! adapt to workload changes").
//!
//! The coordinator subsamples incoming requests (e.g. 100 requests
//! every 10 minutes), estimates their [`TraceStats`], and when the
//! relative shift against the stats the current plan was built for
//! exceeds a threshold, signals that the bi-level scheduler should run
//! again with the recent window.

use crate::workload::{estimate_stats, Request, TraceStats};

#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Number of recent requests kept in the sliding window.
    pub window: usize,
    /// Minimum window fill before shift detection activates.
    pub min_samples: usize,
    /// Relative shift (max over rate/lengths/complexity) that triggers
    /// re-scheduling.
    pub shift_threshold: f64,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig { window: 100, min_samples: 60, shift_threshold: 0.3 }
    }
}

/// Sliding-window workload monitor.
#[derive(Debug)]
pub struct Monitor {
    pub config: MonitorConfig,
    baseline: TraceStats,
    window: Vec<Request>,
    /// Number of re-schedules triggered (diagnostics).
    pub reschedules: usize,
}

impl Monitor {
    /// `baseline` is the stats the current plan was computed for.
    pub fn new(config: MonitorConfig, baseline: TraceStats) -> Monitor {
        Monitor { config, baseline, window: Vec::new(), reschedules: 0 }
    }

    /// Record an observed request. Returns `Some(new_stats)` when a
    /// significant shift is detected — the caller should re-run the
    /// scheduler with those stats and then call [`Monitor::rebased`].
    pub fn observe(&mut self, req: Request) -> Option<TraceStats> {
        self.window.push(req);
        if self.window.len() > self.config.window {
            let excess = self.window.len() - self.config.window;
            self.window.drain(0..excess);
        }
        if self.window.len() < self.config.min_samples {
            return None;
        }
        let current = estimate_stats(&self.window);
        if current.shift_from(&self.baseline) > self.config.shift_threshold {
            Some(current)
        } else {
            None
        }
    }

    /// Acknowledge a re-schedule: the new plan was built for `stats`.
    pub fn rebased(&mut self, stats: TraceStats) {
        self.baseline = stats;
        self.window.clear();
        self.reschedules += 1;
    }

    pub fn baseline(&self) -> &TraceStats {
        &self.baseline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, paper_trace};

    fn baseline() -> TraceStats {
        let reqs = generate(&paper_trace(2, 4.0), 500, 1);
        estimate_stats(&reqs)
    }

    #[test]
    fn stable_workload_never_triggers() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        for req in generate(&paper_trace(2, 4.0), 400, 2) {
            assert!(m.observe(req).is_none(), "false positive reschedule");
        }
    }

    #[test]
    fn rate_surge_triggers() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        // Same mix, 3x the rate.
        let mut triggered = false;
        for req in generate(&paper_trace(2, 12.0), 400, 3) {
            if m.observe(req).is_some() {
                triggered = true;
                break;
            }
        }
        assert!(triggered, "rate surge not detected");
    }

    #[test]
    fn complexity_shift_triggers() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        // Switch to the much harder trace 1 at the same rate.
        let mut triggered = false;
        for req in generate(&paper_trace(1, 4.0), 400, 4) {
            if m.observe(req).is_some() {
                triggered = true;
                break;
            }
        }
        assert!(triggered, "complexity shift not detected");
    }

    #[test]
    fn rebased_resets_detection() {
        let base = baseline();
        let mut m = Monitor::new(MonitorConfig::default(), base);
        let mut new_stats = None;
        for req in generate(&paper_trace(1, 12.0), 400, 5) {
            if let Some(s) = m.observe(req) {
                new_stats = Some(s);
                break;
            }
        }
        let s = new_stats.expect("shift detected");
        m.rebased(s);
        assert_eq!(m.reschedules, 1);
        // Continuing with the same (new) workload should not re-trigger.
        for req in generate(&paper_trace(1, 12.0), 200, 6) {
            assert!(m.observe(req).is_none());
        }
    }

    #[test]
    fn window_is_bounded() {
        let base = baseline();
        let cfg = MonitorConfig { window: 50, ..Default::default() };
        let mut m = Monitor::new(cfg, base);
        for req in generate(&paper_trace(2, 4.0), 300, 7) {
            let _ = m.observe(req);
        }
        assert!(m.window.len() <= 50);
    }
}
