//! Whole-cascade simulation: chains the per-tier discrete-event
//! simulator so tier t+1's arrivals are the completion times of tier
//! t's escalated requests, and a request's end-to-end latency is the
//! sum of its per-tier residencies — exactly the serving semantics of
//! Figure 5.

use anyhow::{bail, Result};

use crate::cluster::ClusterSpec;
use crate::judge::Judger;
use crate::models::ModelSpec;
use crate::perf::ReplicaModel;
use crate::router::route_with;
use crate::sched::plan::CascadePlan;
use crate::sim::des::{simulate, SimRequest};
use crate::sim::SimOutcome;
use crate::util::stats;
use crate::workload::Request;

/// End-to-end cascade simulation result.
#[derive(Debug, Clone)]
pub struct CascadeSimResult {
    /// End-to-end latency per request (trace order).
    pub e2e_latencies: Vec<f64>,
    /// Per-tier simulator outcomes (None for undeployed tiers).
    pub tier_outcomes: Vec<Option<SimOutcome>>,
    /// Judged quality of the final answers.
    pub quality: f64,
    /// Requests per second over the whole run.
    pub throughput_rps: f64,
    pub makespan: f64,
    /// Accepting tier per request.
    pub accepting_tier: Vec<u8>,
}

impl CascadeSimResult {
    pub fn p95(&self) -> f64 {
        stats::percentile(&self.e2e_latencies, 0.95)
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.e2e_latencies)
    }

    pub fn slo_attainment(&self, slo: f64) -> f64 {
        stats::fraction_within(&self.e2e_latencies, slo)
    }

    /// Smallest SLO scale (multiple of `unit`) at which attainment
    /// reaches `target` — the paper's headline metric (95% attainment).
    pub fn min_slo_scale(&self, unit: f64, target: f64) -> f64 {
        // Direct computation from the latency distribution: the
        // `target` quantile divided by the unit.
        let q = stats::percentile(&self.e2e_latencies, target);
        q / unit
    }
}

/// Build the replica pool for a tier plan.
pub fn replicas_for(
    plan: &CascadePlan,
    tier: usize,
    cascade: &[ModelSpec],
    cluster: &ClusterSpec,
) -> Vec<ReplicaModel> {
    let tp = &plan.tiers[tier];
    let Some(strategy) = &tp.strategy else {
        return Vec::new();
    };
    let w = &tp.workload;
    let avg_ctx = (w.avg_input + w.avg_output / 2.0).max(64.0);
    strategy
        .groups
        .iter()
        .flat_map(|g| {
            (0..g.count)
                .map(|_| ReplicaModel::new(&cascade[tier], cluster, g.tp, g.pp, avg_ctx))
        })
        .collect()
}

/// Simulate `requests` through the deployed cascade `plan`.
///
/// Routing decisions reuse the same judger as the scheduler, so the
/// simulated processing ratios equal the planned ones (up to trace
/// noise when the evaluation trace differs from the planning trace).
pub fn simulate_cascade(
    plan: &CascadePlan,
    cascade: &[ModelSpec],
    cluster: &ClusterSpec,
    judger: &Judger,
    requests: &[Request],
) -> Result<CascadeSimResult> {
    if requests.is_empty() {
        bail!("empty trace");
    }
    let c = cascade.len();
    let span =
        (requests[requests.len() - 1].arrival - requests[0].arrival).max(1e-9);
    let routing = route_with(cascade, judger, requests, &plan.policy, span)?;

    // Per-request bookkeeping: the time the request becomes available
    // to the next tier (initially its arrival).
    let mut ready: Vec<f64> = requests.iter().map(|r| r.arrival).collect();
    let mut e2e_done: Vec<f64> = vec![f64::NAN; requests.len()];
    let mut tier_outcomes: Vec<Option<SimOutcome>> = vec![None; c];
    let mut makespan: f64 = 0.0;

    for tier in 0..c {
        // A request is *served* by this tier iff the tier is deployed
        // and the request has not been accepted earlier. Undeployed
        // tiers are pure pass-throughs (the standalone baseline forces
        // escalation past them via h=101 thresholds, and Table 1's
        // tier-subset plans never route traffic to them) — but a
        // request ACCEPTED at an undeployed tier is a plan bug.
        if plan.tiers[tier].gpus == 0 {
            if let Some(i) = (0..requests.len())
                .find(|&i| routing.accepting_tier[i] as usize == tier)
            {
                bail!(
                    "request {} accepted at undeployed tier {} ({})",
                    i,
                    tier,
                    cascade[tier].name
                );
            }
            continue;
        }
        // Requests that actually visit this tier (skip-capable policies
        // do not visit every tier up to the accepting one).
        let mut idx: Vec<usize> = (0..requests.len())
            .filter(|&i| routing.visited_tiers[i].contains(&(tier as u8)))
            .collect();
        if idx.is_empty() {
            continue;
        }
        // DES requires arrival-sorted traces.
        idx.sort_by(|&a, &b| ready[a].total_cmp(&ready[b]));
        let trace: Vec<SimRequest> = idx
            .iter()
            .map(|&i| SimRequest::new(
                ready[i],
                requests[i].input_tokens,
                requests[i].output_tokens,
            ))
            .collect();
        let replicas = replicas_for(plan, tier, cascade, cluster);
        if replicas.is_empty() {
            bail!("tier {tier} has no replicas");
        }
        let outcome = simulate(&replicas, &trace);
        for (k, &i) in idx.iter().enumerate() {
            let done = outcome.completions[k];
            ready[i] = done;
            if routing.accepting_tier[i] as usize == tier {
                e2e_done[i] = done;
            }
            makespan = makespan.max(done);
        }
        tier_outcomes[tier] = Some(outcome);
    }

    let e2e_latencies: Vec<f64> = (0..requests.len())
        .map(|i| e2e_done[i] - requests[i].arrival)
        .collect();
    if e2e_latencies.iter().any(|l| !l.is_finite() || *l < 0.0) {
        bail!("cascade simulation produced invalid latencies");
    }

    Ok(CascadeSimResult {
        throughput_rps: requests.len() as f64 / makespan.max(1e-9),
        e2e_latencies,
        tier_outcomes,
        quality: routing.quality,
        makespan,
        accepting_tier: routing.accepting_tier,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;
    use crate::router::PolicySpec;
    use crate::sched::outer::{optimize, select_plan, OuterOptions};
    use crate::workload::{generate, paper_trace};

    fn make_plan(rate: f64, quality_req: f64) -> (CascadePlan, Vec<Request>, Judger) {
        let cascade = deepseek_cascade();
        let cluster = ClusterSpec::paper_testbed();
        let judger = Judger::new(1);
        let reqs = generate(&paper_trace(2, rate), 600, 5);
        let opts = OuterOptions {
            threshold_grid: vec![0.0, 40.0, 70.0, 95.0],
            ..Default::default()
        };
        let sweep = optimize(&cascade, &cluster, &judger, &reqs, 32, &opts).unwrap();
        let plan = select_plan(&sweep, quality_req).expect("plan");
        (plan, reqs, judger)
    }

    #[test]
    fn end_to_end_latencies_are_sane() {
        let (plan, reqs, judger) = make_plan(3.0, 70.0);
        let cascade = deepseek_cascade();
        let cluster = ClusterSpec::paper_testbed();
        let out = simulate_cascade(&plan, &cascade, &cluster, &judger, &reqs).unwrap();
        assert_eq!(out.e2e_latencies.len(), reqs.len());
        assert!(out.p95() > 0.0 && out.p95() < 1e4);
        assert!(out.quality >= 65.0, "quality {}", out.quality);
        assert!(out.throughput_rps > 0.0);
    }

    #[test]
    fn escalated_requests_take_longer() {
        let (plan, reqs, judger) = make_plan(3.0, 70.0);
        let cascade = deepseek_cascade();
        let cluster = ClusterSpec::paper_testbed();
        let out = simulate_cascade(&plan, &cascade, &cluster, &judger, &reqs).unwrap();
        // Mean latency of requests accepted at tier 0 vs deeper tiers.
        let mut sums = [0.0f64; 3];
        let mut counts = [0usize; 3];
        for (i, &t) in out.accepting_tier.iter().enumerate() {
            sums[t as usize] += out.e2e_latencies[i];
            counts[t as usize] += 1;
        }
        if counts[0] > 10 && (counts[1] + counts[2]) > 10 {
            let shallow = sums[0] / counts[0] as f64;
            let deep = (sums[1] + sums[2]) / (counts[1] + counts[2]) as f64;
            assert!(deep > shallow, "deep {deep} <= shallow {shallow}");
        }
    }

    #[test]
    fn undeployed_tier_with_traffic_fails_loudly() {
        let (mut plan, reqs, judger) = make_plan(3.0, 70.0);
        // Force traffic to the last tier while removing its deployment.
        plan.policy = PolicySpec::threshold(vec![101.0, 101.0]).unwrap();
        let last = plan.tiers.len() - 1;
        plan.tiers[last].gpus = 0;
        plan.tiers[last].strategy = None;
        let cascade = deepseek_cascade();
        let cluster = ClusterSpec::paper_testbed();
        let err = simulate_cascade(&plan, &cascade, &cluster, &judger, &reqs);
        assert!(err.is_err());
    }

    #[test]
    fn skip_policies_simulate_on_visited_tiers_only() {
        let (mut plan, reqs, judger) = make_plan(3.0, 70.0);
        // Margin policy with a tight band: deep tier-0 failures skip
        // the middle tier entirely; the simulator must still produce
        // finite latencies for every request.
        plan.policy = PolicySpec::margin(vec![80.0, 80.0], 5.0).unwrap();
        if plan.tiers.iter().any(|t| t.gpus == 0) {
            // The swapped-in policy routes traffic everywhere; it needs
            // a fully-deployed plan to be simulable.
            return;
        }
        let cascade = deepseek_cascade();
        let cluster = ClusterSpec::paper_testbed();
        let out = simulate_cascade(&plan, &cascade, &cluster, &judger, &reqs).unwrap();
        assert_eq!(out.e2e_latencies.len(), reqs.len());
        assert!(out.e2e_latencies.iter().all(|l| l.is_finite() && *l >= 0.0));
        // The skip route means tier 1 serves fewer requests than the
        // count of requests accepted at tier >= 1.
        let deep_accepts = out.accepting_tier.iter().filter(|&&t| t >= 1).count();
        if let Some(t1) = &out.tier_outcomes[1] {
            assert!(t1.completions.len() <= deep_accepts);
        }
    }

    #[test]
    fn slo_scale_metric_behaves() {
        let (plan, reqs, judger) = make_plan(3.0, 70.0);
        let cascade = deepseek_cascade();
        let cluster = ClusterSpec::paper_testbed();
        let out = simulate_cascade(&plan, &cascade, &cluster, &judger, &reqs).unwrap();
        let unit = out.mean().max(1e-9);
        let scale = out.min_slo_scale(unit, 0.95);
        // Attainment at that scale must be >= 95%.
        assert!(out.slo_attainment(unit * scale) >= 0.95 - 1e-9);
        // And p95/mean should be a modest multiple.
        assert!(scale > 0.5 && scale < 100.0, "scale {scale}");
    }
}
