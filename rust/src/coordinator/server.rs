//! The live serving engine: policy-routed cascade serving over real
//! model execution.
//!
//! Topology: each deployed tier runs `replicas` worker threads; each
//! worker owns its own backend instance (PJRT executables are not
//! `Send`, so backends are constructed *inside* the worker via the
//! factory). A tier-level [`Batcher`] feeds workers FIFO under the
//! KV-capacity bound; a coordinator thread scores finished responses
//! with the live judger and asks the configured
//! [`crate::router::RoutingPolicy`] whether to complete the request,
//! escalate it, or skip ahead — the same routing workflow the
//! scheduler optimized (§3.3), now on the real request path.
//! [`ServerConfig::from_plan`] derives the whole configuration from a
//! scheduler-produced [`CascadePlan`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::Batcher;
use crate::router::{Decision, PolicySpec, RequestFeatures, RoutingPolicy};
use crate::sched::plan::CascadePlan;
use crate::util::stats;

/// Generates tokens for one tier. One instance per worker thread.
pub trait TierBackend {
    /// Greedy-decode up to `max_new` tokens after `prompt`.
    fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>>;
}

/// Scores a (prompt, output) pair in [0, 100]. Shared across threads.
pub trait ResponseJudger: Send + Sync {
    fn score(&self, prompt: &[i32], output: &[i32]) -> f64;
}

/// Factory building a tier's backend inside its worker thread.
pub type BackendFactory<'a> =
    dyn Fn(usize) -> Result<Box<dyn TierBackend>> + Send + Sync + 'a;

/// Server configuration: one entry per tier, in cascade order.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Worker replicas per tier (from the plan's strategy replica count).
    pub replicas: Vec<usize>,
    /// Max batch admitted per tier iteration.
    pub max_batch: Vec<usize>,
    /// Routing policy deciding acceptance/escalation per scored
    /// response.
    pub policy: PolicySpec,
    /// Max tokens to generate per request.
    pub max_new_tokens: usize,
}

impl ServerConfig {
    /// Convenience constructor for the classic fixed-threshold server.
    pub fn with_thresholds(
        replicas: Vec<usize>,
        max_batch: Vec<usize>,
        thresholds: Vec<f64>,
        max_new_tokens: usize,
    ) -> Result<ServerConfig> {
        Ok(ServerConfig {
            replicas,
            max_batch,
            policy: PolicySpec::threshold(thresholds)?,
            max_new_tokens,
        })
    }

    /// Derive a serving configuration from a scheduler-produced plan:
    /// the plan's policy routes, its strategies set the replica counts,
    /// and admission scales with the allocation. Undeployed tiers keep
    /// one idle worker so skip/escalation targets always exist (the
    /// policy routes no steady-state traffic to them).
    pub fn from_plan(plan: &CascadePlan, max_new_tokens: usize) -> Result<ServerConfig> {
        plan.policy.validate(plan.tiers.len())?;
        let replicas: Vec<usize> = plan
            .tiers
            .iter()
            .map(|t| t.strategy.as_ref().map(|s| s.n_replicas()).unwrap_or(0).max(1))
            .collect();
        let max_batch: Vec<usize> = plan
            .tiers
            .iter()
            .map(|t| (t.gpus.max(1) * 2).clamp(1, 16))
            .collect();
        Ok(ServerConfig {
            replicas,
            max_batch,
            policy: plan.policy.clone(),
            max_new_tokens,
        })
    }
}

/// One in-flight request.
#[derive(Debug, Clone)]
struct LiveRequest {
    id: usize,
    prompt: Vec<i32>,
    submitted: Instant,
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub output: Vec<i32>,
    pub score: f64,
    pub accepting_tier: usize,
    pub e2e_latency: Duration,
    /// Time spent queued (all tiers) vs executing.
    pub queue_latency: Duration,
}

/// Aggregate statistics of a serving run.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub completions: Vec<Completion>,
    pub wall_clock: Duration,
    pub per_tier_processed: Vec<usize>,
}

impl ServerStats {
    pub fn p95_latency(&self) -> f64 {
        let v: Vec<f64> = self.completions.iter().map(|c| c.e2e_latency.as_secs_f64()).collect();
        stats::percentile(&v, 0.95)
    }

    pub fn mean_latency(&self) -> f64 {
        let v: Vec<f64> = self.completions.iter().map(|c| c.e2e_latency.as_secs_f64()).collect();
        stats::mean(&v)
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completions.len() as f64 / self.wall_clock.as_secs_f64().max(1e-9)
    }

    pub fn mean_quality(&self) -> f64 {
        let v: Vec<f64> = self.completions.iter().map(|c| c.score).collect();
        stats::mean(&v)
    }

    pub fn processing_ratios(&self) -> Vec<f64> {
        let n = self.completions.len().max(1) as f64;
        self.per_tier_processed.iter().map(|&c| c as f64 / n).collect()
    }
}

/// Work distribution state for one tier.
struct TierState {
    batcher: Mutex<Batcher<LiveRequest>>,
    wake: Condvar,
    /// Set when no more work will ever arrive for this tier.
    closed: AtomicBool,
}

impl TierState {
    fn new(max_batch: usize) -> TierState {
        TierState {
            batcher: Mutex::new(Batcher::new(max_batch)),
            wake: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    fn push(&self, req: LiveRequest, t0: Instant) {
        let mut b = self.batcher.lock().unwrap();
        b.push(req, t0.elapsed().as_secs_f64());
        drop(b);
        self.wake.notify_one();
    }

    fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.wake.notify_all();
    }
}

/// The cascade serving engine.
pub struct CascadeServer {
    pub config: ServerConfig,
}

enum RouterMsg {
    Done { tier: usize, req: LiveRequest, output: Vec<i32>, exec_seconds: f64 },
    /// A request that was admitted by a worker that then died; the
    /// router re-queues it on the same tier (surviving replicas pick
    /// it up).
    Failed { tier: usize, req: LiveRequest },
    WorkerDead { tier: usize, err: String },
}

impl CascadeServer {
    pub fn new(config: ServerConfig) -> Result<CascadeServer> {
        if config.replicas.len() != config.max_batch.len() {
            anyhow::bail!(
                "replicas ({}) and max_batch ({}) must cover the same tiers",
                config.replicas.len(),
                config.max_batch.len()
            );
        }
        config.policy.validate(config.replicas.len())?;
        Ok(CascadeServer { config })
    }

    /// Build the server straight from a scheduler plan.
    pub fn from_plan(plan: &CascadePlan, max_new_tokens: usize) -> Result<CascadeServer> {
        CascadeServer::new(ServerConfig::from_plan(plan, max_new_tokens)?)
    }

    /// Serve a trace of (arrival_offset_seconds, prompt) pairs; blocks
    /// until all requests complete and returns the statistics.
    ///
    /// `factory(tier)` is called once per worker thread, inside that
    /// thread, to build its backend. `judger` scores responses on the
    /// request path.
    pub fn serve(
        &self,
        trace: &[(f64, Vec<i32>)],
        factory: &BackendFactory<'_>,
        judger: &dyn ResponseJudger,
    ) -> Result<ServerStats> {
        let c = self.config.replicas.len();
        let t0 = Instant::now();
        let tiers: Vec<TierState> = self
            .config
            .max_batch
            .iter()
            .map(|&mb| TierState::new(mb.max(1)))
            .collect();
        let (tx, rx) = channel::<RouterMsg>();
        let queue_time: Mutex<HashMap<usize, f64>> = Mutex::new(HashMap::new());

        let stats = std::thread::scope(|scope| -> Result<ServerStats> {
            // --- Workers ---
            for tier in 0..c {
                for _replica in 0..self.config.replicas[tier].max(1) {
                    let tier_state = &tiers[tier];
                    let tx = tx.clone();
                    let max_new = self.config.max_new_tokens;
                    scope.spawn(move || {
                        let mut backend = match factory(tier) {
                            Ok(b) => b,
                            Err(e) => {
                                let _ = tx.send(RouterMsg::WorkerDead {
                                    tier,
                                    err: e.to_string(),
                                });
                                return;
                            }
                        };
                        loop {
                            // Wait for work or shutdown.
                            let batch = {
                                let mut b = tier_state.batcher.lock().unwrap();
                                loop {
                                    let admitted = b.admit();
                                    if !admitted.is_empty() {
                                        break admitted;
                                    }
                                    if tier_state.closed.load(Ordering::SeqCst) {
                                        return;
                                    }
                                    b = tier_state.wake.wait(b).unwrap();
                                }
                            };
                            let n = batch.len();
                            let mut iter = batch.into_iter();
                            while let Some(pending) = iter.next() {
                                let started = Instant::now();
                                let result = backend.generate(&pending.item.prompt, max_new);
                                match result {
                                    Ok(output) => {
                                        let _ = tx.send(RouterMsg::Done {
                                            tier,
                                            req: pending.item,
                                            output,
                                            exec_seconds: started.elapsed().as_secs_f64(),
                                        });
                                    }
                                    Err(e) => {
                                        // Replica death: hand every
                                        // admitted-but-unserved request
                                        // back to the router, release
                                        // batch capacity, and exit.
                                        let _ = tx.send(RouterMsg::Failed {
                                            tier,
                                            req: pending.item,
                                        });
                                        for rest in iter.by_ref() {
                                            let _ = tx.send(RouterMsg::Failed {
                                                tier,
                                                req: rest.item,
                                            });
                                        }
                                        let _ = tx.send(RouterMsg::WorkerDead {
                                            tier,
                                            err: e.to_string(),
                                        });
                                        tier_state.batcher.lock().unwrap().complete(n);
                                        tier_state.wake.notify_all();
                                        return;
                                    }
                                }
                            }
                            tier_state.batcher.lock().unwrap().complete(n);
                            tier_state.wake.notify_all();
                        }
                    });
                }
            }
            drop(tx);

            // --- Submitter (paced by arrival offsets); the policy may
            // route a request past the small tiers before any model
            // runs (length-predictive entry). ---
            let submit_tiers = &tiers;
            let policy = &self.config.policy;
            scope.spawn(move || {
                for (i, (offset, prompt)) in trace.iter().enumerate() {
                    let target = Duration::from_secs_f64(*offset);
                    let elapsed = t0.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                    let features = RequestFeatures::live(prompt.len());
                    let entry = policy.entry_tier(&features, c).min(c - 1);
                    submit_tiers[entry].push(
                        LiveRequest { id: i, prompt: prompt.clone(), submitted: Instant::now() },
                        t0,
                    );
                }
            });

            // --- Router / coordinator ---
            let mut completions: Vec<Completion> = Vec::with_capacity(trace.len());
            let mut per_tier = vec![0usize; c];
            let mut done = 0usize;
            let mut worker_errors: Vec<String> = Vec::new();
            let mut dead = vec![0usize; c];
            while done < trace.len() {
                let msg = match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break, // all workers gone
                };
                match msg {
                    RouterMsg::WorkerDead { tier, err } => {
                        // A replica died: record and keep serving with the
                        // remaining replicas of that tier (failure
                        // injection tests exercise this path).
                        worker_errors.push(format!("tier {tier}: {err}"));
                        dead[tier] += 1;
                        if dead[tier] >= self.config.replicas[tier].max(1) {
                            // Unblock every surviving worker before
                            // returning, or thread::scope never joins.
                            for t in &tiers {
                                t.close();
                            }
                            anyhow::bail!(
                                "all replicas of tier {tier} died: {worker_errors:?}"
                            );
                        }
                        continue;
                    }
                    RouterMsg::Failed { tier, req } => {
                        // Re-route to the same tier; a surviving replica
                        // will serve it.
                        tiers[tier].push(req, t0);
                        continue;
                    }
                    RouterMsg::Done { tier, req, output, exec_seconds } => {
                        per_tier[tier] += 1;
                        let score = judger.score(&req.prompt, &output);
                        let features = RequestFeatures::live(req.prompt.len());
                        let decision = if tier == c - 1 {
                            Decision::Accept
                        } else {
                            self.config.policy.decide(tier, score, &features, c)
                        };
                        // A skip must move strictly forward; clamp a
                        // misbehaving target rather than wedging the
                        // request mid-flight.
                        let next_tier = match decision {
                            Decision::Accept => None,
                            Decision::Escalate => Some(tier + 1),
                            Decision::SkipTo(t) => Some(t.clamp(tier + 1, c - 1)),
                        };
                        if next_tier.is_none() {
                            let e2e = req.submitted.elapsed();
                            let execd = {
                                let mut qt = queue_time.lock().unwrap();
                                qt.remove(&req.id).unwrap_or(0.0) + exec_seconds
                            };
                            completions.push(Completion {
                                id: req.id,
                                output,
                                score,
                                accepting_tier: tier,
                                e2e_latency: e2e,
                                queue_latency: Duration::from_secs_f64(
                                    (e2e.as_secs_f64() - execd).max(0.0),
                                ),
                            });
                            done += 1;
                        } else {
                            let next = next_tier.unwrap();
                            queue_time.lock().unwrap().entry(req.id).or_insert(0.0);
                            *queue_time.lock().unwrap().get_mut(&req.id).unwrap() +=
                                exec_seconds;
                            tiers[next].push(req, t0);
                        }
                    }
                }
            }
            for t in &tiers {
                t.close();
            }
            if done < trace.len() {
                anyhow::bail!(
                    "served {done}/{} requests; worker errors: {:?}",
                    trace.len(),
                    worker_errors
                );
            }
            Ok(ServerStats {
                completions,
                wall_clock: t0.elapsed(),
                per_tier_processed: per_tier,
            })
        })?;

        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Simulated backend: deterministic "generation" with configurable
    /// per-tier delay; output quality encoded in first token.
    struct FakeBackend {
        tier: usize,
        delay: Duration,
    }

    impl TierBackend for FakeBackend {
        fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
            std::thread::sleep(self.delay);
            // Tier t "answers correctly" iff prompt difficulty <= t.
            let difficulty = prompt.first().copied().unwrap_or(0);
            let ok = difficulty <= self.tier as i32;
            Ok(vec![if ok { 1 } else { 0 }; max_new.min(4)])
        }
    }

    struct FakeJudger;

    impl ResponseJudger for FakeJudger {
        fn score(&self, _prompt: &[i32], output: &[i32]) -> f64 {
            if output.first() == Some(&1) {
                90.0
            } else {
                10.0
            }
        }
    }

    fn config() -> ServerConfig {
        ServerConfig::with_thresholds(vec![2, 1], vec![4, 2], vec![50.0], 4).unwrap()
    }

    fn factory(tier: usize) -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(FakeBackend { tier, delay: Duration::from_millis(2) }))
    }

    #[test]
    fn serves_all_and_routes_by_difficulty() {
        let server = CascadeServer::new(config()).unwrap();
        // Difficulty 0 -> accepted at tier 0; difficulty 1 -> escalated.
        let trace: Vec<(f64, Vec<i32>)> =
            (0..20).map(|i| (0.0, vec![(i % 2) as i32, 7, 8])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 20);
        assert_eq!(stats.per_tier_processed[0], 20);
        assert_eq!(stats.per_tier_processed[1], 10);
        for c in &stats.completions {
            let expect_tier = (trace[c.id].1[0]) as usize;
            assert_eq!(c.accepting_tier, expect_tier, "req {}", c.id);
            assert!(c.score >= 50.0 || c.accepting_tier == 1);
        }
        assert!(stats.throughput_rps() > 10.0);
    }

    #[test]
    fn escalated_requests_have_higher_latency() {
        let server = CascadeServer::new(config()).unwrap();
        let trace: Vec<(f64, Vec<i32>)> =
            (0..30).map(|i| (0.0, vec![(i % 2) as i32])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        let mean_of = |tier: usize| {
            let v: Vec<f64> = stats
                .completions
                .iter()
                .filter(|c| c.accepting_tier == tier)
                .map(|c| c.e2e_latency.as_secs_f64())
                .collect();
            stats_mean(&v)
        };
        assert!(mean_of(1) > mean_of(0));
    }

    fn stats_mean(v: &[f64]) -> f64 {
        crate::util::stats::mean(v)
    }

    #[test]
    fn replica_death_degrades_but_survives() {
        // Tier 0 has 2 replicas; one dies on first request. The other
        // must still finish everything.
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SPAWNED: AtomicUsize = AtomicUsize::new(0);

        struct DyingBackend {
            dies: bool,
            inner: FakeBackend,
        }
        impl TierBackend for DyingBackend {
            fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
                if self.dies {
                    anyhow::bail!("simulated replica crash");
                }
                self.inner.generate(prompt, max_new)
            }
        }

        let factory = |tier: usize| -> Result<Box<dyn TierBackend>> {
            let idx = SPAWNED.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(DyingBackend {
                // Exactly one tier-0 replica dies.
                dies: tier == 0 && idx == 0,
                inner: FakeBackend { tier, delay: Duration::from_millis(1) },
            }))
        };

        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![2, 1], vec![2, 2], vec![50.0], 2).unwrap(),
        )
        .unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..10).map(|_| (0.0, vec![0])).collect();
        // The dying replica hands its admitted requests back to the
        // router, which re-routes them to the surviving replica — every
        // request must complete.
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 10);
    }

    #[test]
    fn all_replicas_dead_fails_loudly() {
        struct AlwaysDies;
        impl TierBackend for AlwaysDies {
            fn generate(&mut self, _p: &[i32], _m: usize) -> Result<Vec<i32>> {
                anyhow::bail!("boom")
            }
        }
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![1, 1], vec![2, 2], vec![50.0], 2).unwrap(),
        )
        .unwrap();
        let factory = |_t: usize| -> Result<Box<dyn TierBackend>> { Ok(Box::new(AlwaysDies)) };
        let trace: Vec<(f64, Vec<i32>)> = (0..4).map(|_| (0.0, vec![0])).collect();
        let err = server.serve(&trace, &factory, &FakeJudger).unwrap_err();
        assert!(err.to_string().contains("all replicas"), "{err}");
    }

    #[test]
    fn queue_latency_reported() {
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![1, 1], vec![1, 1], vec![50.0], 2).unwrap(),
        )
        .unwrap();
        // Burst of easy requests through a single slow replica: most of
        // their latency must be queueing.
        let slow_factory = |tier: usize| -> Result<Box<dyn TierBackend>> {
            Ok(Box::new(FakeBackend { tier, delay: Duration::from_millis(10) }))
        };
        let trace: Vec<(f64, Vec<i32>)> = (0..6).map(|_| (0.0, vec![0])).collect();
        let stats = server.serve(&trace, &slow_factory, &FakeJudger).unwrap();
        let max_queue = stats
            .completions
            .iter()
            .map(|c| c.queue_latency.as_secs_f64())
            .fold(0.0, f64::max);
        assert!(max_queue > 0.02, "queueing should dominate: {max_queue}");
    }

    #[test]
    fn length_policy_enters_at_predicted_tier_live() {
        // Prompts with >= 5 tokens are predicted hard and enter at tier
        // 1; everything is easy (difficulty 0) so requests accept at
        // their entry tier.
        let server = CascadeServer::new(ServerConfig {
            replicas: vec![1, 1],
            max_batch: vec![4, 4],
            policy: PolicySpec::length(vec![0.0], 5.0, 1).unwrap(),
            max_new_tokens: 4,
        })
        .unwrap();
        let mut trace: Vec<(f64, Vec<i32>)> = Vec::new();
        for _ in 0..6 {
            trace.push((0.0, vec![0, 1])); // short -> tier 0
        }
        for _ in 0..4 {
            trace.push((0.0, vec![0, 1, 2, 3, 4, 5])); // long -> tier 1
        }
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 10);
        assert_eq!(stats.per_tier_processed, vec![6, 4]);
        for c in &stats.completions {
            let expect = if trace[c.id].1.len() >= 5 { 1 } else { 0 };
            assert_eq!(c.accepting_tier, expect, "req {}", c.id);
        }
    }

    #[test]
    fn margin_policy_skips_middle_tier_live() {
        // Difficulty-2 prompts fail tiers 0 and 1 (score 10); with a
        // tight margin the deep failure at tier 0 skips tier 1 and goes
        // straight to tier 2.
        let server = CascadeServer::new(ServerConfig {
            replicas: vec![1, 1, 1],
            max_batch: vec![2, 2, 2],
            policy: PolicySpec::margin(vec![80.0, 80.0], 5.0).unwrap(),
            max_new_tokens: 4,
        })
        .unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..8).map(|_| (0.0, vec![2, 9])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 8);
        assert_eq!(stats.per_tier_processed[0], 8);
        assert_eq!(stats.per_tier_processed[1], 0, "middle tier should be skipped");
        assert_eq!(stats.per_tier_processed[2], 8);
        assert!(stats.completions.iter().all(|c| c.accepting_tier == 2));
    }

    #[test]
    fn from_plan_derives_replicas_and_policy() {
        use crate::parallel::Strategy;
        use crate::perf::Workload;
        use crate::sched::plan::TierPlan;

        let plan = CascadePlan {
            policy: PolicySpec::threshold(vec![50.0]).unwrap(),
            tiers: vec![
                TierPlan {
                    model_name: "small".into(),
                    gpus: 4,
                    strategy: Some(Strategy::uniform(2, 1, 2)),
                    workload: Workload { rate: 4.0, avg_input: 300.0, avg_output: 100.0 },
                    processing_ratio: 1.0,
                    predicted_p95: 1.0,
                },
                TierPlan {
                    model_name: "large".into(),
                    gpus: 0,
                    strategy: None,
                    workload: Workload { rate: 0.0, avg_input: 0.0, avg_output: 0.0 },
                    processing_ratio: 0.0,
                    predicted_p95: 0.0,
                },
            ],
            predicted_latency: 1.0,
            predicted_quality: 80.0,
        };
        let cfg = ServerConfig::from_plan(&plan, 6).unwrap();
        assert_eq!(cfg.replicas, vec![2, 1]); // undeployed tier keeps 1 worker
        assert_eq!(cfg.policy.thresholds(), &[50.0]);
        assert_eq!(cfg.max_new_tokens, 6);
        assert_eq!(cfg.replicas.len(), cfg.max_batch.len());
        // The derived config constructs a valid server.
        CascadeServer::new(cfg).unwrap();
    }

    #[test]
    fn mismatched_policy_arity_rejected_at_construction() {
        let err = CascadeServer::new(ServerConfig {
            replicas: vec![1, 1, 1],
            max_batch: vec![2, 2, 2],
            policy: PolicySpec::threshold(vec![50.0]).unwrap(),
            max_new_tokens: 2,
        });
        assert!(err.is_err());
    }
}
