//! The live serving engine: policy-routed cascade serving over real
//! model execution.
//!
//! Topology: each deployed tier runs `replicas` worker threads; each
//! worker owns its own backend instance (PJRT executables are not
//! `Send`, so backends are constructed *inside* the worker via the
//! factory). A tier-level [`Batcher`] feeds workers FIFO under the
//! KV-capacity bound; a coordinator thread scores finished responses
//! with the live judger and asks the configured
//! [`crate::router::RoutingPolicy`] whether to complete the request,
//! escalate it, or skip ahead — the same routing workflow the
//! scheduler optimized (§3.3), now on the real request path.
//! [`ServerConfig::from_plan`] derives the whole configuration from a
//! scheduler-produced [`CascadePlan`].
//!
//! Worker inner loops run in one of two disciplines ([`ExecMode`]):
//! whole-batch lockstep (the measurable baseline), or the
//! continuous-batching execution engine ([`crate::engine`]) — requests
//! admitted and retired at decode-iteration granularity against paged
//! KV pools sized from the plan's own cost model
//! ([`ServerConfig::from_plan_with_engine`]), with per-tier queue and
//! page-occupancy telemetry on [`ServerStats`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::ClusterSpec;
use crate::coordinator::batcher::Batcher;
use crate::engine::{
    prompt_page_hashes, EngineConfig, EngineCore, EngineRole, MigrationHub, SpecPair, StepBackend,
};
use crate::models::ModelSpec;
use crate::obs::{
    Clock, EngineTracer, Event as ObsEvent, EventKind as ObsEventKind, MetricsRegistry,
    TraceRecorder, ACTION_ACCEPT, ACTION_ESCALATE, ACTION_SKIP, LATENCY_BUCKETS, REQ_NONE,
};
use crate::perf::{ReplicaModel, DEFAULT_PAGE_TOKENS};
use crate::router::{Decision, PolicySpec, RequestFeatures, RoutingPolicy};
use crate::sched::plan::{CascadePlan, DisaggSpec, SpecSpec};
use crate::util::stats;
use crate::util::sync::{CondvarExt, LockExt, RwLockExt};

/// Generates tokens for one tier. One instance per worker thread.
pub trait TierBackend {
    /// Greedy-decode up to `max_new` tokens after `prompt`.
    fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>>;

    /// Iteration-granular stepping interface, when the backend has one
    /// (see [`crate::engine::StepBackend`]). The continuous-batching
    /// engine probes this: a `Some` backend is stepped token-by-token;
    /// a `None` backend keeps working unchanged — its whole-request
    /// `generate` is adapted at the engine's prefill boundary.
    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        None
    }
}

/// Scores a (prompt, output) pair in [0, 100]. Shared across threads.
pub trait ResponseJudger: Send + Sync {
    fn score(&self, prompt: &[i32], output: &[i32]) -> f64;
}

/// Factory building a tier's backend inside its worker thread.
pub type BackendFactory<'a> =
    dyn Fn(usize) -> Result<Box<dyn TierBackend>> + Send + Sync + 'a;

/// Observes every admitted request — the adaptation subsystem's tap
/// into the live request stream (implementations feed the workload
/// monitor; see [`crate::adapt`]).
pub trait AdmissionObserver: Send + Sync {
    /// Called by the submitter as trace entry `req_index` is admitted,
    /// before entry routing. A swap the observer queues here is
    /// applied by the router between routing steps — promptly, but
    /// not necessarily before this request itself routes.
    fn on_admit(&self, req_index: usize);

    /// Called by the router as a request completes, with its accepting
    /// tier and end-to-end latency — the SLO burn-rate trigger's feed
    /// ([`crate::adapt`]). Default: ignore.
    fn on_complete(&self, tier: usize, e2e_s: f64) {
        let _ = (tier, e2e_s);
    }
}

/// Handle through which a running [`CascadeServer::serve_adaptive`]
/// loop accepts live plan hot-swaps.
///
/// [`ServeControl::apply_plan`] queues a new configuration (latest
/// submission wins); the serve loop applies it between routing steps:
/// the routing policy is swapped atomically, per-tier admission bounds
/// are rescaled, and worker pools are resized — all without dropping
/// in-flight requests. Scale-up spawns workers immediately; scale-down
/// retires surplus workers at their next safe boundary (a lockstep
/// worker's batch end; a continuous worker's first idle iteration
/// boundary), so a worker never abandons admitted work. Continuous
/// servers additionally rescale their per-tier KV pools from the
/// swapped config's engine sizing.
pub struct ServeControl {
    n_tiers: usize,
    /// The plan the server was launched from, when known: hot-swaps
    /// must preserve the cascade identity
    /// ([`CascadePlan::hot_swappable_with`]) — a plan scheduled for a
    /// different model cascade must not be swapped in just because the
    /// tier counts happen to match.
    reference: Option<CascadePlan>,
    pending: Mutex<Option<ServerConfig>>,
    hot_swaps: AtomicUsize,
}

impl ServeControl {
    /// Control knowing only the tier count (no cascade-identity check
    /// on swapped plans; prefer [`ServeControl::for_plan`]).
    pub fn new(n_tiers: usize) -> Arc<ServeControl> {
        Arc::new(ServeControl {
            n_tiers,
            reference: None,
            pending: Mutex::new(None),
            hot_swaps: AtomicUsize::new(0),
        })
    }

    /// Control for a server built from `plan`: swapped plans are
    /// validated against the launch plan's cascade identity (tier
    /// count and model per tier), not just its tier count.
    pub fn for_plan(plan: &CascadePlan) -> Arc<ServeControl> {
        Arc::new(ServeControl {
            n_tiers: plan.tiers.len(),
            reference: Some(plan.clone()),
            pending: Mutex::new(None),
            hot_swaps: AtomicUsize::new(0),
        })
    }

    /// Queue a scheduler plan for hot-swap into the running server.
    /// Fails fast if the plan does not cover the running cascade.
    pub fn apply_plan(&self, plan: &CascadePlan, max_new_tokens: usize) -> Result<()> {
        self.apply_plan_config(plan, ServerConfig::from_plan(plan, max_new_tokens)?)
    }

    /// Queue a pre-built configuration derived from `plan` (e.g.
    /// [`ServerConfig::from_plan_with_engine`]) with the same
    /// cascade-identity check as [`ServeControl::apply_plan`].
    pub fn apply_plan_config(&self, plan: &CascadePlan, config: ServerConfig) -> Result<()> {
        if let Some(reference) = &self.reference {
            if !reference.hot_swappable_with(plan) {
                anyhow::bail!(
                    "plan is not hot-swappable onto the running cascade: \
                     serving [{}], plan covers [{}]",
                    reference
                        .tiers
                        .iter()
                        .map(|t| t.model_name.as_str())
                        .collect::<Vec<_>>()
                        .join(", "),
                    plan.tiers
                        .iter()
                        .map(|t| t.model_name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        self.apply_config(config)
    }

    /// Queue a raw server configuration for hot-swap. The config must
    /// cover exactly the running cascade's tiers.
    pub fn apply_config(&self, config: ServerConfig) -> Result<()> {
        if config.replicas.len() != self.n_tiers || config.max_batch.len() != self.n_tiers {
            anyhow::bail!(
                "hot-swap config covers {} tiers but the server runs {}",
                config.replicas.len(),
                self.n_tiers
            );
        }
        if let ExecMode::Continuous(engines) = &config.exec {
            if engines.len() != self.n_tiers {
                anyhow::bail!(
                    "hot-swap engine configs cover {} tiers but the server runs {}",
                    engines.len(),
                    self.n_tiers
                );
            }
        }
        if !config.disagg.is_empty() && config.disagg.len() != self.n_tiers {
            anyhow::bail!(
                "hot-swap disagg covers {} tiers but the server runs {}",
                config.disagg.len(),
                self.n_tiers
            );
        }
        validate_speculation(&config.speculation, &config.disagg, self.n_tiers)?;
        config.policy.validate(self.n_tiers)?;
        *self.pending.plock() = Some(config);
        Ok(())
    }

    /// Number of swaps a serve loop has actually applied.
    pub fn hot_swaps(&self) -> usize {
        self.hot_swaps.load(Ordering::SeqCst)
    }

    fn take_pending(&self) -> Option<ServerConfig> {
        self.pending.plock().take()
    }
}

/// Validate a config's per-tier speculation against the cascade shape
/// (shared by server construction and hot-swap admission): the vector
/// covers all tiers or none, tier 0 never speculates (there is no
/// shallower tier to draft with), depths and acceptance rates are
/// sane, and speculation never rides a disaggregated tier — a
/// [`SpecPair`]'s draft state does not survive the prefill→decode KV
/// handoff.
fn validate_speculation(
    speculation: &[Option<SpecSpec>],
    disagg: &[Option<DisaggSpec>],
    n_tiers: usize,
) -> Result<()> {
    if !speculation.is_empty() && speculation.len() != n_tiers {
        anyhow::bail!(
            "speculation covers {} tiers but the server runs {}",
            speculation.len(),
            n_tiers
        );
    }
    for (t, s) in speculation.iter().enumerate() {
        let Some(s) = s else { continue };
        if t == 0 {
            anyhow::bail!("tier 0 cannot speculate: there is no shallower tier to draft with");
        }
        if s.draft_k == 0 {
            anyhow::bail!("tier {t}: speculation needs draft_k >= 1");
        }
        if !(0.0..=1.0).contains(&s.acceptance) {
            anyhow::bail!("tier {t}: speculation acceptance {} outside [0, 1]", s.acceptance);
        }
        if disagg.get(t).copied().flatten().is_some() {
            anyhow::bail!(
                "tier {t}: speculation cannot ride a prefill/decode split \
                 (draft state does not survive the KV handoff)"
            );
        }
    }
    Ok(())
}

/// Render a caught worker panic payload for the error path.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// A surplus worker (after a scale-down) retires iff it can decrement
/// the live count without dropping the pool below its target.
fn try_retire(alive: &AtomicUsize, target: &AtomicUsize) -> bool {
    loop {
        let a = alive.load(Ordering::SeqCst);
        if a <= target.load(Ordering::SeqCst) {
            return false;
        }
        if alive
            .compare_exchange(a, a - 1, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            return true;
        }
    }
}

/// Per-tier continuous-engine telemetry, aggregated across that tier's
/// workers as they iterate.
#[derive(Default)]
struct EngineTierCounters {
    peak_pool_pages: AtomicUsize,
    peak_pages: AtomicUsize,
    preemptions: AtomicUsize,
    iterations: AtomicUsize,
    forced_expansions: AtomicUsize,
    prefix_hit_tokens: AtomicUsize,
    shared_claims: AtomicUsize,
    cow_copies: AtomicUsize,
    swap_outs: AtomicUsize,
    swap_ins: AtomicUsize,
    swap_bytes: AtomicUsize,
    migrations: AtomicUsize,
    migrate_pages: AtomicUsize,
    spec_accepted_tokens: AtomicUsize,
    spec_rejected_tokens: AtomicUsize,
}

/// The continuous-batching inner loop of one tier worker: admit from
/// the tier batcher at every decode-iteration boundary, step the
/// engine one iteration, and retire finished requests to the router —
/// short requests overtake long batchmates instead of waiting out a
/// whole-batch lockstep.
///
/// Hot-swap semantics: the live pool size is re-read every iteration
/// (scale-down takes effect as sequences retire), and a surplus worker
/// (after a replica scale-down) stops admitting and retires at the
/// first iteration boundary where its running set has drained — not at
/// a whole-batch boundary, and never abandoning admitted work.
///
/// Disaggregated tiers run this same loop under a role tag: a
/// prefill-role worker admits from the batcher, mirrors the tier hub's
/// backpressure into its scheduler, and routes handed-off sequences
/// through the hub (re-owning any the hub bounces); a decode-role
/// worker never touches the batcher — the hub feeds it, it reports its
/// pool occupancy back for the least-loaded pick, and it exits when
/// the hub closes with nothing pending.
#[allow(clippy::too_many_arguments)]
fn continuous_worker_loop(
    tier: usize,
    backend: Box<dyn TierBackend>,
    cfg: EngineConfig,
    role: EngineRole,
    hub: Option<&MigrationHub<LiveRequest>>,
    pool_pages: &AtomicUsize,
    spec_k: &AtomicUsize,
    counters: &EngineTierCounters,
    tier_state: &TierState,
    alive: &AtomicUsize,
    feeders: &AtomicUsize,
    target: &AtomicUsize,
    tx: Sender<RouterMsg>,
    max_new: &AtomicUsize,
    t0: Instant,
    tracer: Option<EngineTracer>,
) {
    let mut engine: EngineCore<LiveRequest> = EngineCore::new(backend, cfg);
    engine.set_tracer(tracer.clone());
    engine.set_role(role);
    // A decode-role worker registers the hub slot handoffs route to.
    let slot = match (role, hub) {
        (EngineRole::Decode, Some(h)) => Some(h.register_decoder()),
        _ => None,
    };
    loop {
        // Pick up a hot-swapped pool size at the iteration boundary.
        let budget = pool_pages.load(Ordering::SeqCst).max(1);
        engine.set_pool_pages(budget);
        counters.peak_pool_pages.fetch_max(budget, Ordering::SeqCst);
        // Pick up a hot-swapped draft depth (0 disables drafting).
        // Safe between steps: a draft never spans an iteration, so no
        // draft state is stranded by flipping the knob here.
        let k = spec_k.load(Ordering::SeqCst);
        if engine.speculation() != k {
            engine.set_speculation(k);
        }
        if role == EngineRole::Prefill {
            // Mirror the hub's backpressure into the scheduler each
            // iteration: a closed hub (no live decoder, or transit
            // backlog over budget) makes newly prefilled sequences
            // decode locally instead of queueing behind the handoff.
            engine.set_migration_open(hub.map(|h| h.open()).unwrap_or(false));
        }
        if let (Some(s), Some(h)) = (slot, hub) {
            // Decode-role admission: drain the hub, blocking on it only
            // when the engine is idle. An empty wait result means the
            // hub closed with nothing pending — the exit signal.
            loop {
                let incoming = h.try_drain(s);
                if !incoming.is_empty() {
                    for m in incoming {
                        engine.submit_migrated(m);
                    }
                    break;
                }
                if !engine.is_idle() {
                    break;
                }
                let waited = h.pop_wait(s);
                if waited.is_empty() {
                    return;
                }
                for m in waited {
                    engine.submit_migrated(m);
                }
                break;
            }
        } else {
            // Batcher admission (or, when idle, wait for work /
            // shutdown / retire) — unified and prefill-role workers.
            let mut b = tier_state.batcher.plock();
            loop {
                let surplus = alive.load(Ordering::SeqCst) > target.load(Ordering::SeqCst);
                if !surplus {
                    // Share by the live batcher-admitting worker count:
                    // a disagg tier's decode workers never admit, so
                    // they must not dilute the prefill pool's share.
                    let pool = feeders.load(Ordering::SeqCst).max(1);
                    let share = (b.max_batch / pool).max(1);
                    let room = share.saturating_sub(engine.n_seqs());
                    for p in b.admit_up_to(room, t0.elapsed().as_secs_f64()) {
                        let prompt = p.item.prompt.clone();
                        let rid = p.item.id as u64;
                        let mn = p
                            .item
                            .max_new
                            .unwrap_or_else(|| max_new.load(Ordering::SeqCst))
                            .max(1);
                        // Escalated requests arrive with their prompt
                        // hashes already chained (computed once at
                        // submission) — a deeper-tier re-serve claims
                        // shared pages without rehashing.
                        let hashes = if cfg.page_tokens == DEFAULT_PAGE_TOKENS {
                            p.item.hashes.clone()
                        } else {
                            None
                        };
                        if let Some(tr) = &tracer {
                            tr.emit(rid, ObsEventKind::QueueExit, 0, 0, 0);
                        }
                        // The GLOBAL request id keys this sequence's
                        // trace events, so escalation chains stay
                        // linked across per-tier engines.
                        engine.submit_traced(p.item, prompt, mn, hashes, rid);
                    }
                }
                if !engine.is_idle() {
                    break;
                }
                if tier_state.closed.load(Ordering::SeqCst) {
                    return;
                }
                // Idle = an iteration boundary with nothing running:
                // the continuous engine's retirement point. Only
                // batcher-admitting roles reach here, so the feeder
                // count retires with the worker.
                if try_retire(alive, target) {
                    feeders.fetch_sub(1, Ordering::SeqCst);
                    return;
                }
                b = tier_state.wake.pwait(b);
            }
        }
        // One decode iteration. Panics in the backend are contained
        // exactly like the lockstep path's.
        let step = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| engine.step()))
            .unwrap_or_else(|p| {
                Err(anyhow::anyhow!("backend panicked: {}", panic_msg(&*p)))
            });
        match step {
            Ok(out) => {
                counters.iterations.fetch_add(1, Ordering::SeqCst);
                counters.peak_pages.fetch_max(out.pages_in_use, Ordering::SeqCst);
                counters.preemptions.fetch_add(out.preempted, Ordering::SeqCst);
                counters
                    .forced_expansions
                    .fetch_add(out.forced_expansions, Ordering::SeqCst);
                counters
                    .prefix_hit_tokens
                    .fetch_add(out.prefix_hit_tokens, Ordering::SeqCst);
                counters.shared_claims.fetch_add(out.shared_claims, Ordering::SeqCst);
                counters.cow_copies.fetch_add(out.cow_copies, Ordering::SeqCst);
                counters.swap_outs.fetch_add(out.swap_outs, Ordering::SeqCst);
                counters.swap_ins.fetch_add(out.swap_ins, Ordering::SeqCst);
                counters
                    .spec_accepted_tokens
                    .fetch_add(out.spec_accepted, Ordering::SeqCst);
                counters
                    .spec_rejected_tokens
                    .fetch_add(out.spec_rejected, Ordering::SeqCst);
                counters.swap_bytes.fetch_add(
                    (out.swap_pages as f64 * cfg.preemption.page_bytes) as usize,
                    Ordering::SeqCst,
                );
                if role == EngineRole::Decode {
                    // Migration telemetry counts at the receiving side:
                    // one handoff, one migration, its private pages.
                    counters.migrations.fetch_add(out.migrated_in, Ordering::SeqCst);
                    counters.migrate_pages.fetch_add(out.migrate_pages, Ordering::SeqCst);
                    if let (Some(s), Some(h)) = (slot, hub) {
                        h.report_pages(s, engine.kv_in_use());
                    }
                }
                if !out.migrated_out.is_empty() {
                    // Route handed-off sequences to a decode worker. A
                    // bounce (decoder died or hub closed since the
                    // open() check) re-owns the sequence: it decodes
                    // locally, exactly-once preserved.
                    for m in out.migrated_out {
                        match hub {
                            Some(h) => {
                                if let Err(back) = h.push(m) {
                                    engine.submit_migrated(back);
                                }
                            }
                            None => engine.submit_migrated(m),
                        }
                    }
                }
                if !out.completed.is_empty() {
                    let n = out.completed.len();
                    for fin in out.completed {
                        let _ = tx.send(RouterMsg::Done {
                            tier,
                            req: fin.payload,
                            output: fin.output,
                            exec_seconds: fin.exec_seconds,
                            first_token_at: fin.first_token_at,
                        });
                    }
                    tier_state.batcher.plock().complete(n);
                    tier_state.wake.notify_all();
                }
            }
            Err(e) => {
                // Replica death: hand every in-engine request back to
                // the router (none completed this step — exactly-once
                // is preserved), release batch capacity, and exit. A
                // dying decode worker also retires its hub slot: queued
                // handoffs re-route to surviving decoders, and any the
                // hub cannot place come back here to fail upstream —
                // nothing is lost mid-migration.
                let mut failed: Vec<LiveRequest> = engine.drain();
                if let (Some(s), Some(h)) = (slot, hub) {
                    failed.extend(h.retire(s).into_iter().map(|m| m.payload));
                }
                let n = failed.len();
                for req in failed {
                    let _ = tx.send(RouterMsg::Failed { tier, req });
                }
                alive.fetch_sub(1, Ordering::SeqCst);
                if role != EngineRole::Decode {
                    feeders.fetch_sub(1, Ordering::SeqCst);
                }
                let _ = tx.send(RouterMsg::WorkerDead { tier, err: e.to_string() });
                tier_state.batcher.plock().complete(n);
                tier_state.wake.notify_all();
                return;
            }
        }
    }
}

/// How a tier worker's inner loop executes its admitted work.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecMode {
    /// Whole-batch lockstep: a worker admits a batch, runs every
    /// request to completion, and only then admits more — the
    /// pre-engine discipline, kept as the measurable baseline.
    BatchLockstep,
    /// Iteration-granular continuous batching through
    /// [`crate::engine::EngineCore`], one entry per tier sizing each
    /// replica's paged KV pool. Requests join and leave the running
    /// batch at decode-iteration boundaries.
    Continuous(Vec<EngineConfig>),
}

/// Server configuration: one entry per tier, in cascade order.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Worker replicas per tier (from the plan's strategy replica count).
    pub replicas: Vec<usize>,
    /// Max batch admitted per tier iteration.
    pub max_batch: Vec<usize>,
    /// Routing policy deciding acceptance/escalation per scored
    /// response.
    pub policy: PolicySpec,
    /// Max tokens to generate per request.
    pub max_new_tokens: usize,
    /// Worker inner-loop discipline. The mode is fixed for a run; a
    /// hot-swapped config only retunes the continuous pools.
    pub exec: ExecMode,
    /// Per-tier prefill/decode split (empty vec or `None` entries =
    /// unified). A split tier partitions its worker pool into
    /// prefill-role and decode-role workers wired through a tier-local
    /// [`MigrationHub`]; the split's total must equal `replicas[t]`.
    /// Splits take effect only under [`ExecMode::Continuous`] — a
    /// lockstep server has no iteration boundary to hand off at and
    /// serves the tier unified. The split is fixed for a run: hot-swaps
    /// leave a disaggregated tier's worker counts untouched.
    pub disagg: Vec<Option<DisaggSpec>>,
    /// Per-tier cross-tier speculative decoding (empty vec or `None`
    /// entries = plain decode). A speculating tier's workers draft
    /// `draft_k` tokens per steady decoder with a tier-below backend
    /// and verify them in one step — lossless: every emitted token is
    /// the tier's own model's choice. Never valid on tier 0 (no
    /// shallower tier to draft with) or on a tier that also runs a
    /// prefill/decode split (draft state does not survive the KV
    /// handoff). Takes effect only under [`ExecMode::Continuous`];
    /// hot-swaps retune or disable the depth at iteration boundaries.
    pub speculation: Vec<Option<SpecSpec>>,
}

impl ServerConfig {
    /// Convenience constructor for the classic fixed-threshold server.
    pub fn with_thresholds(
        replicas: Vec<usize>,
        max_batch: Vec<usize>,
        thresholds: Vec<f64>,
        max_new_tokens: usize,
    ) -> Result<ServerConfig> {
        Ok(ServerConfig {
            replicas,
            max_batch,
            policy: PolicySpec::threshold(thresholds)?,
            max_new_tokens,
            exec: ExecMode::BatchLockstep,
            disagg: Vec::new(),
            speculation: Vec::new(),
        })
    }

    /// The prefill/decode split configured for `tier`, if any.
    pub fn disagg_for(&self, tier: usize) -> Option<DisaggSpec> {
        self.disagg.get(tier).copied().flatten()
    }

    /// The speculative-decoding config of `tier`, if any.
    pub fn speculation_for(&self, tier: usize) -> Option<SpecSpec> {
        self.speculation.get(tier).copied().flatten()
    }

    /// Switch this configuration to the continuous-batching engine
    /// with per-tier pool sizing.
    pub fn continuous(mut self, engines: Vec<EngineConfig>) -> ServerConfig {
        self.exec = ExecMode::Continuous(engines);
        self
    }

    /// Derive a serving configuration from a scheduler-produced plan:
    /// the plan's policy routes, its strategies set the replica counts,
    /// and admission scales with the allocation. Undeployed tiers keep
    /// one idle worker so skip/escalation targets always exist (the
    /// policy routes no steady-state traffic to them).
    pub fn from_plan(plan: &CascadePlan, max_new_tokens: usize) -> Result<ServerConfig> {
        plan.policy.validate(plan.tiers.len())?;
        let replicas: Vec<usize> = plan
            .tiers
            .iter()
            .map(|t| t.strategy.as_ref().map(|s| s.n_replicas()).unwrap_or(0).max(1))
            .collect();
        let max_batch: Vec<usize> = plan
            .tiers
            .iter()
            .map(|t| (t.gpus.max(1) * 2).clamp(1, 16))
            .collect();
        Ok(ServerConfig {
            replicas,
            max_batch,
            policy: plan.policy.clone(),
            max_new_tokens,
            exec: ExecMode::BatchLockstep,
            disagg: plan.tiers.iter().map(|t| t.disagg).collect(),
            speculation: plan.tiers.iter().map(|t| t.speculation).collect(),
        })
    }

    /// Like [`ServerConfig::from_plan`], but workers run the
    /// continuous-batching engine with per-replica KV pools sized from
    /// the plan's own parallelism under the scheduler's cost model
    /// ([`ReplicaModel::kv_pages_total`]) — the plan's memory terms and
    /// the runtime's page accounting agree by construction. The plan's
    /// per-tier preemption knob ([`CascadePlan::preemption_for`])
    /// selects each tier's eviction discipline, with the swap budget
    /// and PCIe cost terms derived from the same replica model —
    /// schedule→serve round-trips the whole policy. Tiers the plan
    /// splits ([`crate::sched::plan::TierPlan::disagg`]) come out as
    /// disaggregated worker pools. Undeployed tiers get a nominal pool.
    pub fn from_plan_with_engine(
        plan: &CascadePlan,
        cascade: &[ModelSpec],
        cluster: &ClusterSpec,
        max_new_tokens: usize,
    ) -> Result<ServerConfig> {
        if cascade.len() != plan.tiers.len() {
            anyhow::bail!(
                "cascade has {} models but the plan covers {} tiers",
                cascade.len(),
                plan.tiers.len()
            );
        }
        let cfg = Self::from_plan(plan, max_new_tokens)?;
        let engines: Vec<EngineConfig> = plan
            .tiers
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let avg_ctx = (t.workload.avg_input + t.workload.avg_output).max(64.0);
                match t.strategy.as_ref().and_then(|s| s.groups.first()) {
                    Some(g) => {
                        let rm = ReplicaModel::from_group(&cascade[i], cluster, g, avg_ctx);
                        EngineConfig::for_replica_with_preemption(
                            &rm,
                            DEFAULT_PAGE_TOKENS,
                            plan.preemption_for(i),
                        )
                    }
                    None => EngineConfig::nominal(DEFAULT_PAGE_TOKENS),
                }
            })
            .collect();
        Ok(cfg.continuous(engines))
    }
}

/// One entry of a serving trace: arrival offset, prompt, and an
/// optional per-request decode budget overriding the server-wide
/// `max_new_tokens` — traces reproduce their length mixtures instead
/// of decoding every request to one global depth.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Arrival offset from serve start, seconds.
    pub at: f64,
    pub prompt: Vec<i32>,
    /// Per-request decode budget (None = server default).
    pub max_new: Option<usize>,
}

impl TraceEntry {
    pub fn new(at: f64, prompt: Vec<i32>) -> TraceEntry {
        TraceEntry { at, prompt, max_new: None }
    }
}

/// One in-flight request.
#[derive(Debug, Clone)]
struct LiveRequest {
    id: usize,
    prompt: Vec<i32>,
    submitted: Instant,
    /// Per-request decode budget (None = server default).
    max_new: Option<usize>,
    /// Chained prompt page hashes at [`DEFAULT_PAGE_TOKENS`], computed
    /// once at submission and carried through every escalation so
    /// deeper-tier engines claim shared prefix pages without
    /// rehashing. None on lockstep servers (nothing would claim them).
    hashes: Option<Arc<Vec<u64>>>,
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub output: Vec<i32>,
    pub score: f64,
    pub accepting_tier: usize,
    pub e2e_latency: Duration,
    /// Time spent queued (all tiers) vs executing.
    pub queue_latency: Duration,
    /// Submission to first generated token anywhere in the cascade
    /// (the entry tier's TTFT; whole-request backends report their
    /// completion instant — they do not stream).
    pub ttft: Duration,
}

/// Queue telemetry of one tier's batcher over a run (the counters the
/// batcher always tracked but never reported).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierQueueStats {
    /// Peak queue depth seen.
    pub peak_depth: usize,
    /// Items admitted over the run.
    pub admitted: usize,
    /// Mean seconds admitted items spent queued.
    pub mean_wait_s: f64,
}

/// Continuous-engine telemetry of one tier, aggregated across its
/// workers (all-zero under [`ExecMode::BatchLockstep`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierEngineStats {
    /// Configured KV pages per replica pool (post-swap value).
    pub pool_pages: usize,
    /// Largest configured pool budget in force at any iteration of the
    /// run. Occupancy invariants compare against THIS, not
    /// `pool_pages`: a pool-shrinking hot-swap legitimately leaves
    /// `peak_pages` above the final budget while sequences admitted
    /// under the old budget drain.
    pub peak_pool_pages: usize,
    /// Peak pages any one replica had allocated in an iteration.
    pub peak_pages: usize,
    /// Sequences preempted-and-requeued on pool exhaustion.
    pub preemptions: usize,
    /// Decode iterations executed (all replicas).
    pub iterations: usize,
    /// Forced pool expansions (pool smaller than one sequence) — 0 in
    /// any sanely sized deployment.
    pub forced_expansions: usize,
    /// Prompt tokens served from shared prefix pages instead of being
    /// re-prefilled (system prompts, retries, cascade re-serves).
    pub prefix_hit_tokens: usize,
    /// Pages claimed through the prefix trie.
    pub shared_claims: usize,
    /// Copy-on-write page copies (divergence after a shared claim).
    pub cow_copies: usize,
    /// Sequences swapped out to host (swap-to-host preemption; their
    /// checkpointed progress survives, unlike `preemptions`).
    pub swap_outs: usize,
    /// Sequences resumed from host swap space.
    pub swap_ins: usize,
    /// Bytes moved across PCIe by KV swaps, both directions.
    pub swap_bytes: usize,
    /// Prefill→decode handoffs admitted on this tier's decode-role
    /// engines (0 on unified tiers). Counted at the decode side so a
    /// handoff is one migration, not two.
    pub migrations: usize,
    /// Private KV pages that crossed the interconnect with those
    /// handoffs (shared prefix pages re-claim locally and don't count).
    pub migrate_pages: usize,
    /// Draft tokens the tier's verify steps accepted (0 on tiers
    /// without speculation). Each accepted token is one decode
    /// iteration the deep tier did not have to run.
    pub spec_accepted_tokens: usize,
    /// Draft tokens rejected at verification (the losslessness price:
    /// rejected positions are re-emitted by the verify model itself).
    pub spec_rejected_tokens: usize,
}

/// Aggregate statistics of a serving run.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub completions: Vec<Completion>,
    pub wall_clock: Duration,
    pub per_tier_processed: Vec<usize>,
    /// Per-tier queue telemetry.
    pub queue: Vec<TierQueueStats>,
    /// Per-tier continuous-engine telemetry (zeros under lockstep).
    pub engine: Vec<TierEngineStats>,
}

impl ServerStats {
    pub fn p95_latency(&self) -> f64 {
        let v: Vec<f64> = self.completions.iter().map(|c| c.e2e_latency.as_secs_f64()).collect();
        stats::percentile(&v, 0.95)
    }

    pub fn mean_latency(&self) -> f64 {
        let v: Vec<f64> = self.completions.iter().map(|c| c.e2e_latency.as_secs_f64()).collect();
        stats::mean(&v)
    }

    /// Full p50/p95/p99 + mean tail summary of end-to-end latencies
    /// (the server's summary used to be mean/p95-only). One sort per
    /// call — read the percentiles off the returned summary rather
    /// than calling per-percentile.
    pub fn latency_summary(&self) -> crate::metrics::LatencySummary {
        let v: Vec<f64> = self.completions.iter().map(|c| c.e2e_latency.as_secs_f64()).collect();
        crate::metrics::LatencySummary::of(&v)
    }

    /// p95 of submission-to-first-token latency across completions —
    /// the tail the chunked-prefill budget exists to flatten (0.0 when
    /// nothing completed).
    pub fn p95_ttft(&self) -> f64 {
        let v: Vec<f64> = self.completions.iter().map(|c| c.ttft.as_secs_f64()).collect();
        if v.is_empty() {
            return 0.0;
        }
        stats::percentile(&v, 0.95)
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completions.len() as f64 / self.wall_clock.as_secs_f64().max(1e-9)
    }

    pub fn mean_quality(&self) -> f64 {
        let v: Vec<f64> = self.completions.iter().map(|c| c.score).collect();
        stats::mean(&v)
    }

    pub fn processing_ratios(&self) -> Vec<f64> {
        let n = self.completions.len().max(1) as f64;
        self.per_tier_processed.iter().map(|&c| c as f64 / n).collect()
    }
}

/// Work distribution state for one tier.
struct TierState {
    batcher: Mutex<Batcher<LiveRequest>>,
    wake: Condvar,
    /// Set when no more work will ever arrive for this tier.
    closed: AtomicBool,
}

impl TierState {
    fn new(max_batch: usize) -> TierState {
        TierState {
            batcher: Mutex::new(Batcher::new(max_batch)),
            wake: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    fn push(&self, req: LiveRequest, t0: Instant) {
        let mut b = self.batcher.plock();
        b.push(req, t0.elapsed().as_secs_f64());
        drop(b);
        self.wake.notify_one();
    }

    fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.wake.notify_all();
    }
}

/// Tracing + metrics sinks for one serving run (see [`crate::obs`]).
///
/// The caller keeps its own `Arc` clones: after the run, read the
/// event timeline off `recorder` (Chrome export, timeline diff) and
/// scrape `registry` ([`MetricsRegistry::render_prometheus`]). One
/// recorder shard per tier keeps worker emission contention-free; the
/// router and submitter emit on the shard of the tier they touch.
pub struct ServeTelemetry {
    pub recorder: Arc<TraceRecorder>,
    pub registry: Arc<MetricsRegistry>,
}

impl ServeTelemetry {
    /// Sinks sized for an `n_tiers` cascade.
    pub fn for_tiers(n_tiers: usize) -> Arc<ServeTelemetry> {
        Arc::new(ServeTelemetry {
            recorder: Arc::new(TraceRecorder::for_tiers(n_tiers)),
            registry: Arc::new(MetricsRegistry::new()),
        })
    }
}

/// The cascade serving engine.
pub struct CascadeServer {
    pub config: ServerConfig,
    /// Optional tracing/metrics sinks; `None` (the default) keeps the
    /// request path free of any observability work.
    telemetry: Option<Arc<ServeTelemetry>>,
}

enum RouterMsg {
    Done {
        tier: usize,
        req: LiveRequest,
        output: Vec<i32>,
        exec_seconds: f64,
        first_token_at: Option<Instant>,
    },
    /// A request that was admitted by a worker that then died; the
    /// router re-queues it on the same tier (surviving replicas pick
    /// it up).
    Failed { tier: usize, req: LiveRequest },
    WorkerDead { tier: usize, err: String },
}

impl CascadeServer {
    pub fn new(config: ServerConfig) -> Result<CascadeServer> {
        if config.replicas.len() != config.max_batch.len() {
            anyhow::bail!(
                "replicas ({}) and max_batch ({}) must cover the same tiers",
                config.replicas.len(),
                config.max_batch.len()
            );
        }
        if let ExecMode::Continuous(engines) = &config.exec {
            if engines.len() != config.replicas.len() {
                anyhow::bail!(
                    "engine configs cover {} tiers but the server runs {}",
                    engines.len(),
                    config.replicas.len()
                );
            }
            for (t, e) in engines.iter().enumerate() {
                if e.pool_pages == 0 || e.page_tokens == 0 || e.max_running == 0 {
                    anyhow::bail!("tier {t}: engine pool/page/batch sizes must be positive");
                }
            }
        }
        if !config.disagg.is_empty() && config.disagg.len() != config.replicas.len() {
            anyhow::bail!(
                "disagg covers {} tiers but the server runs {}",
                config.disagg.len(),
                config.replicas.len()
            );
        }
        for (t, d) in config.disagg.iter().enumerate() {
            if let Some(d) = d {
                if d.prefill_replicas == 0 || d.decode_replicas == 0 {
                    anyhow::bail!("tier {t}: a disagg split needs both roles staffed");
                }
                if d.total() != config.replicas[t] {
                    anyhow::bail!(
                        "tier {t}: disagg split {}p+{}d != {} replicas",
                        d.prefill_replicas,
                        d.decode_replicas,
                        config.replicas[t]
                    );
                }
            }
        }
        validate_speculation(&config.speculation, &config.disagg, config.replicas.len())?;
        config.policy.validate(config.replicas.len())?;
        Ok(CascadeServer { config, telemetry: None })
    }

    /// Build the server straight from a scheduler plan.
    pub fn from_plan(plan: &CascadePlan, max_new_tokens: usize) -> Result<CascadeServer> {
        CascadeServer::new(ServerConfig::from_plan(plan, max_new_tokens)?)
    }

    /// Attach (or detach) tracing + metrics sinks for subsequent serve
    /// calls. The caller keeps its own `Arc` to read results after the
    /// run.
    pub fn set_telemetry(&mut self, telemetry: Option<Arc<ServeTelemetry>>) {
        self.telemetry = telemetry;
    }

    /// Serve a trace of (arrival_offset_seconds, prompt) pairs; blocks
    /// until all requests complete and returns the statistics.
    ///
    /// `factory(tier)` is called once per worker thread, inside that
    /// thread, to build its backend. `judger` scores responses on the
    /// request path.
    pub fn serve(
        &self,
        trace: &[(f64, Vec<i32>)],
        factory: &BackendFactory<'_>,
        judger: &dyn ResponseJudger,
    ) -> Result<ServerStats> {
        let entries: Vec<TraceEntry> =
            trace.iter().map(|(at, p)| TraceEntry::new(*at, p.clone())).collect();
        self.run(&entries, factory, judger, None, None)
    }

    /// Like [`CascadeServer::serve`], with per-request decode budgets
    /// ([`TraceEntry::max_new`]) so replayed traces reproduce their
    /// output-length mixture instead of a single global depth.
    pub fn serve_entries(
        &self,
        trace: &[TraceEntry],
        factory: &BackendFactory<'_>,
        judger: &dyn ResponseJudger,
    ) -> Result<ServerStats> {
        self.run(trace, factory, judger, None, None)
    }

    /// Like [`CascadeServer::serve`], but the run accepts live plan
    /// hot-swaps through `control` (routing policy, admission bounds,
    /// and worker-pool sizes change mid-run without dropping in-flight
    /// requests) and reports every admitted request to `observer` —
    /// the tap the adaptation subsystem ([`crate::adapt`]) feeds its
    /// workload monitor from.
    pub fn serve_adaptive(
        &self,
        trace: &[(f64, Vec<i32>)],
        factory: &BackendFactory<'_>,
        judger: &dyn ResponseJudger,
        control: &ServeControl,
        observer: Option<&dyn AdmissionObserver>,
    ) -> Result<ServerStats> {
        let entries: Vec<TraceEntry> =
            trace.iter().map(|(at, p)| TraceEntry::new(*at, p.clone())).collect();
        self.serve_adaptive_entries(&entries, factory, judger, control, observer)
    }

    /// [`CascadeServer::serve_adaptive`] over [`TraceEntry`] records
    /// (per-request decode budgets).
    pub fn serve_adaptive_entries(
        &self,
        trace: &[TraceEntry],
        factory: &BackendFactory<'_>,
        judger: &dyn ResponseJudger,
        control: &ServeControl,
        observer: Option<&dyn AdmissionObserver>,
    ) -> Result<ServerStats> {
        if control.n_tiers != self.config.replicas.len() {
            anyhow::bail!(
                "control is sized for {} tiers but the server runs {}",
                control.n_tiers,
                self.config.replicas.len()
            );
        }
        self.run(trace, factory, judger, Some(control), observer)
    }

    fn run(
        &self,
        trace: &[TraceEntry],
        factory: &BackendFactory<'_>,
        judger: &dyn ResponseJudger,
        control: Option<&ServeControl>,
        observer: Option<&dyn AdmissionObserver>,
    ) -> Result<ServerStats> {
        let c = self.config.replicas.len();
        let t0 = Instant::now();
        // Observability sinks for this run: one wall clock anchored at
        // t0 stamps every event, so timestamps are seconds-from-serve-
        // start (directly comparable with DES timelines). `None` keeps
        // every emission branch dead.
        let telem: Option<Arc<ServeTelemetry>> = self.telemetry.clone();
        let clock = Clock::wall_from(t0);
        let tiers: Vec<TierState> = self
            .config
            .max_batch
            .iter()
            .map(|&mb| TierState::new(mb.max(1)))
            .collect();
        // Continuous-engine state: per-tier live pool sizes (the
        // hot-swap lever — workers re-read them at every iteration
        // boundary) and the telemetry the run reports.
        let engine_mode: Option<&[EngineConfig]> = match &self.config.exec {
            ExecMode::Continuous(v) => Some(v.as_slice()),
            ExecMode::BatchLockstep => None,
        };
        let pool_pages_live: Vec<AtomicUsize> = (0..c)
            .map(|t| AtomicUsize::new(engine_mode.map(|v| v[t].pool_pages).unwrap_or(0)))
            .collect();
        // Per-tier live draft depth (the speculation hot-swap lever —
        // workers re-read it at every iteration boundary; 0 = off).
        let spec_k_live: Vec<AtomicUsize> = (0..c)
            .map(|t| {
                AtomicUsize::new(
                    self.config.speculation_for(t).map(|s| s.draft_k).unwrap_or(0),
                )
            })
            .collect();
        let engine_counters: Vec<EngineTierCounters> =
            (0..c).map(|_| EngineTierCounters::default()).collect();
        // Per-tier migration hubs for disaggregated tiers (continuous
        // mode only): the tier-local router between its prefill- and
        // decode-role worker pools. The in-transit page budget mirrors
        // the tier's per-replica pool, so a stalled decode pool closes
        // the hub long before handoffs could queue unboundedly.
        let hubs: Vec<Option<MigrationHub<LiveRequest>>> = (0..c)
            .map(|t| match (engine_mode, self.config.disagg_for(t)) {
                (Some(engines), Some(_)) => Some(MigrationHub::new(engines[t].pool_pages)),
                _ => None,
            })
            .collect();
        // Live batcher-admitting workers per tier (unified + prefill
        // roles): sizes each feeder's admission share, and detects the
        // unservable state where a disagg tier's prefill pool is gone.
        let feeders: Vec<AtomicUsize> = (0..c).map(|_| AtomicUsize::new(0)).collect();
        // Swappable routing/pool state: the policy the submitter and
        // router consult, and the per-tier live/target worker counts
        // the pools converge to after a hot-swap.
        let policy: RwLock<PolicySpec> = RwLock::new(self.config.policy.clone());
        let max_new_live = AtomicUsize::new(self.config.max_new_tokens);
        let alive: Vec<AtomicUsize> = (0..c).map(|_| AtomicUsize::new(0)).collect();
        let target: Vec<AtomicUsize> = self
            .config
            .replicas
            .iter()
            .map(|&r| AtomicUsize::new(r.max(1)))
            .collect();
        let (tx, rx) = channel::<RouterMsg>();
        let queue_time: Mutex<HashMap<usize, f64>> = Mutex::new(HashMap::new());
        // First-token instant per request id (the entry tier's — set
        // once, survives escalations).
        let first_tokens: Mutex<HashMap<usize, Duration>> = Mutex::new(HashMap::new());

        let stats = std::thread::scope(|scope| -> Result<ServerStats> {
            // --- Workers (spawnable mid-run for hot-swap scale-up) ---
            let alive = &alive;
            let feeders = &feeders;
            let target = &target;
            let tiers_ref = &tiers;
            let hubs_ref = &hubs;
            let max_new = &max_new_live;
            let pool_live_ref = &pool_pages_live;
            let spec_live_ref = &spec_k_live;
            let spec_cfg = &self.config.speculation;
            let engine_ctr_ref = &engine_counters;
            let telem_ref = &telem;
            let clock_ref = &clock;
            let spawn_worker = |tier: usize, role: EngineRole| {
                let tier_state = &tiers_ref[tier];
                let tx = tx.clone();
                // Workers emit on their tier's recorder shard; the
                // router is the terminal authority for `finished`
                // (a request may traverse several engines).
                let tracer = telem_ref.as_ref().map(|tm| EngineTracer {
                    recorder: Arc::clone(&tm.recorder),
                    shard: tier,
                    tier: tier as u32,
                    clock: clock_ref.clone(),
                    terminal: false,
                });
                alive[tier].fetch_add(1, Ordering::SeqCst);
                if role != EngineRole::Decode {
                    feeders[tier].fetch_add(1, Ordering::SeqCst);
                }
                scope.spawn(move || {
                    // Panics in the backend are contained and converted
                    // to the replica-death path: an unwinding worker
                    // would bypass the alive/WorkerDead accounting and
                    // leave the router waiting forever.
                    // A speculating tier pairs its verify backend with
                    // a tier-below draft backend behind a [`SpecPair`],
                    // giving generate-based backends the draft/verify
                    // stepping interface. Backends with native stepping
                    // keep it — the engine probes their own
                    // draft/verify and falls back to plain decode where
                    // unsupported.
                    let wants_spec = engine_mode.is_some()
                        && tier > 0
                        && spec_cfg.get(tier).copied().flatten().is_some();
                    let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        let mut b = factory(tier)?;
                        if wants_spec && b.step_backend().is_none() {
                            return Ok(Box::new(SpecPair::new(factory(tier - 1)?, b))
                                as Box<dyn TierBackend>);
                        }
                        Ok(b)
                    }))
                    .unwrap_or_else(|p| {
                        Err(anyhow::anyhow!("backend factory panicked: {}", panic_msg(&*p)))
                    });
                    let mut backend = match built {
                        Ok(b) => b,
                        Err(e) => {
                            alive[tier].fetch_sub(1, Ordering::SeqCst);
                            if role != EngineRole::Decode {
                                feeders[tier].fetch_sub(1, Ordering::SeqCst);
                            }
                            let _ = tx.send(RouterMsg::WorkerDead {
                                tier,
                                err: e.to_string(),
                            });
                            return;
                        }
                    };
                    // Continuous mode hands the worker's inner loop to
                    // the paged iteration engine.
                    if let Some(engines) = engine_mode {
                        continuous_worker_loop(
                            tier,
                            backend,
                            engines[tier],
                            role,
                            hubs_ref[tier].as_ref(),
                            &pool_live_ref[tier],
                            &spec_live_ref[tier],
                            &engine_ctr_ref[tier],
                            tier_state,
                            &alive[tier],
                            &feeders[tier],
                            &target[tier],
                            tx,
                            max_new,
                            t0,
                            tracer,
                        );
                        return;
                    }
                    loop {
                        // Retire at batch boundaries if the pool shrank
                        // (a worker never abandons admitted work).
                        if try_retire(&alive[tier], &target[tier]) {
                            return;
                        }
                        // Wait for work or shutdown. Each worker
                        // admits only its share of the tier's batch
                        // budget, so the queue drains across the whole
                        // pool instead of serializing behind one
                        // replica — pool size is the capacity lever
                        // hot-swaps pull.
                        let batch = {
                            let mut b = tier_state.batcher.plock();
                            loop {
                                // Share by the *live* worker count: after
                                // replica deaths the survivors must cover
                                // the whole batch budget, not a 1/target
                                // sliver of it.
                                let pool = alive[tier].load(Ordering::SeqCst).max(1);
                                let share = (b.max_batch / pool).max(1);
                                let admitted =
                                    b.admit_up_to(share, t0.elapsed().as_secs_f64());
                                if !admitted.is_empty() {
                                    break admitted;
                                }
                                if tier_state.closed.load(Ordering::SeqCst) {
                                    return;
                                }
                                if try_retire(&alive[tier], &target[tier]) {
                                    return;
                                }
                                b = tier_state.wake.pwait(b);
                            }
                        };
                        if let Some(tr) = &tracer {
                            for p in &batch {
                                tr.emit(p.item.id as u64, ObsEventKind::QueueExit, 0, 0, 0);
                            }
                        }
                        let n = batch.len();
                        let mut iter = batch.into_iter();
                        while let Some(pending) = iter.next() {
                            let started = Instant::now();
                            let mn = pending
                                .item
                                .max_new
                                .unwrap_or_else(|| max_new.load(Ordering::SeqCst))
                                .max(1);
                            let result =
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                    backend.generate(&pending.item.prompt, mn)
                                }))
                                .unwrap_or_else(|p| {
                                    Err(anyhow::anyhow!(
                                        "backend panicked: {}",
                                        panic_msg(&*p)
                                    ))
                                });
                            match result {
                                Ok(output) => {
                                    let _ = tx.send(RouterMsg::Done {
                                        tier,
                                        req: pending.item,
                                        output,
                                        exec_seconds: started.elapsed().as_secs_f64(),
                                        // Lockstep does not stream; the
                                        // first token lands with the rest.
                                        first_token_at: Some(Instant::now()),
                                    });
                                }
                                Err(e) => {
                                    // Replica death: hand every
                                    // admitted-but-unserved request
                                    // back to the router, release
                                    // batch capacity, and exit.
                                    let _ = tx.send(RouterMsg::Failed {
                                        tier,
                                        req: pending.item,
                                    });
                                    for rest in iter.by_ref() {
                                        let _ = tx.send(RouterMsg::Failed {
                                            tier,
                                            req: rest.item,
                                        });
                                    }
                                    alive[tier].fetch_sub(1, Ordering::SeqCst);
                                    let _ = tx.send(RouterMsg::WorkerDead {
                                        tier,
                                        err: e.to_string(),
                                    });
                                    tier_state.batcher.plock().complete(n);
                                    tier_state.wake.notify_all();
                                    return;
                                }
                            }
                        }
                        tier_state.batcher.plock().complete(n);
                        tier_state.wake.notify_all();
                    }
                });
            };
            for tier in 0..c {
                match (engine_mode.is_some(), self.config.disagg_for(tier)) {
                    (true, Some(d)) => {
                        for _ in 0..d.prefill_replicas {
                            spawn_worker(tier, EngineRole::Prefill);
                        }
                        for _ in 0..d.decode_replicas {
                            spawn_worker(tier, EngineRole::Decode);
                        }
                    }
                    _ => {
                        for _replica in 0..self.config.replicas[tier].max(1) {
                            spawn_worker(tier, EngineRole::Unified);
                        }
                    }
                }
            }

            // --- Submitter (paced by arrival offsets); the policy may
            // route a request past the small tiers before any model
            // runs (length-predictive entry). ---
            let submit_tiers = &tiers;
            let policy_ref = &policy;
            let telem_sub = telem_ref;
            let clock_sub = clock_ref;
            let hash_prompts =
                engine_mode.is_some_and(|v| v.iter().any(|e| e.share_prefixes));
            scope.spawn(move || {
                for (i, entry) in trace.iter().enumerate() {
                    let due = Duration::from_secs_f64(entry.at);
                    let elapsed = t0.elapsed();
                    if due > elapsed {
                        std::thread::sleep(due - elapsed);
                    }
                    // The adaptation tap sees the request before entry
                    // routing; a swap queued here is picked up by the
                    // router within a few messages.
                    if let Some(obs) = observer {
                        obs.on_admit(i);
                    }
                    let features = RequestFeatures::live(entry.prompt.len());
                    let entry_tier =
                        policy_ref.pread().entry_tier(&features, c).min(c - 1);
                    if let Some(tm) = telem_sub {
                        let t = clock_sub.now();
                        tm.recorder.emit(
                            entry_tier,
                            ObsEvent {
                                a: entry_tier as u64,
                                ..ObsEvent::at(
                                    t,
                                    i as u64,
                                    entry_tier as u32,
                                    ObsEventKind::Admitted,
                                )
                            },
                        );
                        tm.recorder.emit(
                            entry_tier,
                            ObsEvent::at(
                                t,
                                i as u64,
                                entry_tier as u32,
                                ObsEventKind::QueueEnter,
                            ),
                        );
                        tm.registry.inc(&format!(
                            "cascadia_requests_admitted_total{{tier=\"{entry_tier}\"}}"
                        ));
                    }
                    // Hash the prompt ONCE; every tier (and every
                    // escalation) reuses the chain.
                    let hashes = hash_prompts.then(|| {
                        Arc::new(prompt_page_hashes(&entry.prompt, DEFAULT_PAGE_TOKENS))
                    });
                    submit_tiers[entry_tier].push(
                        LiveRequest {
                            id: i,
                            prompt: entry.prompt.clone(),
                            submitted: Instant::now(),
                            max_new: entry.max_new,
                            hashes,
                        },
                        t0,
                    );
                }
            });

            // --- Router / coordinator ---
            let mut completions: Vec<Completion> = Vec::with_capacity(trace.len());
            let mut per_tier = vec![0usize; c];
            let mut done = 0usize;
            let mut worker_errors: Vec<String> = Vec::new();
            while done < trace.len() {
                // Apply a queued hot-swap between routing steps: swap
                // the policy atomically, rescale admission, resize the
                // worker pools. In-flight requests are untouched — they
                // finish under whichever policy is current when their
                // tier's response is scored.
                if let Some(ctrl) = control {
                    if let Some(next) = ctrl.take_pending() {
                        *policy.pwrite() = next.policy.clone();
                        max_new_live.store(next.max_new_tokens, Ordering::SeqCst);
                        for (t, &mb) in next.max_batch.iter().enumerate() {
                            tiers[t].batcher.plock().max_batch = mb.max(1);
                            tiers[t].wake.notify_all();
                        }
                        // Rescale the continuous KV pools: workers pick
                        // the new size up at their next iteration
                        // boundary (scale-down takes effect as
                        // sequences retire — nothing in flight is
                        // dropped). The exec *mode* never changes
                        // mid-run; a lockstep config swapped onto a
                        // continuous server leaves the pools as they
                        // are.
                        if engine_mode.is_some() {
                            if let ExecMode::Continuous(next_engines) = &next.exec {
                                for (t, e) in next_engines.iter().enumerate().take(c) {
                                    pool_pages_live[t]
                                        .store(e.pool_pages.max(1), Ordering::SeqCst);
                                }
                            }
                            // Retune (or disable) draft depths: workers
                            // pick the new value up at their next
                            // iteration boundary. A config without the
                            // dimension turns speculation off — drafts
                            // never span an iteration, so nothing is
                            // stranded. A tier whose launch config had
                            // no speculation stays plain (its workers
                            // were never paired with a draft backend).
                            for t in 0..c {
                                let k = next
                                    .speculation
                                    .get(t)
                                    .copied()
                                    .flatten()
                                    .map(|s| s.draft_k)
                                    .unwrap_or(0);
                                spec_k_live[t].store(k, Ordering::SeqCst);
                            }
                        }
                        for t in 0..c {
                            // A disaggregated tier's role split is
                            // fixed for the run: resizing its pool
                            // mid-flight would unbalance the
                            // prefill/decode roles (and orphan hub
                            // slots), so hot-swaps leave its worker
                            // counts alone.
                            if hubs[t].is_some() {
                                tiers[t].wake.notify_all();
                                continue;
                            }
                            let want = next.replicas[t].max(1);
                            target[t].store(want, Ordering::SeqCst);
                            while alive[t].load(Ordering::SeqCst) < want {
                                spawn_worker(t, EngineRole::Unified);
                            }
                            // Surplus workers wake up and retire.
                            tiers[t].wake.notify_all();
                        }
                        let ordinal = ctrl.hot_swaps.fetch_add(1, Ordering::SeqCst) + 1;
                        if let Some(tm) = &telem {
                            tm.recorder.emit(
                                0,
                                ObsEvent {
                                    a: ordinal as u64,
                                    ..ObsEvent::at(
                                        clock.now(),
                                        REQ_NONE,
                                        0,
                                        ObsEventKind::HotSwapApplied,
                                    )
                                },
                            );
                            tm.registry.inc("cascadia_hot_swaps_total");
                        }
                    }
                }
                // Adaptive runs poll with a short timeout so a queued
                // swap is applied even while the channel is idle; plain
                // serves block (no mailbox can ever fill). Either way
                // the channel cannot disconnect mid-run — the spawning
                // handle outlives the loop — so worker loss is handled
                // via WorkerDead accounting, not sender counting.
                let msg = if control.is_some() {
                    match rx.recv_timeout(Duration::from_millis(2)) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Timeout) => continue,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                } else {
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => break,
                    }
                };
                match msg {
                    RouterMsg::WorkerDead { tier, err } => {
                        // A replica died: record and keep serving with the
                        // remaining replicas of that tier (failure
                        // injection tests exercise this path).
                        worker_errors.push(format!("tier {tier}: {err}"));
                        // A disagg tier whose last prefill worker died
                        // can never admit queued work again, even with
                        // decoders alive — that's as dead as an empty
                        // tier.
                        let starved = hubs[tier].is_some()
                            && feeders[tier].load(Ordering::SeqCst) == 0;
                        if alive[tier].load(Ordering::SeqCst) == 0 || starved {
                            // Unblock every surviving worker before
                            // returning, or thread::scope never joins.
                            for t in &tiers {
                                t.close();
                            }
                            for h in hubs.iter().flatten() {
                                h.close();
                            }
                            if starved {
                                anyhow::bail!(
                                    "all prefill replicas of disaggregated tier {tier} \
                                     died: {worker_errors:?}"
                                );
                            }
                            anyhow::bail!(
                                "all replicas of tier {tier} died: {worker_errors:?}"
                            );
                        }
                        continue;
                    }
                    RouterMsg::Failed { tier, req } => {
                        // Re-route to the same tier; a surviving replica
                        // will serve it.
                        tiers[tier].push(req, t0);
                        continue;
                    }
                    RouterMsg::Done { tier, req, output, exec_seconds, first_token_at } => {
                        per_tier[tier] += 1;
                        if let Some(at) = first_token_at {
                            let ttft = at
                                .checked_duration_since(req.submitted)
                                .unwrap_or_default();
                            first_tokens.plock().entry(req.id).or_insert(ttft);
                        }
                        let score = judger.score(&req.prompt, &output);
                        let features = RequestFeatures::live(req.prompt.len());
                        let decision = if tier == c - 1 {
                            Decision::Accept
                        } else {
                            policy.pread().decide(tier, score, &features, c)
                        };
                        // A skip must move strictly forward; clamp a
                        // misbehaving target rather than wedging the
                        // request mid-flight.
                        let next_tier = match decision {
                            Decision::Accept => None,
                            Decision::Escalate => Some(tier + 1),
                            Decision::SkipTo(t) => Some(t.clamp(tier + 1, c - 1)),
                        };
                        if let Some(tm) = &telem {
                            let action = match decision {
                                Decision::Accept => ACTION_ACCEPT,
                                Decision::Escalate => ACTION_ESCALATE,
                                Decision::SkipTo(_) => ACTION_SKIP,
                            };
                            tm.recorder.emit(
                                tier,
                                ObsEvent {
                                    a: action,
                                    b: next_tier.unwrap_or(tier) as u64,
                                    ..ObsEvent::at(
                                        clock.now(),
                                        req.id as u64,
                                        tier as u32,
                                        ObsEventKind::RouteDecision,
                                    )
                                },
                            );
                        }
                        if next_tier.is_none() {
                            let e2e = req.submitted.elapsed();
                            let execd = {
                                let mut qt = queue_time.plock();
                                qt.remove(&req.id).unwrap_or(0.0) + exec_seconds
                            };
                            let ttft =
                                first_tokens.plock().remove(&req.id).unwrap_or(e2e);
                            if let Some(tm) = &telem {
                                // The router is the terminal authority:
                                // exactly one `finished` per request.
                                tm.recorder.emit(
                                    tier,
                                    ObsEvent {
                                        fa: ttft.as_secs_f64(),
                                        fb: e2e.as_secs_f64(),
                                        ..ObsEvent::at(
                                            clock.now(),
                                            req.id as u64,
                                            tier as u32,
                                            ObsEventKind::Finished,
                                        )
                                    },
                                );
                                tm.registry.observe(
                                    &format!("cascadia_ttft_seconds{{tier=\"{tier}\"}}"),
                                    LATENCY_BUCKETS,
                                    ttft.as_secs_f64(),
                                );
                                tm.registry.observe(
                                    &format!(
                                        "cascadia_e2e_latency_seconds{{tier=\"{tier}\"}}"
                                    ),
                                    LATENCY_BUCKETS,
                                    e2e.as_secs_f64(),
                                );
                                tm.registry.inc(&format!(
                                    "cascadia_requests_completed_total{{tier=\"{tier}\"}}"
                                ));
                            }
                            // Completion tap: the SLO burn-rate
                            // trigger's feed (admission already went
                            // through `on_admit` in the submitter).
                            if let Some(obs) = observer {
                                obs.on_complete(tier, e2e.as_secs_f64());
                            }
                            completions.push(Completion {
                                id: req.id,
                                output,
                                score,
                                accepting_tier: tier,
                                e2e_latency: e2e,
                                queue_latency: Duration::from_secs_f64(
                                    (e2e.as_secs_f64() - execd).max(0.0),
                                ),
                                ttft,
                            });
                            done += 1;
                        } else {
                            let next = next_tier.unwrap_or(c - 1);
                            if let Some(tm) = &telem {
                                let t = clock.now();
                                tm.recorder.emit(
                                    tier,
                                    ObsEvent {
                                        a: tier as u64,
                                        b: next as u64,
                                        ..ObsEvent::at(
                                            t,
                                            req.id as u64,
                                            tier as u32,
                                            ObsEventKind::Escalate,
                                        )
                                    },
                                );
                                tm.recorder.emit(
                                    next,
                                    ObsEvent::at(
                                        t,
                                        req.id as u64,
                                        next as u32,
                                        ObsEventKind::QueueEnter,
                                    ),
                                );
                                tm.registry.inc(&format!(
                                    "cascadia_escalations_total{{from=\"{tier}\",to=\"{next}\"}}"
                                ));
                            }
                            // One guard for the whole accumulation —
                            // re-locking `queue_time` per clause is the
                            // lock churn the `lock-order` lint flags.
                            // Scoped so the guard is dropped before
                            // `push` takes the tier's `batcher` lock:
                            // `batcher` is an outer tier relative to
                            // `queue_time` in the declared hierarchy,
                            // so it must never be taken under `qt`.
                            {
                                let mut qt = queue_time.plock();
                                *qt.entry(req.id).or_insert(0.0) += exec_seconds;
                            }
                            tiers[next].push(req, t0);
                        }
                    }
                }
            }
            for t in &tiers {
                t.close();
            }
            for h in hubs.iter().flatten() {
                h.close();
            }
            if done < trace.len() {
                anyhow::bail!(
                    "served {done}/{} requests; worker errors: {:?}",
                    trace.len(),
                    worker_errors
                );
            }
            let queue: Vec<TierQueueStats> = tiers
                .iter()
                .map(|t| {
                    let b = t.batcher.plock();
                    TierQueueStats {
                        peak_depth: b.peak_depth,
                        admitted: b.admitted(),
                        mean_wait_s: b.mean_wait(),
                    }
                })
                .collect();
            let engine: Vec<TierEngineStats> = (0..c)
                .map(|t| TierEngineStats {
                    pool_pages: pool_pages_live[t].load(Ordering::SeqCst),
                    peak_pool_pages: engine_counters[t]
                        .peak_pool_pages
                        .load(Ordering::SeqCst)
                        .max(pool_pages_live[t].load(Ordering::SeqCst)),
                    peak_pages: engine_counters[t].peak_pages.load(Ordering::SeqCst),
                    preemptions: engine_counters[t].preemptions.load(Ordering::SeqCst),
                    iterations: engine_counters[t].iterations.load(Ordering::SeqCst),
                    forced_expansions: engine_counters[t]
                        .forced_expansions
                        .load(Ordering::SeqCst),
                    prefix_hit_tokens: engine_counters[t]
                        .prefix_hit_tokens
                        .load(Ordering::SeqCst),
                    shared_claims: engine_counters[t].shared_claims.load(Ordering::SeqCst),
                    cow_copies: engine_counters[t].cow_copies.load(Ordering::SeqCst),
                    swap_outs: engine_counters[t].swap_outs.load(Ordering::SeqCst),
                    swap_ins: engine_counters[t].swap_ins.load(Ordering::SeqCst),
                    swap_bytes: engine_counters[t].swap_bytes.load(Ordering::SeqCst),
                    migrations: engine_counters[t].migrations.load(Ordering::SeqCst),
                    migrate_pages: engine_counters[t].migrate_pages.load(Ordering::SeqCst),
                    spec_accepted_tokens: engine_counters[t]
                        .spec_accepted_tokens
                        .load(Ordering::SeqCst),
                    spec_rejected_tokens: engine_counters[t]
                        .spec_rejected_tokens
                        .load(Ordering::SeqCst),
                })
                .collect();
            if let Some(tm) = &telem {
                crate::obs::export_recorder_health(&tm.recorder, &tm.registry);
            }
            Ok(ServerStats {
                completions,
                wall_clock: t0.elapsed(),
                per_tier_processed: per_tier,
                queue,
                engine,
            })
        })?;

        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{PreemptionConfig, PreemptionMode};

    /// Simulated backend: deterministic "generation" with configurable
    /// per-tier delay; output quality encoded in first token.
    struct FakeBackend {
        tier: usize,
        delay: Duration,
    }

    impl TierBackend for FakeBackend {
        fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
            std::thread::sleep(self.delay);
            // Tier t "answers correctly" iff prompt difficulty <= t.
            let difficulty = prompt.first().copied().unwrap_or(0);
            let ok = difficulty <= self.tier as i32;
            Ok(vec![if ok { 1 } else { 0 }; max_new.min(4)])
        }
    }

    struct FakeJudger;

    impl ResponseJudger for FakeJudger {
        fn score(&self, _prompt: &[i32], output: &[i32]) -> f64 {
            if output.first() == Some(&1) {
                90.0
            } else {
                10.0
            }
        }
    }

    fn config() -> ServerConfig {
        ServerConfig::with_thresholds(vec![2, 1], vec![4, 2], vec![50.0], 4).unwrap()
    }

    fn factory(tier: usize) -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(FakeBackend { tier, delay: Duration::from_millis(2) }))
    }

    #[test]
    fn serves_all_and_routes_by_difficulty() {
        let server = CascadeServer::new(config()).unwrap();
        // Difficulty 0 -> accepted at tier 0; difficulty 1 -> escalated.
        let trace: Vec<(f64, Vec<i32>)> =
            (0..20).map(|i| (0.0, vec![(i % 2) as i32, 7, 8])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 20);
        assert_eq!(stats.per_tier_processed[0], 20);
        assert_eq!(stats.per_tier_processed[1], 10);
        for c in &stats.completions {
            let expect_tier = (trace[c.id].1[0]) as usize;
            assert_eq!(c.accepting_tier, expect_tier, "req {}", c.id);
            assert!(c.score >= 50.0 || c.accepting_tier == 1);
        }
        assert!(stats.throughput_rps() > 10.0);
    }

    #[test]
    fn escalated_requests_have_higher_latency() {
        let server = CascadeServer::new(config()).unwrap();
        let trace: Vec<(f64, Vec<i32>)> =
            (0..30).map(|i| (0.0, vec![(i % 2) as i32])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        let mean_of = |tier: usize| {
            let v: Vec<f64> = stats
                .completions
                .iter()
                .filter(|c| c.accepting_tier == tier)
                .map(|c| c.e2e_latency.as_secs_f64())
                .collect();
            stats_mean(&v)
        };
        assert!(mean_of(1) > mean_of(0));
    }

    fn stats_mean(v: &[f64]) -> f64 {
        crate::util::stats::mean(v)
    }

    #[test]
    fn replica_death_degrades_but_survives() {
        // Tier 0 has 2 replicas; one dies on first request. The other
        // must still finish everything.
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SPAWNED: AtomicUsize = AtomicUsize::new(0);

        struct DyingBackend {
            dies: bool,
            inner: FakeBackend,
        }
        impl TierBackend for DyingBackend {
            fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
                if self.dies {
                    anyhow::bail!("simulated replica crash");
                }
                self.inner.generate(prompt, max_new)
            }
        }

        let factory = |tier: usize| -> Result<Box<dyn TierBackend>> {
            let idx = SPAWNED.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(DyingBackend {
                // Exactly one tier-0 replica dies.
                dies: tier == 0 && idx == 0,
                inner: FakeBackend { tier, delay: Duration::from_millis(1) },
            }))
        };

        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![2, 1], vec![2, 2], vec![50.0], 2).unwrap(),
        )
        .unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..10).map(|_| (0.0, vec![0])).collect();
        // The dying replica hands its admitted requests back to the
        // router, which re-routes them to the surviving replica — every
        // request must complete.
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 10);
    }

    #[test]
    fn all_replicas_dead_fails_loudly() {
        struct AlwaysDies;
        impl TierBackend for AlwaysDies {
            fn generate(&mut self, _p: &[i32], _m: usize) -> Result<Vec<i32>> {
                anyhow::bail!("boom")
            }
        }
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![1, 1], vec![2, 2], vec![50.0], 2).unwrap(),
        )
        .unwrap();
        let factory = |_t: usize| -> Result<Box<dyn TierBackend>> { Ok(Box::new(AlwaysDies)) };
        let trace: Vec<(f64, Vec<i32>)> = (0..4).map(|_| (0.0, vec![0])).collect();
        let err = server.serve(&trace, &factory, &FakeJudger).unwrap_err();
        assert!(err.to_string().contains("all replicas"), "{err}");
    }

    #[test]
    fn panicking_backend_fails_loudly_instead_of_hanging() {
        // A panic (not an Err) in the backend must be contained and
        // fed through the replica-death accounting — unwinding past it
        // would leave the router waiting forever.
        struct PanickingBackend;
        impl TierBackend for PanickingBackend {
            fn generate(&mut self, _p: &[i32], _m: usize) -> Result<Vec<i32>> {
                panic!("kaboom");
            }
        }
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![1, 1], vec![2, 2], vec![50.0], 2).unwrap(),
        )
        .unwrap();
        let factory = |t: usize| -> Result<Box<dyn TierBackend>> {
            if t == 0 {
                Ok(Box::new(PanickingBackend))
            } else {
                Ok(Box::new(FakeBackend { tier: t, delay: Duration::from_millis(1) }))
            }
        };
        let trace: Vec<(f64, Vec<i32>)> = (0..4).map(|_| (0.0, vec![0])).collect();
        let err = server.serve(&trace, &factory, &FakeJudger).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
    }

    #[test]
    fn queue_latency_reported() {
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![1, 1], vec![1, 1], vec![50.0], 2).unwrap(),
        )
        .unwrap();
        // Burst of easy requests through a single slow replica: most of
        // their latency must be queueing.
        let slow_factory = |tier: usize| -> Result<Box<dyn TierBackend>> {
            Ok(Box::new(FakeBackend { tier, delay: Duration::from_millis(10) }))
        };
        let trace: Vec<(f64, Vec<i32>)> = (0..6).map(|_| (0.0, vec![0])).collect();
        let stats = server.serve(&trace, &slow_factory, &FakeJudger).unwrap();
        let max_queue = stats
            .completions
            .iter()
            .map(|c| c.queue_latency.as_secs_f64())
            .fold(0.0, f64::max);
        assert!(max_queue > 0.02, "queueing should dominate: {max_queue}");
    }

    #[test]
    fn length_policy_enters_at_predicted_tier_live() {
        // Prompts with >= 5 tokens are predicted hard and enter at tier
        // 1; everything is easy (difficulty 0) so requests accept at
        // their entry tier.
        let server = CascadeServer::new(ServerConfig {
            replicas: vec![1, 1],
            max_batch: vec![4, 4],
            policy: PolicySpec::length(vec![0.0], 5.0, 1).unwrap(),
            max_new_tokens: 4,
            exec: ExecMode::BatchLockstep,
            disagg: Vec::new(),
            speculation: Vec::new(),
        })
        .unwrap();
        let mut trace: Vec<(f64, Vec<i32>)> = Vec::new();
        for _ in 0..6 {
            trace.push((0.0, vec![0, 1])); // short -> tier 0
        }
        for _ in 0..4 {
            trace.push((0.0, vec![0, 1, 2, 3, 4, 5])); // long -> tier 1
        }
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 10);
        assert_eq!(stats.per_tier_processed, vec![6, 4]);
        for c in &stats.completions {
            let expect = if trace[c.id].1.len() >= 5 { 1 } else { 0 };
            assert_eq!(c.accepting_tier, expect, "req {}", c.id);
        }
    }

    #[test]
    fn margin_policy_skips_middle_tier_live() {
        // Difficulty-2 prompts fail tiers 0 and 1 (score 10); with a
        // tight margin the deep failure at tier 0 skips tier 1 and goes
        // straight to tier 2.
        let server = CascadeServer::new(ServerConfig {
            replicas: vec![1, 1, 1],
            max_batch: vec![2, 2, 2],
            policy: PolicySpec::margin(vec![80.0, 80.0], 5.0).unwrap(),
            max_new_tokens: 4,
            exec: ExecMode::BatchLockstep,
            disagg: Vec::new(),
            speculation: Vec::new(),
        })
        .unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..8).map(|_| (0.0, vec![2, 9])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 8);
        assert_eq!(stats.per_tier_processed[0], 8);
        assert_eq!(stats.per_tier_processed[1], 0, "middle tier should be skipped");
        assert_eq!(stats.per_tier_processed[2], 8);
        assert!(stats.completions.iter().all(|c| c.accepting_tier == 2));
    }

    #[test]
    fn from_plan_derives_replicas_and_policy() {
        use crate::parallel::Strategy;
        use crate::perf::Workload;
        use crate::sched::plan::TierPlan;

        let plan = CascadePlan {
            policy: PolicySpec::threshold(vec![50.0]).unwrap(),
            tiers: vec![
                TierPlan {
                    model_name: "small".into(),
                    gpus: 4,
                    strategy: Some(Strategy::uniform(2, 1, 2)),
                    workload: Workload { rate: 4.0, avg_input: 300.0, avg_output: 100.0 },
                    processing_ratio: 1.0,
                    predicted_p95: 1.0,
                    disagg: None,
                    speculation: None,
                },
                TierPlan {
                    model_name: "large".into(),
                    gpus: 0,
                    strategy: None,
                    workload: Workload { rate: 0.0, avg_input: 0.0, avg_output: 0.0 },
                    processing_ratio: 0.0,
                    predicted_p95: 0.0,
                    disagg: None,
                    speculation: None,
                },
            ],
            predicted_latency: 1.0,
            predicted_quality: 80.0,
            preemption: vec![PreemptionMode::Recompute; 2],
        };
        let cfg = ServerConfig::from_plan(&plan, 6).unwrap();
        assert_eq!(cfg.replicas, vec![2, 1]); // undeployed tier keeps 1 worker
        assert_eq!(cfg.policy.thresholds(), &[50.0]);
        assert_eq!(cfg.max_new_tokens, 6);
        assert_eq!(cfg.replicas.len(), cfg.max_batch.len());
        // The derived config constructs a valid server.
        CascadeServer::new(cfg).unwrap();
    }

    /// Observer that queues a hot-swap exactly when trace entry `at` is
    /// admitted — a deterministic trigger point for the swap tests.
    struct SwapAt {
        control: Arc<ServeControl>,
        at: usize,
        next: ServerConfig,
        fired: AtomicBool,
    }

    impl AdmissionObserver for SwapAt {
        fn on_admit(&self, i: usize) {
            if i == self.at && !self.fired.swap(true, Ordering::SeqCst) {
                self.control.apply_config(self.next.clone()).unwrap();
            }
        }
    }

    #[test]
    fn hot_swap_loses_no_requests_and_scales_up() {
        // Start at 1 replica/tier with singleton batches; swap to a
        // bigger pool and an accept-everything policy mid-run. Every
        // request must complete exactly once across the swap.
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![1, 1], vec![1, 1], vec![50.0], 4).unwrap(),
        )
        .unwrap();
        let control = ServeControl::new(2);
        let next =
            ServerConfig::with_thresholds(vec![3, 2], vec![4, 4], vec![0.0], 4).unwrap();
        let swap = SwapAt {
            control: Arc::clone(&control),
            at: 10,
            next,
            fired: AtomicBool::new(false),
        };
        let trace: Vec<(f64, Vec<i32>)> =
            (0..40).map(|i| (0.0, vec![(i % 2) as i32, 5])).collect();
        let stats = server
            .serve_adaptive(&trace, &factory, &FakeJudger, &control, Some(&swap))
            .unwrap();
        assert_eq!(stats.completions.len(), 40, "every request must survive the swap");
        assert_eq!(control.hot_swaps(), 1);
        let mut ids: Vec<usize> = stats.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..40).collect::<Vec<_>>(), "no drops, no duplicates");
    }

    #[test]
    fn hot_swap_scales_down_without_deadlock() {
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![3, 2], vec![4, 4], vec![50.0], 4).unwrap(),
        )
        .unwrap();
        let control = ServeControl::new(2);
        let next =
            ServerConfig::with_thresholds(vec![1, 1], vec![1, 1], vec![50.0], 4).unwrap();
        let swap = SwapAt {
            control: Arc::clone(&control),
            at: 8,
            next,
            fired: AtomicBool::new(false),
        };
        let trace: Vec<(f64, Vec<i32>)> =
            (0..30).map(|i| (0.0, vec![(i % 2) as i32])).collect();
        let stats = server
            .serve_adaptive(&trace, &factory, &FakeJudger, &control, Some(&swap))
            .unwrap();
        assert_eq!(stats.completions.len(), 30);
        assert_eq!(control.hot_swaps(), 1);
    }

    #[test]
    fn control_rejects_mismatched_tier_count() {
        let control = ServeControl::new(3);
        let two_tier =
            ServerConfig::with_thresholds(vec![1, 1], vec![1, 1], vec![50.0], 2).unwrap();
        assert!(control.apply_config(two_tier.clone()).is_err());
        // And serve_adaptive refuses a control sized for another cascade.
        let server = CascadeServer::new(two_tier).unwrap();
        assert!(server
            .serve_adaptive(&[], &factory, &FakeJudger, &control, None)
            .is_err());
    }

    #[test]
    fn control_for_plan_rejects_different_cascade() {
        use crate::parallel::Strategy;
        use crate::perf::Workload;
        use crate::sched::plan::TierPlan;

        let plan_with = |names: [&str; 2]| CascadePlan {
            policy: PolicySpec::threshold(vec![50.0]).unwrap(),
            tiers: names
                .iter()
                .map(|n| TierPlan {
                    model_name: n.to_string(),
                    gpus: 2,
                    strategy: Some(Strategy::uniform(1, 1, 2)),
                    workload: Workload { rate: 2.0, avg_input: 100.0, avg_output: 50.0 },
                    processing_ratio: 0.5,
                    predicted_p95: 1.0,
                    disagg: None,
                    speculation: None,
                })
                .collect(),
            predicted_latency: 1.0,
            predicted_quality: 80.0,
            preemption: vec![PreemptionMode::Recompute; 2],
        };
        let launched = plan_with(["small", "large"]);
        let control = ServeControl::for_plan(&launched);
        // Same cascade, retuned: accepted.
        let mut retuned = plan_with(["small", "large"]);
        retuned.policy = PolicySpec::threshold(vec![70.0]).unwrap();
        control.apply_plan(&retuned, 4).unwrap();
        // Same tier count, different models: rejected — the weights on
        // the GPUs don't change on a hot-swap.
        let other = plan_with(["small", "other-large"]);
        let err = control.apply_plan(&other, 4).unwrap_err();
        assert!(err.to_string().contains("not hot-swappable"), "{err}");
        // A tier-count-only control would have accepted it.
        assert!(ServeControl::new(2).apply_plan(&other, 4).is_ok());
    }

    #[test]
    fn latency_summary_covers_percentiles() {
        let server = CascadeServer::new(config()).unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..20).map(|_| (0.0, vec![0])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        let s = stats.latency_summary();
        assert!(s.p50 > 0.0 && s.p50 <= s.p95 && s.p95 <= s.p99);
        assert!((s.p95 - stats.p95_latency()).abs() < 1e-9);
        assert!((s.mean - stats.mean_latency()).abs() < 1e-9);
    }

    #[test]
    fn mismatched_policy_arity_rejected_at_construction() {
        let err = CascadeServer::new(ServerConfig {
            replicas: vec![1, 1, 1],
            max_batch: vec![2, 2, 2],
            policy: PolicySpec::threshold(vec![50.0]).unwrap(),
            max_new_tokens: 2,
            exec: ExecMode::BatchLockstep,
            disagg: Vec::new(),
            speculation: Vec::new(),
        });
        assert!(err.is_err());
    }

    // ---- Continuous-batching engine on the live path ----

    fn engine_cfgs(n: usize) -> Vec<EngineConfig> {
        vec![
            EngineConfig {
                pool_pages: 256,
                page_tokens: 16,
                max_running: 8,
                prefill_chunk: usize::MAX,
                share_prefixes: true,
                preemption: PreemptionConfig::default(),
            };
            n
        ]
    }

    fn continuous_config() -> ServerConfig {
        config().continuous(engine_cfgs(2))
    }

    #[test]
    fn continuous_mode_serves_all_and_routes_identically() {
        let server = CascadeServer::new(continuous_config()).unwrap();
        let trace: Vec<(f64, Vec<i32>)> =
            (0..20).map(|i| (0.0, vec![(i % 2) as i32, 7, 8])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 20);
        assert_eq!(stats.per_tier_processed[0], 20);
        assert_eq!(stats.per_tier_processed[1], 10);
        for c in &stats.completions {
            let expect_tier = (trace[c.id].1[0]) as usize;
            assert_eq!(c.accepting_tier, expect_tier, "req {}", c.id);
        }
        // Engine telemetry is live: iterations ran, pages were used,
        // and occupancy stayed within the pool budget.
        for (t, e) in stats.engine.iter().enumerate() {
            assert!(e.iterations > 0, "tier {t} must iterate");
            assert!(e.peak_pages > 0, "tier {t} must allocate pages");
            assert!(e.peak_pages <= e.peak_pool_pages, "tier {t} exceeded its pool");
            assert_eq!(e.forced_expansions, 0);
        }
    }

    #[test]
    fn lockstep_engine_stats_are_zero_but_queue_stats_report() {
        let server = CascadeServer::new(config()).unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..12).map(|_| (0.0, vec![0])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.engine.len(), 2);
        assert!(stats.engine.iter().all(|e| *e == TierEngineStats::default()));
        assert_eq!(stats.queue.len(), 2);
        assert_eq!(stats.queue[0].admitted, 12, "tier 0 admits every request");
        assert!(stats.queue[0].peak_depth > 0);
        assert!(stats.queue[0].mean_wait_s >= 0.0);
    }

    #[test]
    fn continuous_mode_contains_backend_failures() {
        use std::sync::atomic::AtomicUsize;
        static SPAWNED_C: AtomicUsize = AtomicUsize::new(0);

        struct DyingBackend {
            dies: bool,
            inner: FakeBackend,
        }
        impl TierBackend for DyingBackend {
            fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
                if self.dies {
                    anyhow::bail!("simulated replica crash");
                }
                self.inner.generate(prompt, max_new)
            }
        }

        let factory = |tier: usize| -> Result<Box<dyn TierBackend>> {
            let idx = SPAWNED_C.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(DyingBackend {
                dies: tier == 0 && idx == 0,
                inner: FakeBackend { tier, delay: Duration::from_millis(1) },
            }))
        };
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![2, 1], vec![2, 2], vec![50.0], 2)
                .unwrap()
                .continuous(engine_cfgs(2)),
        )
        .unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..10).map(|_| (0.0, vec![0])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 10, "failed work re-routes, exactly once");
        let mut ids: Vec<usize> = stats.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn continuous_hot_swap_scales_down_at_iteration_boundaries() {
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![3, 2], vec![4, 4], vec![50.0], 4)
                .unwrap()
                .continuous(engine_cfgs(2)),
        )
        .unwrap();
        let control = ServeControl::new(2);
        // Scale down workers AND halve the pools.
        let next = ServerConfig::with_thresholds(vec![1, 1], vec![1, 1], vec![50.0], 4)
            .unwrap()
            .continuous(vec![
                EngineConfig {
                    pool_pages: 128,
                    page_tokens: 16,
                    max_running: 8,
                    prefill_chunk: usize::MAX,
                    share_prefixes: true,
                    preemption: PreemptionConfig::default(),
                };
                2
            ]);
        let swap = SwapAt {
            control: Arc::clone(&control),
            at: 8,
            next,
            fired: AtomicBool::new(false),
        };
        let trace: Vec<(f64, Vec<i32>)> =
            (0..30).map(|i| (0.0, vec![(i % 2) as i32])).collect();
        let stats = server
            .serve_adaptive(&trace, &factory, &FakeJudger, &control, Some(&swap))
            .unwrap();
        assert_eq!(stats.completions.len(), 30, "no drops across the swap");
        assert_eq!(control.hot_swaps(), 1);
        // The swapped pool size is what the run reports, while the
        // occupancy invariant is judged against the largest budget in
        // force during the run (the pre-swap 256).
        assert!(stats.engine.iter().all(|e| e.pool_pages == 128));
        assert!(stats.engine.iter().all(|e| e.peak_pool_pages == 256));
        assert!(stats.engine.iter().all(|e| e.peak_pages <= e.peak_pool_pages));
    }

    fn swap_engine_cfgs(n: usize, pool_pages: usize) -> Vec<EngineConfig> {
        vec![
            EngineConfig {
                pool_pages,
                page_tokens: 16,
                max_running: 8,
                prefill_chunk: usize::MAX,
                share_prefixes: false,
                preemption: PreemptionConfig {
                    mode: PreemptionMode::Swap,
                    swap_pages: 64,
                    prefill_s_per_token: 0.0,
                    swap_s_per_page: 0.0,
                    page_bytes: 1024.0,
                },
            };
            n
        ]
    }

    #[test]
    fn hot_swap_while_sequences_are_parked_orphans_nothing() {
        // Tight swap-enabled pools guarantee sequences are parked in
        // host swap space while serving; mid-run the plan hot-swap
        // shrinks the pools AND scales the workers down. Every
        // in-flight request must still complete exactly once — a
        // retiring worker may not abandon parked sequences, and the
        // pool resize must carry their resident prefixes.
        struct LongBackend;
        impl TierBackend for LongBackend {
            fn generate(&mut self, _p: &[i32], max_new: usize) -> Result<Vec<i32>> {
                Ok(vec![1; max_new])
            }
        }
        let long_factory =
            |_t: usize| -> Result<Box<dyn TierBackend>> { Ok(Box::new(LongBackend)) };
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![2, 1], vec![4, 4], vec![50.0], 24)
                .unwrap()
                .continuous(swap_engine_cfgs(2, 4)),
        )
        .unwrap();
        let control = ServeControl::new(2);
        // Shrink pools and drop to one worker per tier mid-run.
        let next = ServerConfig::with_thresholds(vec![1, 1], vec![2, 2], vec![50.0], 24)
            .unwrap()
            .continuous(swap_engine_cfgs(2, 3));
        let swap = SwapAt {
            control: Arc::clone(&control),
            at: 6,
            next,
            fired: AtomicBool::new(false),
        };
        let trace: Vec<(f64, Vec<i32>)> = (0..12).map(|_| (0.0, vec![1; 17])).collect();
        let stats = server
            .serve_adaptive(&trace, &long_factory, &FakeJudger, &control, Some(&swap))
            .unwrap();
        assert_eq!(stats.completions.len(), 12, "no parked sequence may be orphaned");
        let mut ids: Vec<usize> = stats.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<_>>(), "exactly-once across the swap");
        assert_eq!(control.hot_swaps(), 1);
        let e = &stats.engine[0];
        assert!(e.swap_outs > 0, "the tight pool must have parked sequences: {e:?}");
        assert_eq!(e.swap_outs, e.swap_ins, "every park resumed despite the hot-swap");
        assert!(e.swap_bytes > 0, "page_bytes telemetry must accumulate");
        assert_eq!(e.preemptions, 0, "ample host budget: no recompute fallback");
        assert!(e.peak_pages <= e.peak_pool_pages);
    }

    #[test]
    fn continuous_tight_pool_preempts_but_completes_everything() {
        // 4-page pools, 17-token prompts (2 pages at admission), 20
        // generated tokens: two co-running sequences collide when the
        // older one grows its 3rd page (ctx 33), so the engine must
        // preempt-and-requeue — and still complete every request
        // exactly once within the page budget.
        struct LongBackend;
        impl TierBackend for LongBackend {
            fn generate(&mut self, _p: &[i32], max_new: usize) -> Result<Vec<i32>> {
                Ok(vec![1; max_new])
            }
        }
        let long_factory =
            |_t: usize| -> Result<Box<dyn TierBackend>> { Ok(Box::new(LongBackend)) };
        let server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![1, 1], vec![4, 4], vec![50.0], 20)
                .unwrap()
                .continuous(vec![
                    EngineConfig {
                        pool_pages: 4,
                        page_tokens: 16,
                        max_running: 4,
                        prefill_chunk: usize::MAX,
                        share_prefixes: false,
                        preemption: PreemptionConfig::default(),
                    };
                    2
                ]),
        )
        .unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..6).map(|_| (0.0, vec![1; 17])).collect();
        let stats = server.serve(&trace, &long_factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 6);
        let mut ids: Vec<usize> = stats.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<_>>(), "exactly-once under preemption");
        let e = &stats.engine[0];
        assert!(e.preemptions > 0, "the tight pool must preempt: {e:?}");
        assert!(e.peak_pages <= e.peak_pool_pages, "budget must hold even under preemption");
        assert_eq!(e.forced_expansions, 0);
    }

    #[test]
    fn from_plan_with_engine_sizes_pools_from_the_cost_model() {
        use crate::cluster::ClusterSpec;
        use crate::models::llama_cascade;
        use crate::parallel::Strategy;
        use crate::perf::Workload;
        use crate::sched::plan::TierPlan;

        let cascade = llama_cascade();
        let plan = CascadePlan {
            policy: PolicySpec::threshold(vec![50.0]).unwrap(),
            tiers: vec![
                TierPlan {
                    model_name: cascade[0].name.to_string(),
                    gpus: 2,
                    strategy: Some(Strategy::uniform(1, 1, 2)),
                    workload: Workload { rate: 4.0, avg_input: 300.0, avg_output: 100.0 },
                    processing_ratio: 1.0,
                    predicted_p95: 1.0,
                    disagg: None,
                    speculation: None,
                },
                TierPlan {
                    model_name: cascade[1].name.to_string(),
                    gpus: 0,
                    strategy: None,
                    workload: Workload { rate: 0.0, avg_input: 0.0, avg_output: 0.0 },
                    processing_ratio: 0.0,
                    predicted_p95: 0.0,
                    disagg: None,
                    speculation: None,
                },
            ],
            predicted_latency: 1.0,
            predicted_quality: 80.0,
            preemption: vec![PreemptionMode::Swap; 2],
        };
        let cfg = ServerConfig::from_plan_with_engine(
            &plan,
            &cascade,
            &ClusterSpec::paper_testbed(),
            6,
        )
        .unwrap();
        let ExecMode::Continuous(engines) = &cfg.exec else {
            panic!("engine mode expected");
        };
        assert_eq!(engines.len(), 2);
        assert!(engines[0].pool_pages > 1000, "a deployed 8B tier has a deep pool");
        assert!(engines[1].pool_pages > 0, "undeployed tiers get a nominal pool");
        // The plan's swap knob round-trips into the deployed tier's
        // engine: a host budget and real PCIe/prefill cost rates.
        assert_eq!(engines[0].preemption.mode, PreemptionMode::Swap);
        assert!(engines[0].preemption.swap_pages > engines[0].pool_pages);
        assert!(engines[0].preemption.swap_s_per_page > 0.0);
        assert!(engines[0].preemption.prefill_s_per_token > 0.0);
        assert!(engines[0].preemption.page_bytes > 0.0);
        assert_eq!(
            engines[1].preemption,
            PreemptionConfig::default(),
            "undeployed tiers stay on recompute"
        );
        CascadeServer::new(cfg).unwrap();
        // Arity mismatch is rejected.
        assert!(ServerConfig::from_plan_with_engine(
            &plan,
            &cascade[..1],
            &ClusterSpec::paper_testbed(),
            6
        )
        .is_err());
    }

    // ---- Disaggregated (prefill/decode split) tiers ----

    fn disagg_config() -> ServerConfig {
        let mut cfg = ServerConfig::with_thresholds(vec![3, 1], vec![4, 2], vec![50.0], 4)
            .unwrap()
            .continuous(engine_cfgs(2));
        cfg.disagg =
            vec![Some(DisaggSpec { prefill_replicas: 2, decode_replicas: 1 }), None];
        cfg
    }

    #[test]
    fn disagg_tier_serves_exactly_once_and_migrates() {
        let server = CascadeServer::new(disagg_config()).unwrap();
        let trace: Vec<(f64, Vec<i32>)> =
            (0..20).map(|i| (0.0, vec![(i % 2) as i32, 7, 8])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 20);
        let mut ids: Vec<usize> = stats.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..20).collect::<Vec<_>>(), "no drops, no duplicates");
        // Routing semantics are unchanged by the split.
        assert_eq!(stats.per_tier_processed[0], 20);
        assert_eq!(stats.per_tier_processed[1], 10);
        for c in &stats.completions {
            assert_eq!(c.accepting_tier, trace[c.id].1[0] as usize, "req {}", c.id);
        }
        let e = &stats.engine[0];
        assert!(e.migrations > 0, "the split tier must hand sequences off: {e:?}");
        assert!(
            e.migrate_pages > 0,
            "private pages must cross the interconnect: {e:?}"
        );
        assert_eq!(stats.engine[1].migrations, 0, "unified tiers never migrate");
    }

    #[test]
    fn disagg_split_must_be_staffed_and_match_replicas() {
        // Split total != tier replica count.
        let mut cfg = disagg_config();
        cfg.disagg[0] = Some(DisaggSpec { prefill_replicas: 1, decode_replicas: 1 });
        assert!(CascadeServer::new(cfg).is_err());
        // A role with zero workers.
        let mut cfg = disagg_config();
        cfg.replicas[0] = 3;
        cfg.disagg[0] = Some(DisaggSpec { prefill_replicas: 3, decode_replicas: 0 });
        assert!(CascadeServer::new(cfg).is_err());
        // Arity mismatch with the cascade.
        let mut cfg = disagg_config();
        cfg.disagg.push(None);
        assert!(CascadeServer::new(cfg).is_err());
    }

    #[test]
    fn disagg_under_lockstep_serves_unified() {
        // A lockstep server has no iteration boundary to hand off at:
        // the split is carried in the config (from_plan keeps it) but
        // serving degrades to unified, losing nothing.
        let mut cfg = ServerConfig::with_thresholds(vec![2, 1], vec![4, 2], vec![50.0], 4)
            .unwrap();
        cfg.disagg =
            vec![Some(DisaggSpec { prefill_replicas: 1, decode_replicas: 1 }), None];
        let server = CascadeServer::new(cfg).unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..10).map(|_| (0.0, vec![0])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 10);
        assert_eq!(stats.engine[0].migrations, 0);
    }

    #[test]
    fn disagg_mid_migration_hot_swap_loses_no_requests() {
        // A hot-swap lands while sequences are in flight across the
        // prefill→decode handoff. The swap retunes the policy and the
        // unified tier's pool but must leave the split tier's role
        // counts alone — and every request completes exactly once.
        let server = CascadeServer::new(disagg_config()).unwrap();
        let control = ServeControl::new(2);
        let next = ServerConfig::with_thresholds(vec![3, 2], vec![4, 4], vec![0.0], 4)
            .unwrap()
            .continuous(engine_cfgs(2));
        let swap = SwapAt {
            control: Arc::clone(&control),
            at: 10,
            next,
            fired: AtomicBool::new(false),
        };
        let trace: Vec<(f64, Vec<i32>)> =
            (0..40).map(|i| (0.0, vec![(i % 2) as i32, 5])).collect();
        let stats = server
            .serve_adaptive(&trace, &factory, &FakeJudger, &control, Some(&swap))
            .unwrap();
        assert_eq!(stats.completions.len(), 40, "every request must survive the swap");
        assert_eq!(control.hot_swaps(), 1);
        let mut ids: Vec<usize> = stats.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..40).collect::<Vec<_>>(), "exactly-once across the swap");
        assert!(stats.engine[0].migrations > 0, "handoffs ran across the swap");
    }

    #[test]
    fn disagg_prefill_keeps_ownership_when_hub_is_shut() {
        // When no decode worker is accepting (hub closed or not yet
        // registered at the moment the prefill engine checks), handoff
        // stays closed and the prefill worker decodes locally — the
        // split degrades to unified serving instead of stranding work.
        // The hub-level retire/bounce invariants are pinned in
        // `engine::migrate`; this covers the serving-level fallback:
        // even a tiny burst that races worker startup completes fully.
        let server = CascadeServer::new(disagg_config()).unwrap();
        let trace: Vec<(f64, Vec<i32>)> = (0..8).map(|_| (0.0, vec![0])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 8);
        let mut ids: Vec<usize> = stats.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    // ---- Request-lifecycle tracing (obs) on the live path ----

    #[test]
    fn telemetry_one_terminal_event_per_request_and_linked_escalations() {
        use crate::obs::EventKind as K;
        let mut server = CascadeServer::new(config()).unwrap();
        let telem = ServeTelemetry::for_tiers(2);
        server.set_telemetry(Some(Arc::clone(&telem)));
        let trace: Vec<(f64, Vec<i32>)> =
            (0..20).map(|i| (0.0, vec![(i % 2) as i32, 7, 8])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 20);

        let by_req = telem.recorder.per_request();
        assert_eq!(by_req.len(), 20, "every admitted request must leave a span");
        for (req, evs) in &by_req {
            let fin: Vec<_> = evs.iter().filter(|e| e.kind == K::Finished).collect();
            assert_eq!(fin.len(), 1, "req {req}: exactly one terminal event");
            assert!(
                evs.last().map(|e| e.kind.is_terminal()).unwrap_or(false),
                "req {req}: terminal event must close the span"
            );
            assert_eq!(
                evs.iter().filter(|e| e.kind == K::Admitted).count(),
                1,
                "req {req}: exactly one admission"
            );
            assert!(fin[0].fb >= fin[0].fa, "req {req}: e2e >= ttft");
            let escalated = *req % 2 == 1; // difficulty 1 fails tier 0
            let esc: Vec<_> = evs.iter().filter(|e| e.kind == K::Escalate).collect();
            if escalated {
                assert_eq!(esc.len(), 1, "req {req}: one escalation hop");
                assert_eq!((esc[0].a, esc[0].b), (0, 1), "req {req}: tier 0 -> 1");
                // The chain spans both tiers under a single request id,
                // finishing on the tier that accepted.
                assert!(evs.iter().any(|e| e.tier == 0) && evs.iter().any(|e| e.tier == 1));
                assert_eq!(fin[0].tier, 1, "req {req}: accepted at tier 1");
                assert!(evs.iter().any(|e| {
                    e.kind == K::RouteDecision && e.tier == 0 && e.a == ACTION_ESCALATE
                }));
            } else {
                assert!(esc.is_empty(), "req {req}: easy requests never escalate");
                assert_eq!(fin[0].tier, 0, "req {req}: accepted at tier 0");
            }
            assert!(evs.iter().any(|e| {
                e.kind == K::RouteDecision && e.a == ACTION_ACCEPT && e.tier == fin[0].tier
            }));
        }
        assert_eq!(telem.recorder.dropped_events(), 0);

        // The registry derives the same counts the stats report, and the
        // scrape carries per-tier latency histograms.
        assert_eq!(telem.registry.counter("cascadia_requests_admitted_total{tier=\"0\"}"), 20);
        assert_eq!(telem.registry.counter("cascadia_requests_completed_total{tier=\"0\"}"), 10);
        assert_eq!(telem.registry.counter("cascadia_requests_completed_total{tier=\"1\"}"), 10);
        assert_eq!(telem.registry.counter("cascadia_escalations_total{from=\"0\",to=\"1\"}"), 10);
        let scrape = telem.registry.render_prometheus();
        assert!(scrape.contains("cascadia_ttft_seconds_bucket{tier=\"0\""), "{scrape}");
        assert!(scrape.contains("cascadia_e2e_latency_seconds_sum"), "{scrape}");
        assert!(scrape.contains("cascadia_trace_events"), "{scrape}");
    }

    #[test]
    fn telemetry_continuous_engines_trace_without_double_terminals() {
        use crate::obs::EventKind as K;
        let mut server = CascadeServer::new(continuous_config()).unwrap();
        let telem = ServeTelemetry::for_tiers(2);
        server.set_telemetry(Some(Arc::clone(&telem)));
        let trace: Vec<(f64, Vec<i32>)> =
            (0..12).map(|i| (0.0, vec![(i % 2) as i32, 7, 8])).collect();
        let stats = server.serve(&trace, &factory, &FakeJudger).unwrap();
        assert_eq!(stats.completions.len(), 12);
        let by_req = telem.recorder.per_request();
        assert_eq!(by_req.len(), 12);
        for (req, evs) in &by_req {
            assert_eq!(
                evs.iter().filter(|e| e.kind == K::Finished).count(),
                1,
                "req {req}: engine tracers must not add a second terminal"
            );
            assert!(
                evs.iter().any(|e| e.kind == K::PrefillChunk),
                "req {req}: engine prefill must be traced on the live path"
            );
            assert!(
                evs.iter().any(|e| e.kind == K::QueueExit),
                "req {req}: queue exit must be traced"
            );
        }
        assert_eq!(telem.recorder.dropped_events(), 0);
    }

    #[test]
    fn telemetry_hot_swap_emits_marker_event() {
        use crate::obs::EventKind as K;
        let mut server = CascadeServer::new(
            ServerConfig::with_thresholds(vec![1, 1], vec![1, 1], vec![50.0], 4).unwrap(),
        )
        .unwrap();
        let telem = ServeTelemetry::for_tiers(2);
        server.set_telemetry(Some(Arc::clone(&telem)));
        let control = ServeControl::new(2);
        let next =
            ServerConfig::with_thresholds(vec![3, 2], vec![4, 4], vec![0.0], 4).unwrap();
        let swap = SwapAt {
            control: Arc::clone(&control),
            at: 10,
            next,
            fired: AtomicBool::new(false),
        };
        let trace: Vec<(f64, Vec<i32>)> =
            (0..40).map(|i| (0.0, vec![(i % 2) as i32, 5])).collect();
        let stats = server
            .serve_adaptive(&trace, &factory, &FakeJudger, &control, Some(&swap))
            .unwrap();
        assert_eq!(stats.completions.len(), 40);
        let snap = telem.recorder.snapshot();
        let swaps: Vec<_> = snap.iter().filter(|e| e.kind == K::HotSwapApplied).collect();
        assert_eq!(swaps.len(), 1, "one hot-swap, one marker");
        assert_eq!(swaps[0].a, 1, "marker carries the swap ordinal");
        assert_eq!(swaps[0].req, REQ_NONE, "markers are not request-scoped");
        assert_eq!(telem.registry.counter("cascadia_hot_swaps_total"), 1);
        // Markers never leak into per-request spans.
        assert!(telem
            .recorder
            .per_request()
            .values()
            .all(|evs| evs.iter().all(|e| e.kind != K::HotSwapApplied)));
    }

    // ---- Cross-tier speculative decoding ----

    #[test]
    fn speculation_config_is_validated_at_construction_and_hot_swap() {
        let spec = Some(SpecSpec { draft_k: 3, acceptance: 0.5 });
        // Tier 0 has no shallower tier to draft with.
        let mut cfg = continuous_config();
        cfg.speculation = vec![spec, None];
        assert!(CascadeServer::new(cfg).is_err());
        // Arity must match the cascade.
        let mut cfg = continuous_config();
        cfg.speculation = vec![spec];
        assert!(CascadeServer::new(cfg).is_err());
        // draft_k 0 and out-of-range acceptance are rejected.
        let mut cfg = continuous_config();
        cfg.speculation = vec![None, Some(SpecSpec { draft_k: 0, acceptance: 0.5 })];
        assert!(CascadeServer::new(cfg).is_err());
        let mut cfg = continuous_config();
        cfg.speculation = vec![None, Some(SpecSpec { draft_k: 2, acceptance: 1.5 })];
        assert!(CascadeServer::new(cfg).is_err());
        // Speculation never rides a disaggregated tier: a SpecPair's
        // draft state does not survive the prefill->decode handoff.
        let mut cfg = disagg_config();
        cfg.disagg = vec![None, Some(DisaggSpec { prefill_replicas: 1, decode_replicas: 1 })];
        cfg.replicas = vec![3, 2];
        cfg.speculation = vec![None, spec];
        assert!(CascadeServer::new(cfg).is_err());
        // The hot-swap gate applies the same rules.
        let control = ServeControl::new(2);
        let mut cfg = continuous_config();
        cfg.speculation = vec![spec, None];
        assert!(control.apply_config(cfg).is_err());
        // A well-formed speculating config passes both gates.
        let mut cfg = continuous_config();
        cfg.speculation = vec![None, spec];
        assert!(CascadeServer::new(cfg.clone()).is_ok());
        assert!(control.apply_config(cfg).is_ok());
    }

    #[test]
    fn speculative_tier_is_lossless_and_counts_draft_tokens() {
        // Difficulty-2 prompts fail BOTH tiers, so tier 0's draft
        // stream agrees with tier 1's verify stream (both emit 0s) and
        // drafts are accepted. Difficulty-1 prompts disagree at every
        // position (tier 0 emits 0s, tier 1 emits 1s), so every draft
        // is rejected — the losslessness price, paid without changing
        // a single output token.
        let trace: Vec<(f64, Vec<i32>)> =
            (0..16).map(|i| (0.0, vec![1 + (i % 2) as i32, 7, 8])).collect();
        let run = |speculation: Vec<Option<SpecSpec>>| {
            let mut cfg = continuous_config();
            cfg.speculation = speculation;
            let server = CascadeServer::new(cfg).unwrap();
            server.serve(&trace, &factory, &FakeJudger).unwrap()
        };
        let plain = run(Vec::new());
        let spec = run(vec![None, Some(SpecSpec { draft_k: 3, acceptance: 0.5 })]);
        assert_eq!(spec.completions.len(), 16);
        let outputs = |s: &ServerStats| {
            let mut v: Vec<(usize, usize, Vec<i32>)> = s
                .completions
                .iter()
                .map(|c| (c.id, c.accepting_tier, c.output.clone()))
                .collect();
            v.sort();
            v
        };
        // Identical routing and bit-identical outputs: speculation is
        // an execution detail, never a quality change.
        assert_eq!(outputs(&plain), outputs(&spec));
        let e = &spec.engine[1];
        assert!(e.spec_accepted_tokens > 0, "agreeing drafts must be accepted: {e:?}");
        assert!(e.spec_rejected_tokens > 0, "disagreeing drafts must be rejected: {e:?}");
        assert_eq!(spec.engine[0].spec_accepted_tokens, 0, "tier 0 never drafts");
        assert_eq!(spec.engine[0].spec_rejected_tokens, 0);
        assert_eq!(plain.engine[1].spec_accepted_tokens, 0);
        assert_eq!(plain.engine[1].spec_rejected_tokens, 0);
    }

    #[test]
    fn hot_swap_disables_speculation_without_orphaning_drafts() {
        // Speculation is live on tier 1 with drafts in flight when a
        // mid-run hot-swap disables it and shrinks the KV pools. Every
        // request must complete exactly once with bit-identical
        // outputs — no draft state may be orphaned by the flip, and
        // the tail of the run must decode plainly.
        let spec_cfg = |speculation: Vec<Option<SpecSpec>>, pool: usize| {
            let mut cfg =
                ServerConfig::with_thresholds(vec![2, 1], vec![4, 4], vec![50.0], 8)
                    .unwrap()
                    .continuous(swap_engine_cfgs(2, pool));
            cfg.speculation = speculation;
            cfg
        };
        let server = CascadeServer::new(spec_cfg(
            vec![None, Some(SpecSpec { draft_k: 3, acceptance: 0.5 })],
            8,
        ))
        .unwrap();
        let control = ServeControl::new(2);
        let swap = SwapAt {
            control: Arc::clone(&control),
            at: 12,
            next: spec_cfg(Vec::new(), 6),
            fired: AtomicBool::new(false),
        };
        // All difficulty-2: every request escalates and speculates on
        // tier 1 (full agreement: both tiers emit 0s). Arrivals are
        // staggered so early requests are drafting on tier 1 well
        // before the swap request (#12) is even admitted.
        let trace: Vec<(f64, Vec<i32>)> =
            (0..24).map(|i| (i as f64 * 0.005, vec![2, 7, 8])).collect();
        let stats = server
            .serve_adaptive(&trace, &factory, &FakeJudger, &control, Some(&swap))
            .unwrap();
        assert_eq!(stats.completions.len(), 24, "no draft state may be orphaned");
        let mut ids: Vec<usize> = stats.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..24).collect::<Vec<_>>(), "exactly-once across the swap");
        assert_eq!(control.hot_swaps(), 1);
        for c in &stats.completions {
            assert_eq!(c.output, vec![0; 8], "req {}: speculation altered tokens", c.id);
        }
        let e = &stats.engine[1];
        assert!(
            e.spec_accepted_tokens > 0,
            "drafts must have been in flight before the swap: {e:?}"
        );
    }
}
