//! Minimal network front-end for the live cascade: a line-delimited
//! JSON protocol over TCP (std-only; tokio is not in the vendored crate
//! set, so this uses a small blocking accept loop + the serving
//! engine's own worker threads).
//!
//! Protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": [60, 3, 5], "max_new": 8}
//!   <- {"id": 1, "output": [8, 13, ...], "score": 100.0,
//!       "tier": 0, "latency_ms": 41.2}
//!
//! Used by `cascadia serve` (see `examples/serve_tcp.rs`) and the
//! integration test; demonstrates the coordinator as an actual network
//! service rather than a library loop. Routing goes through the same
//! [`RoutingPolicy`] abstraction as the offline scheduler and the
//! batched engine; [`TcpFrontend::from_plan`] wires a scheduler plan
//! straight into the wire service.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::server::{BackendFactory, ResponseJudger, TierBackend};
use crate::obs::{
    export_recorder_health, Clock, Event, EventKind, MetricsRegistry, ProfileAggregator,
    ProfileConfig, TraceRecorder, ACTION_ACCEPT, ACTION_ESCALATE, ACTION_SKIP, LATENCY_BUCKETS,
};
use crate::router::{Decision, PolicySpec, RequestFeatures, RoutingPolicy};
use crate::sched::plan::CascadePlan;
use crate::util::json::Json;
use crate::util::sync::RwLockExt;

/// A single-connection-at-a-time TCP server over one backend chain.
///
/// Each request runs through the cascade *synchronously* per
/// connection (the heavy concurrency story lives in
/// [`crate::coordinator::server::CascadeServer`]; this front-end is
/// about the wire protocol and lifecycle).
pub struct TcpFrontend {
    /// Swappable routing policy: [`TcpFrontend::apply_plan`] replaces
    /// it while the accept loop is live, so a re-schedule reaches the
    /// wire path without a restart.
    policy: RwLock<PolicySpec>,
    pub n_tiers: usize,
    pub max_new_default: usize,
    /// Unified metrics for the wire path, scraped via `GET /metrics`
    /// on the same port (Prometheus text exposition 0.0.4).
    registry: Arc<MetricsRegistry>,
    /// Request-lifecycle events for the wire path, in the same 12-kind
    /// vocabulary the engine and DES emit (one shard per tier). Folded
    /// on demand into a latency-attribution report by `GET /profile`.
    recorder: Arc<TraceRecorder>,
    clock: Clock,
    next_req: AtomicU64,
}

impl TcpFrontend {
    pub fn new(policy: PolicySpec, n_tiers: usize, max_new_default: usize) -> Result<TcpFrontend> {
        policy.validate(n_tiers)?;
        Ok(TcpFrontend {
            policy: RwLock::new(policy),
            n_tiers,
            max_new_default,
            registry: Arc::new(MetricsRegistry::new()),
            recorder: Arc::new(TraceRecorder::for_tiers(n_tiers.max(1))),
            clock: Clock::wall(),
            next_req: AtomicU64::new(0),
        })
    }

    /// The front-end's metrics registry, shared with the scrape
    /// endpoint — callers can read counters/histograms directly.
    pub fn metrics(&self) -> Arc<MetricsRegistry> {
        Arc::clone(&self.registry)
    }

    /// The wire path's lifecycle trace, shared with `GET /profile`.
    pub fn recorder(&self) -> Arc<TraceRecorder> {
        Arc::clone(&self.recorder)
    }

    /// Wire a scheduler-produced plan into the front-end: the plan's
    /// policy routes and its tier count sizes the backend chain.
    pub fn from_plan(plan: &CascadePlan, max_new_default: usize) -> Result<TcpFrontend> {
        TcpFrontend::new(plan.policy.clone(), plan.tiers.len(), max_new_default)
    }

    /// Snapshot of the current routing policy.
    pub fn policy(&self) -> PolicySpec {
        self.policy.pread().clone()
    }

    /// Label of the current routing policy (for logs).
    pub fn policy_label(&self) -> String {
        self.policy.pread().label()
    }

    /// Hot-swap the routing policy; requests already read from the
    /// socket finish under the policy they started with, subsequent
    /// requests route under the new one.
    pub fn set_policy(&self, policy: PolicySpec) -> Result<()> {
        policy.validate(self.n_tiers)?;
        *self.policy.pwrite() = policy;
        Ok(())
    }

    /// Hot-swap a re-scheduled plan's policy into the live front-end.
    /// The plan must cover the same backend chain (tier count).
    pub fn apply_plan(&self, plan: &CascadePlan) -> Result<()> {
        if plan.tiers.len() != self.n_tiers {
            anyhow::bail!(
                "plan has {} tiers but the front-end serves {}",
                plan.tiers.len(),
                self.n_tiers
            );
        }
        self.set_policy(plan.policy.clone())
    }

    /// Serve on `addr` until `shutdown` is set. Backends are created
    /// once per tier on this thread.
    pub fn serve(
        &self,
        addr: &str,
        factory: &BackendFactory<'_>,
        judger: &dyn ResponseJudger,
        shutdown: Arc<AtomicBool>,
    ) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let mut backends: Vec<Box<dyn TierBackend>> = Vec::new();
        for t in 0..self.n_tiers {
            backends.push(factory(t)?);
        }
        while !shutdown.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    if let Err(e) = self.handle(stream, &mut backends, judger, &shutdown) {
                        eprintln!("connection error: {e}");
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    fn handle(
        &self,
        stream: TcpStream,
        backends: &mut [Box<dyn TierBackend>],
        judger: &dyn ResponseJudger,
        shutdown: &AtomicBool,
    ) -> Result<()> {
        stream.set_nonblocking(false)?;
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            // A plain-HTTP scrape on the JSON port: answer the request
            // line with a full HTTP response and close the connection
            // (Prometheus opens a fresh connection per scrape).
            if line.trim_start().starts_with("GET ") {
                let path = line.trim_start();
                let (status, ctype, body) = if path.starts_with("GET /metrics") {
                    export_recorder_health(&self.recorder, &self.registry);
                    (
                        "200 OK",
                        "text/plain; version=0.0.4; charset=utf-8",
                        self.registry.render_prometheus(),
                    )
                } else if path.starts_with("GET /profile") {
                    let events = self.recorder.snapshot();
                    let mut agg = ProfileAggregator::fold(ProfileConfig::default(), &events);
                    let report = agg.report(self.recorder.dropped_events());
                    (
                        "200 OK",
                        "application/json; charset=utf-8",
                        format!("{}\n", report.to_json()),
                    )
                } else {
                    (
                        "404 Not Found",
                        "text/plain; charset=utf-8",
                        String::from("only /metrics and /profile are served\n"),
                    )
                };
                write!(
                    writer,
                    "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
                    body.len()
                )?;
                return Ok(());
            }
            let reply = match self.one_request(&line, backends, judger) {
                Ok(r) => r,
                Err(e) => Json::obj(vec![("error", Json::str(e.to_string()))]),
            };
            writeln!(writer, "{reply}")?;
        }
        Ok(())
    }

    fn one_request(
        &self,
        line: &str,
        backends: &mut [Box<dyn TierBackend>],
        judger: &dyn ResponseJudger,
    ) -> Result<Json> {
        let req = Json::parse(line).context("request is not valid JSON")?;
        let id = req.get("id").and_then(|v| v.as_i64().ok()).unwrap_or(0);
        let prompt: Vec<i32> = req
            .req("prompt")?
            .as_arr()?
            .iter()
            .map(|v| v.as_i64().map(|x| x as i32))
            .collect::<Result<_>>()?;
        if prompt.is_empty() {
            anyhow::bail!("empty prompt");
        }
        let max_new = req
            .get("max_new")
            .and_then(|v| v.as_usize().ok())
            .unwrap_or(self.max_new_default);

        let c = self.n_tiers;
        let features = RequestFeatures::live(prompt.len());
        let t0 = Instant::now();
        let rid = self.next_req.fetch_add(1, Ordering::Relaxed);
        // One consistent policy snapshot per request: a concurrent
        // hot-swap never changes the rules mid-cascade.
        let policy = self.policy.pread().clone();
        let mut tier = policy.entry_tier(&features, c).min(c - 1);
        self.registry.inc(&format!("cascadia_requests_admitted_total{{tier=\"{tier}\"}}"));
        let mut adm = Event::at(self.clock.now(), rid, tier as u32, EventKind::Admitted);
        adm.a = tier as u64;
        self.recorder.emit(tier, adm);
        let mut ttft = None;
        let (tier, output, score) = loop {
            // The wire path serves synchronously per connection, so the
            // queue span collapses to a point — emitted anyway so the
            // profile aggregator sees the same event shape as the
            // engine and DES paths.
            let t_q = self.clock.now();
            self.recorder.emit(tier, Event::at(t_q, rid, tier as u32, EventKind::QueueEnter));
            self.recorder.emit(tier, Event::at(t_q, rid, tier as u32, EventKind::QueueExit));
            let output = backends[tier].generate(&prompt, max_new)?;
            let score = judger.score(&prompt, &output);
            let t_dec = self.clock.now();
            ttft.get_or_insert_with(|| t0.elapsed().as_secs_f64());
            let decision = if tier == c - 1 {
                Decision::Accept
            } else {
                policy.decide(tier, score, &features, c)
            };
            match decision {
                Decision::Accept => {
                    let mut route = Event::at(t_dec, rid, tier as u32, EventKind::RouteDecision);
                    route.a = ACTION_ACCEPT;
                    route.b = tier as u64;
                    self.recorder.emit(tier, route);
                    break (tier, output, score);
                }
                Decision::Escalate | Decision::SkipTo(_) => {
                    let next = match decision {
                        Decision::SkipTo(t) => t.clamp(tier + 1, c - 1),
                        _ => tier + 1,
                    };
                    let mut route = Event::at(t_dec, rid, tier as u32, EventKind::RouteDecision);
                    route.a = if matches!(decision, Decision::SkipTo(_)) {
                        ACTION_SKIP
                    } else {
                        ACTION_ESCALATE
                    };
                    route.b = next as u64;
                    self.recorder.emit(tier, route);
                    let mut esc = Event::at(t_dec, rid, tier as u32, EventKind::Escalate);
                    esc.a = tier as u64;
                    esc.b = next as u64;
                    self.recorder.emit(tier, esc);
                    self.registry.inc(&format!(
                        "cascadia_escalations_total{{from=\"{tier}\",to=\"{next}\"}}"
                    ));
                    tier = next;
                }
            }
        };
        let e2e_s = t0.elapsed().as_secs_f64();
        self.registry
            .inc(&format!("cascadia_requests_completed_total{{tier=\"{tier}\"}}"));
        self.registry.observe(
            &format!("cascadia_e2e_latency_seconds{{tier=\"{tier}\"}}"),
            LATENCY_BUCKETS,
            e2e_s,
        );
        let mut fin = Event::at(self.clock.now(), rid, tier as u32, EventKind::Finished);
        fin.fa = ttft.unwrap_or(e2e_s);
        fin.fb = e2e_s;
        self.recorder.emit(tier, fin);
        Ok(Json::obj(vec![
            ("id", Json::num(id as f64)),
            (
                "output",
                Json::arr(output.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("score", Json::num(score)),
            ("tier", Json::num(tier as f64)),
            (
                "latency_ms",
                Json::num(t0.elapsed().as_secs_f64() * 1e3),
            ),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    struct EchoBackend(usize);

    impl TierBackend for EchoBackend {
        fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
            // Tier t answers "correctly" iff prompt[0] <= t.
            let ok = prompt.first().copied().unwrap_or(0) <= self.0 as i32;
            Ok(vec![if ok { 1 } else { 0 }; max_new.min(3)])
        }
    }

    struct BitJudger;

    impl ResponseJudger for BitJudger {
        fn score(&self, _p: &[i32], o: &[i32]) -> f64 {
            if o.first() == Some(&1) {
                95.0
            } else {
                5.0
            }
        }
    }

    fn spawn_server(addr: &'static str, policy: PolicySpec, n_tiers: usize) -> Arc<AtomicBool> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = shutdown.clone();
        std::thread::spawn(move || {
            let fe = TcpFrontend::new(policy, n_tiers, 4).unwrap();
            let factory = |t: usize| -> Result<Box<dyn TierBackend>> {
                Ok(Box::new(EchoBackend(t)))
            };
            fe.serve(addr, &factory, &BitJudger, sd).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(150));
        shutdown
    }

    #[test]
    fn tcp_roundtrip_and_escalation() {
        let addr = "127.0.0.1:39471";
        let shutdown =
            spawn_server(addr, PolicySpec::threshold(vec![50.0]).unwrap(), 2);

        let mut stream = TcpStream::connect(addr).unwrap();
        // Easy request (difficulty 0) -> tier 0.
        writeln!(stream, r#"{{"id": 1, "prompt": [0, 7], "max_new": 3}}"#).unwrap();
        // Hard request (difficulty 1) -> escalates to tier 1.
        writeln!(stream, r#"{{"id": 2, "prompt": [1, 7]}}"#).unwrap();
        // Malformed -> error object, connection stays alive.
        writeln!(stream, "not json").unwrap();
        writeln!(stream, r#"{{"id": 3, "prompt": [0]}}"#).unwrap();

        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut read_json = || {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line).unwrap()
        };
        let r1 = read_json();
        assert_eq!(r1.req("tier").unwrap().as_i64().unwrap(), 0);
        assert!(r1.req("score").unwrap().as_f64().unwrap() >= 50.0);
        let r2 = read_json();
        assert_eq!(r2.req("tier").unwrap().as_i64().unwrap(), 1);
        let r3 = read_json();
        assert!(r3.get("error").is_some());
        let r4 = read_json();
        assert_eq!(r4.req("id").unwrap().as_i64().unwrap(), 3);

        shutdown.store(true, Ordering::SeqCst);
    }

    #[test]
    fn tcp_length_policy_routes_long_prompts_deep() {
        let addr = "127.0.0.1:39473";
        // Prompts of >= 4 tokens enter at tier 1 directly.
        let shutdown = spawn_server(
            addr,
            PolicySpec::length(vec![50.0], 4.0, 1).unwrap(),
            2,
        );

        let mut stream = TcpStream::connect(addr).unwrap();
        // Short easy prompt -> tier 0.
        writeln!(stream, r#"{{"id": 1, "prompt": [0, 7]}}"#).unwrap();
        // Long prompt -> enters (and accepts) at tier 1 without
        // touching tier 0, even though tier 0 could have answered it.
        writeln!(stream, r#"{{"id": 2, "prompt": [0, 7, 7, 7, 7]}}"#).unwrap();

        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut read_json = || {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line).unwrap()
        };
        let r1 = read_json();
        assert_eq!(r1.req("tier").unwrap().as_i64().unwrap(), 0);
        let r2 = read_json();
        assert_eq!(r2.req("tier").unwrap().as_i64().unwrap(), 1);

        shutdown.store(true, Ordering::SeqCst);
    }

    #[test]
    fn metrics_endpoint_serves_prometheus_text() {
        use std::io::Read as _;
        let addr = "127.0.0.1:39477";
        let shutdown =
            spawn_server(addr, PolicySpec::threshold(vec![50.0]).unwrap(), 2);

        // Serve one easy and one hard request so both tiers have counts.
        let mut stream = TcpStream::connect(addr).unwrap();
        writeln!(stream, r#"{{"id": 1, "prompt": [0, 7]}}"#).unwrap();
        writeln!(stream, r#"{{"id": 2, "prompt": [1, 7]}}"#).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line).unwrap();
        }
        drop(reader);
        drop(stream);

        // A fresh connection scrapes like Prometheus would.
        let mut scrape = TcpStream::connect(addr).unwrap();
        write!(scrape, "GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut response = String::new();
        BufReader::new(scrape).read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.0 200 OK"), "{response}");
        assert!(response.contains("text/plain; version=0.0.4"), "{response}");
        assert!(
            response.contains("cascadia_requests_completed_total{tier=\"0\"} 1"),
            "{response}"
        );
        assert!(
            response.contains("cascadia_requests_completed_total{tier=\"1\"} 1"),
            "{response}"
        );
        assert!(
            response.contains("cascadia_escalations_total{from=\"0\",to=\"1\"} 1"),
            "{response}"
        );
        assert!(response.contains("cascadia_e2e_latency_seconds_bucket"), "{response}");

        // Unknown paths get a 404, not a JSON error.
        let mut other = TcpStream::connect(addr).unwrap();
        write!(other, "GET /health HTTP/1.0\r\n\r\n").unwrap();
        let mut response = String::new();
        BufReader::new(other).read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.0 404"), "{response}");

        shutdown.store(true, Ordering::SeqCst);
    }

    #[test]
    fn profile_endpoint_serves_phase_attribution_json() {
        use std::io::Read as _;
        let addr = "127.0.0.1:39479";
        let shutdown =
            spawn_server(addr, PolicySpec::threshold(vec![50.0]).unwrap(), 2);

        // One accept-at-entry and one escalated request.
        let mut stream = TcpStream::connect(addr).unwrap();
        writeln!(stream, r#"{{"id": 1, "prompt": [0, 7]}}"#).unwrap();
        writeln!(stream, r#"{{"id": 2, "prompt": [1, 7]}}"#).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line).unwrap();
        }
        drop(reader);
        drop(stream);

        let mut scrape = TcpStream::connect(addr).unwrap();
        write!(scrape, "GET /profile HTTP/1.0\r\n\r\n").unwrap();
        let mut response = String::new();
        BufReader::new(scrape).read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.0 200 OK"), "{response}");
        assert!(response.contains("application/json"), "{response}");
        let body = response.split("\r\n\r\n").nth(1).unwrap();
        let json = Json::parse(body).unwrap();
        assert_eq!(
            json.req("schema").unwrap().as_str().unwrap(),
            "cascadia.profile.v1"
        );
        assert_eq!(json.req("requests").unwrap().as_i64().unwrap(), 2);
        assert_eq!(json.req("dropped_events").unwrap().as_i64().unwrap(), 0);
        // Both requests fold through the full attribution path.
        let attribution = json.req("attribution").unwrap();
        assert_eq!(attribution.req("matched").unwrap().as_i64().unwrap(), 2);
        // The escalated request shows up as tier-0 outflow.
        let tiers = json.req("tiers").unwrap().as_arr().unwrap();
        assert_eq!(tiers[0].req("escalated_out").unwrap().as_i64().unwrap(), 1);

        // The same scrape port exports trace-ring health on /metrics.
        let mut metrics = TcpStream::connect(addr).unwrap();
        write!(metrics, "GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut response = String::new();
        BufReader::new(metrics).read_to_string(&mut response).unwrap();
        assert!(
            response.contains("cascadia_trace_ring_occupancy{shard=\"0\"}"),
            "{response}"
        );
        assert!(
            response.contains("cascadia_trace_dropped_events_total{shard=\"0\"} 0"),
            "{response}"
        );

        shutdown.store(true, Ordering::SeqCst);
    }

    #[test]
    fn frontend_rejects_mismatched_policy() {
        assert!(TcpFrontend::new(PolicySpec::threshold(vec![50.0]).unwrap(), 3, 4).is_err());
        // And a live swap is validated against the backend chain too.
        let fe = TcpFrontend::new(PolicySpec::threshold(vec![50.0]).unwrap(), 2, 4).unwrap();
        assert!(fe.set_policy(PolicySpec::threshold(vec![50.0, 60.0]).unwrap()).is_err());
        assert_eq!(fe.policy_label(), "H=(50)");
    }

    #[test]
    fn policy_hot_swap_changes_routing_live() {
        let addr = "127.0.0.1:39475";
        let shutdown = Arc::new(AtomicBool::new(false));
        let fe = Arc::new(
            TcpFrontend::new(PolicySpec::threshold(vec![50.0]).unwrap(), 2, 4).unwrap(),
        );
        let fe_srv = Arc::clone(&fe);
        let sd = shutdown.clone();
        std::thread::spawn(move || {
            let factory = |t: usize| -> Result<Box<dyn TierBackend>> {
                Ok(Box::new(EchoBackend(t)))
            };
            fe_srv.serve(addr, &factory, &BitJudger, sd).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(150));

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut read_json = || {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line).unwrap()
        };
        // A hard request (difficulty 1) escalates under H=50.
        writeln!(stream, r#"{{"id": 1, "prompt": [1, 7]}}"#).unwrap();
        assert_eq!(read_json().req("tier").unwrap().as_i64().unwrap(), 1);
        // Hot-swap to accept-everything: the same request now completes
        // at tier 0 — on the same connection, no restart.
        fe.set_policy(PolicySpec::threshold(vec![0.0]).unwrap()).unwrap();
        writeln!(stream, r#"{{"id": 2, "prompt": [1, 7]}}"#).unwrap();
        assert_eq!(read_json().req("tier").unwrap().as_i64().unwrap(), 0);

        shutdown.store(true, Ordering::SeqCst);
    }
}
