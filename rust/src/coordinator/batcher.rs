//! Continuous batcher: the admission policy between a tier's queue and
//! its replicas.
//!
//! Iteration-level batching (Orca-style): between decode iterations a
//! replica admits waiting requests up to its KV-capacity bound. The
//! batcher is shared by the discrete-event simulator (implicitly, same
//! policy) and the live serving engine; it preserves FIFO order within
//! a tier and never exceeds `max_batch`.

use std::collections::VecDeque;

/// One queued work item.
#[derive(Debug, Clone, PartialEq)]
pub struct Pending<T> {
    pub item: T,
    /// Enqueue timestamp (seconds, caller's clock).
    pub enqueued_at: f64,
}

/// FIFO queue with iteration-level admission.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<Pending<T>>,
    /// Max concurrently admitted items (KV-capacity bound).
    pub max_batch: usize,
    /// Currently admitted (in-flight) count.
    in_flight: usize,
    /// Peak queue depth seen (diagnostics).
    pub peak_depth: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize) -> Batcher<T> {
        assert!(max_batch > 0, "max_batch must be positive");
        Batcher { queue: VecDeque::new(), max_batch, in_flight: 0, peak_depth: 0 }
    }

    pub fn push(&mut self, item: T, now: f64) {
        self.queue.push_back(Pending { item, enqueued_at: now });
        self.peak_depth = self.peak_depth.max(self.queue.len());
    }

    /// Admit as many items as capacity allows; returns them in FIFO
    /// order and marks them in-flight.
    pub fn admit(&mut self) -> Vec<Pending<T>> {
        self.admit_up_to(usize::MAX)
    }

    /// Admit at most `cap` items (never beyond the KV-capacity bound).
    /// The serving engine uses this to spread admission across a
    /// tier's replicas — one replica must not drain the whole queue
    /// into a serial batch while its siblings idle, or the pool size
    /// (the hot-swap capacity lever) stops mattering.
    pub fn admit_up_to(&mut self, cap: usize) -> Vec<Pending<T>> {
        let mut out = Vec::new();
        while self.in_flight < self.max_batch && out.len() < cap {
            let Some(p) = self.queue.pop_front() else { break };
            self.in_flight += 1;
            out.push(p);
        }
        out
    }

    /// Mark `n` in-flight items complete, freeing capacity.
    pub fn complete(&mut self, n: usize) {
        assert!(n <= self.in_flight, "completing more than in flight");
        self.in_flight -= n;
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.in_flight == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push(i, i as f64);
        }
        let first = b.admit();
        assert_eq!(first.iter().map(|p| p.item).collect::<Vec<_>>(), vec![0, 1]);
        // Nothing more fits until completion.
        assert!(b.admit().is_empty());
        b.complete(1);
        let next = b.admit();
        assert_eq!(next[0].item, 2);
    }

    #[test]
    fn never_exceeds_capacity() {
        let mut b = Batcher::new(3);
        for i in 0..10 {
            b.push(i, 0.0);
        }
        let a = b.admit();
        assert_eq!(a.len(), 3);
        assert_eq!(b.in_flight(), 3);
        b.complete(3);
        assert_eq!(b.admit().len(), 3);
    }

    #[test]
    fn admit_up_to_caps_per_call_but_not_capacity() {
        let mut b = Batcher::new(4);
        for i in 0..6 {
            b.push(i, 0.0);
        }
        // Two callers splitting a 4-slot tier: each gets its share.
        let a = b.admit_up_to(2);
        assert_eq!(a.iter().map(|p| p.item).collect::<Vec<_>>(), vec![0, 1]);
        let c = b.admit_up_to(2);
        assert_eq!(c.iter().map(|p| p.item).collect::<Vec<_>>(), vec![2, 3]);
        // Capacity bound still holds.
        assert!(b.admit_up_to(2).is_empty());
        assert_eq!(b.in_flight(), 4);
        b.complete(4);
        assert_eq!(b.admit_up_to(10).len(), 2);
    }

    #[test]
    fn tracks_peak_depth() {
        let mut b = Batcher::new(1);
        for i in 0..4 {
            b.push(i, 0.0);
        }
        assert_eq!(b.peak_depth, 4);
        b.admit();
        assert_eq!(b.queued(), 3);
    }

    #[test]
    #[should_panic(expected = "completing more than in flight")]
    fn over_completion_panics() {
        let mut b: Batcher<u32> = Batcher::new(1);
        b.complete(1);
    }

    #[test]
    fn idle_tracking() {
        let mut b = Batcher::new(2);
        assert!(b.is_idle());
        b.push(1, 0.0);
        assert!(!b.is_idle());
        b.admit();
        assert!(!b.is_idle());
        b.complete(1);
        assert!(b.is_idle());
    }
}
