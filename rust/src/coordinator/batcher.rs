//! Continuous batcher: the admission policy between a tier's queue and
//! its replicas.
//!
//! Iteration-level batching (Orca-style): between decode iterations a
//! replica admits waiting requests up to its KV-capacity bound. The
//! batcher is shared by the discrete-event simulator (implicitly, same
//! policy) and the live serving engine; it preserves FIFO order within
//! a tier and never exceeds `max_batch`. It also tracks the queue
//! telemetry the server reports per tier: peak depth and mean
//! admission wait.

use std::collections::VecDeque;

/// One queued work item.
#[derive(Debug, Clone, PartialEq)]
pub struct Pending<T> {
    pub item: T,
    /// Enqueue timestamp (seconds, caller's clock).
    pub enqueued_at: f64,
}

/// FIFO queue with iteration-level admission.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<Pending<T>>,
    /// Max concurrently admitted items (KV-capacity bound).
    pub max_batch: usize,
    /// Currently admitted (in-flight) count.
    in_flight: usize,
    /// Peak queue depth seen (diagnostics).
    pub peak_depth: usize,
    /// Items admitted over the batcher's lifetime.
    admitted: usize,
    /// Total seconds admitted items spent queued.
    wait_sum: f64,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize) -> Batcher<T> {
        assert!(max_batch > 0, "max_batch must be positive");
        Batcher {
            queue: VecDeque::new(),
            max_batch,
            in_flight: 0,
            peak_depth: 0,
            admitted: 0,
            wait_sum: 0.0,
        }
    }

    pub fn push(&mut self, item: T, now: f64) {
        self.queue.push_back(Pending { item, enqueued_at: now });
        self.peak_depth = self.peak_depth.max(self.queue.len());
    }

    /// Admit as many items as capacity allows; returns them in FIFO
    /// order and marks them in-flight. `now` (caller's clock, same as
    /// `push`) feeds the queue-wait telemetry.
    pub fn admit(&mut self, now: f64) -> Vec<Pending<T>> {
        self.admit_up_to(usize::MAX, now)
    }

    /// Admit at most `cap` items (never beyond the KV-capacity bound);
    /// `cap == 0` is an explicit no-op. The serving engine uses the cap
    /// to spread admission across a tier's replicas — one replica must
    /// not drain the whole queue into a serial batch while its siblings
    /// idle, or the pool size (the hot-swap capacity lever) stops
    /// mattering.
    pub fn admit_up_to(&mut self, cap: usize, now: f64) -> Vec<Pending<T>> {
        if cap == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        while self.in_flight < self.max_batch && out.len() < cap {
            let Some(p) = self.queue.pop_front() else { break };
            self.in_flight += 1;
            self.admitted += 1;
            self.wait_sum += (now - p.enqueued_at).max(0.0);
            out.push(p);
        }
        out
    }

    /// Mark up to `n` in-flight items complete, freeing capacity.
    /// Saturates at the in-flight count (a release server must not
    /// abort on a miscounting worker) and returns how many were
    /// actually completed.
    pub fn complete(&mut self, n: usize) -> usize {
        let done = n.min(self.in_flight);
        self.in_flight -= done;
        done
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.in_flight == 0
    }

    /// Items admitted over the batcher's lifetime.
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    /// Mean seconds admitted items spent queued (0 when nothing was
    /// admitted yet).
    pub fn mean_wait(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            self.wait_sum / self.admitted as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push(i, i as f64);
        }
        let first = b.admit(5.0);
        assert_eq!(first.iter().map(|p| p.item).collect::<Vec<_>>(), vec![0, 1]);
        // Nothing more fits until completion.
        assert!(b.admit(5.0).is_empty());
        assert_eq!(b.complete(1), 1);
        let next = b.admit(5.0);
        assert_eq!(next[0].item, 2);
    }

    #[test]
    fn never_exceeds_capacity() {
        let mut b = Batcher::new(3);
        for i in 0..10 {
            b.push(i, 0.0);
        }
        let a = b.admit(0.0);
        assert_eq!(a.len(), 3);
        assert_eq!(b.in_flight(), 3);
        b.complete(3);
        assert_eq!(b.admit(0.0).len(), 3);
    }

    #[test]
    fn admit_up_to_caps_per_call_but_not_capacity() {
        let mut b = Batcher::new(4);
        for i in 0..6 {
            b.push(i, 0.0);
        }
        // Two callers splitting a 4-slot tier: each gets its share.
        let a = b.admit_up_to(2, 0.0);
        assert_eq!(a.iter().map(|p| p.item).collect::<Vec<_>>(), vec![0, 1]);
        let c = b.admit_up_to(2, 0.0);
        assert_eq!(c.iter().map(|p| p.item).collect::<Vec<_>>(), vec![2, 3]);
        // Capacity bound still holds.
        assert!(b.admit_up_to(2, 0.0).is_empty());
        assert_eq!(b.in_flight(), 4);
        b.complete(4);
        assert_eq!(b.admit_up_to(10, 0.0).len(), 2);
    }

    #[test]
    fn zero_cap_is_a_noop() {
        let mut b = Batcher::new(4);
        b.push(1, 0.0);
        assert!(b.admit_up_to(0, 1.0).is_empty());
        assert_eq!(b.queued(), 1);
        assert_eq!(b.in_flight(), 0);
        assert_eq!(b.admitted(), 0, "a zero-cap call must not touch telemetry");
    }

    #[test]
    fn tracks_peak_depth() {
        let mut b = Batcher::new(1);
        for i in 0..4 {
            b.push(i, 0.0);
        }
        assert_eq!(b.peak_depth, 4);
        b.admit(0.0);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn over_completion_saturates_instead_of_panicking() {
        let mut b: Batcher<u32> = Batcher::new(2);
        assert_eq!(b.complete(1), 0, "nothing in flight: nothing completed");
        b.push(1, 0.0);
        b.admit(0.0);
        assert_eq!(b.complete(5), 1, "completion saturates at the in-flight count");
        assert_eq!(b.in_flight(), 0);
        assert_eq!(b.complete(1), 0);
    }

    #[test]
    fn queue_wait_telemetry() {
        let mut b = Batcher::new(2);
        b.push(1, 10.0);
        b.push(2, 10.0);
        b.push(3, 11.0);
        let a = b.admit(12.0); // items 1, 2 waited 2s each
        assert_eq!(a.len(), 2);
        assert_eq!(b.admitted(), 2);
        assert!((b.mean_wait() - 2.0).abs() < 1e-12);
        b.complete(2);
        b.admit(14.0); // item 3 waited 3s
        assert_eq!(b.admitted(), 3);
        assert!((b.mean_wait() - 7.0 / 3.0).abs() < 1e-12);
        // A clock running behind enqueue stamps never goes negative.
        b.push(4, 100.0);
        b.complete(1);
        b.admit(0.0);
        assert!(b.mean_wait() >= 0.0);
    }

    #[test]
    fn idle_tracking() {
        let mut b = Batcher::new(2);
        assert!(b.is_idle());
        b.push(1, 0.0);
        assert!(!b.is_idle());
        b.admit(0.0);
        assert!(!b.is_idle());
        b.complete(1);
        assert!(b.is_idle());
    }
}
