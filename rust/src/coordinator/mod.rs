//! The serving coordinator — Cascadia's L3 runtime.
//!
//! Two execution paths share the same plan/routing logic:
//!
//! * [`cascade_sim`] — whole-cascade evaluation on the discrete-event
//!   simulator: tier t+1's arrival process is exactly the completion
//!   process of tier t's escalated requests. Generates every end-to-end
//!   figure (7, 8, 9, 10, 11).
//! * [`server`] — the real serving engine used by the e2e example:
//!   worker threads per tier replica, a continuous [`batcher`], the
//!   pluggable routing policy ([`crate::router::RoutingPolicy`]), and
//!   real model execution through [`crate::runtime`] (PJRT). Python is
//!   never on this path. Both paths are constructed from the same
//!   [`crate::sched::plan::CascadePlan`] artifact
//!   (`ServerConfig::from_plan` / `TcpFrontend::from_plan`).
//! * [`monitor`] — the re-scheduling mechanism (§4.4): subsample
//!   incoming workload statistics, detect shifts, trigger a new
//!   bi-level schedule. The [`crate::adapt`] subsystem wires it into a
//!   running server: its controller feeds the monitor from the
//!   server's admission tap and hot-swaps re-scheduled plans through
//!   [`server::ServeControl`].

pub mod batcher;
pub mod cascade_sim;
pub mod monitor;
pub mod net;
pub mod server;

pub use cascade_sim::{simulate_cascade, CascadeSimResult};
pub use monitor::{Monitor, MonitorConfig};
pub use net::TcpFrontend;
pub use server::{
    AdmissionObserver, CascadeServer, ExecMode, ServeControl, ServeTelemetry, ServerConfig,
    ServerStats, TierBackend, TierEngineStats, TierQueueStats, TraceEntry,
};
