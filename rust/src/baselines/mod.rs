//! Comparison systems for the end-to-end evaluation (§4.1).
//!
//! * [`standalone_plan`] — a single model served on the whole cluster,
//!   SGLang-style. Per the paper's protocol the baseline's parallelism
//!   IS tuned with the same MILP/strategy search (fair comparison);
//!   what it lacks is the cascade itself.
//! * [`cascade_serve_plan`] — a CascadeServe-like cascade system: it
//!   reacts to *system load* (arrival rate) but, per the limitations
//!   the paper attributes to it (§2), (i) ignores input/output length
//!   characteristics when picking parallelism (uses fixed default
//!   lengths), (ii) uses replication-only deployment (DP over the
//!   smallest feasible replica), and (iii) tunes routing independently
//!   of deployment (no co-optimization: allocation is proportional to
//!   tier load instead of the min-max MILP).

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterSpec;
use crate::engine::PreemptionMode;
use crate::judge::Judger;
use crate::models::ModelSpec;
use crate::parallel::{design_feasible, Strategy};
use crate::perf::Workload;
use crate::router::{monotone_chains, route_with, PolicySpec, ThresholdPolicy};
use crate::sched::inner::best_strategy_for;
use crate::sched::plan::{CascadePlan, TierPlan};
use crate::workload::Request;

/// Single-model deployment on the full cluster (stand-alone baseline).
/// Returns the plan; routing is degenerate (the model answers all
/// requests) and quality is the model's judged quality on the trace.
pub fn standalone_plan(
    model_idx: usize,
    cascade: &[ModelSpec],
    cluster: &ClusterSpec,
    judger: &Judger,
    requests: &[Request],
    n_gpus: usize,
) -> Result<CascadePlan> {
    if requests.is_empty() {
        bail!("empty trace");
    }
    let span = (requests.last().unwrap().arrival - requests[0].arrival).max(1e-9);
    let stats = crate::workload::estimate_stats(requests);
    let w = Workload {
        rate: requests.len() as f64 / span,
        avg_input: stats.avg_input,
        avg_output: stats.avg_output,
    };
    let model = &cascade[model_idx];
    let (strategy, p95) = best_strategy_for(model, cluster, n_gpus, &w, false)
        .with_context(|| format!("no feasible deployment of {} on {n_gpus} GPUs", model.name))?;

    let quality = requests
        .iter()
        .map(|r| judger.score(model, r, model_idx))
        .sum::<f64>()
        / requests.len() as f64;

    let tiers: Vec<TierPlan> = (0..cascade.len())
        .map(|i| {
            if i == model_idx {
                TierPlan {
                    model_name: cascade[i].name.to_string(),
                    gpus: n_gpus,
                    strategy: Some(strategy.clone()),
                    workload: w,
                    processing_ratio: 1.0,
                    predicted_p95: p95,
                    disagg: None,
                    speculation: None,
                }
            } else {
                TierPlan {
                    model_name: cascade[i].name.to_string(),
                    gpus: 0,
                    strategy: None,
                    workload: Workload { rate: 0.0, avg_input: 0.0, avg_output: 0.0 },
                    processing_ratio: 0.0,
                    predicted_p95: 0.0,
                    disagg: None,
                    speculation: None,
                }
            }
        })
        .collect();

    // Thresholds that route everything to `model_idx` and stop there:
    // force escalation below it, accept everything at it.
    let mut th = vec![0.0; cascade.len() - 1];
    for t in th.iter_mut().take(model_idx) {
        *t = 101.0;
    }
    Ok(CascadePlan {
        policy: PolicySpec::threshold(th)?,
        tiers,
        predicted_latency: p95,
        predicted_quality: quality,
        preemption: vec![PreemptionMode::Recompute; cascade.len()],
    })
}

/// CascadeServe-like baseline (see module docs for the modeled
/// limitations). `quality_requirement` drives its threshold grid search
/// exactly like Cascadia's, so the comparison isolates deployment
/// quality rather than routing-intent differences.
pub fn cascade_serve_plan(
    cascade: &[ModelSpec],
    cluster: &ClusterSpec,
    judger: &Judger,
    requests: &[Request],
    n_gpus: usize,
    quality_requirement: f64,
) -> Result<CascadePlan> {
    if requests.is_empty() {
        bail!("empty trace");
    }
    let c = cascade.len();
    let span = (requests.last().unwrap().arrival - requests[0].arrival).max(1e-9);

    // Fixed default lengths: CascadeServe is load-aware but not
    // length-aware (limitation ii).
    const DEFAULT_IN: f64 = 512.0;
    const DEFAULT_OUT: f64 = 256.0;

    let grid: Vec<f64> = (0..=10).map(|i| i as f64 * 10.0).collect();
    let mut best: Option<(f64, CascadePlan)> = None;

    // Monotone threshold chains, like Cascadia's sweep.
    for chain in monotone_chains(&grid, c - 1) {
        let policy = ThresholdPolicy::new(chain)?;
        let routing = route_with(cascade, judger, requests, &policy, span)?;
        if routing.quality < quality_requirement {
            continue;
        }

        // Load-proportional allocation (limitation iii: no min-max
        // co-optimization): GPUs ∝ rate_i × per-request compute cost,
        // respecting memory floors.
        let loads: Vec<f64> = (0..c)
            .map(|i| {
                routing.tier_workloads[i].rate
                    * cascade[i].flops_per_token()
                    * (DEFAULT_IN + DEFAULT_OUT)
            })
            .collect();
        let total_load: f64 = loads.iter().sum();
        if total_load <= 0.0 {
            continue;
        }
        let floors: Vec<usize> = (0..c)
            .map(|i| {
                if routing.tier_workloads[i].rate > 0.0 {
                    min_feasible_gpus(&cascade[i], cluster)
                } else {
                    0
                }
            })
            .collect();
        if floors.iter().sum::<usize>() > n_gpus {
            continue;
        }
        let mut alloc: Vec<usize> = (0..c)
            .map(|i| {
                if routing.tier_workloads[i].rate > 0.0 {
                    floors[i].max((n_gpus as f64 * loads[i] / total_load).round() as usize)
                } else {
                    0
                }
            })
            .collect();
        // Trim/pad to the budget, preferring to trim the least loaded.
        loop {
            let used: usize = alloc.iter().sum();
            if used == n_gpus {
                break;
            }
            if used > n_gpus {
                // Take from the tier with the most slack above its floor.
                let i = (0..c)
                    .filter(|&i| alloc[i] > floors[i])
                    .max_by(|&a, &b| {
                        (alloc[a] - floors[a]).cmp(&(alloc[b] - floors[b]))
                    });
                match i {
                    Some(i) => alloc[i] -= 1,
                    None => break,
                }
            } else {
                // Give to the most loaded tier.
                let i = (0..c)
                    .max_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
                    .unwrap();
                alloc[i] += 1;
            }
        }
        if alloc.iter().sum::<usize>() != n_gpus {
            continue;
        }

        // Replication-only deployment at default lengths (limitations
        // i+ii): DP over the minimal feasible replica.
        let mut tiers = Vec::with_capacity(c);
        let mut max_p95: f64 = 0.0;
        let mut feasible = true;
        for i in 0..c {
            let w_real = routing.tier_workloads[i];
            if w_real.rate <= 0.0 {
                tiers.push(TierPlan {
                    model_name: cascade[i].name.to_string(),
                    gpus: 0,
                    strategy: None,
                    workload: w_real,
                    processing_ratio: routing.processing_ratios[i],
                    predicted_p95: 0.0,
                    disagg: None,
                    speculation: None,
                });
                continue;
            }
            let unit = min_feasible_gpus(&cascade[i], cluster);
            let count = alloc[i] / unit;
            if count == 0 {
                feasible = false;
                break;
            }
            let strategy = Strategy::uniform(unit.min(cluster.gpus_per_server), unit.div_ceil(cluster.gpus_per_server).max(1), count);
            // Evaluate with the REAL workload (the simulator doesn't
            // lie even if CascadeServe's planner did).
            let avg_ctx = w_real.avg_input + w_real.avg_output / 2.0;
            let replicas: Vec<crate::perf::ReplicaModel> = strategy
                .groups
                .iter()
                .flat_map(|g| {
                    (0..g.count).map(|_| {
                        crate::perf::ReplicaModel::new(&cascade[i], cluster, g.tp, g.pp, avg_ctx)
                    })
                })
                .collect();
            let p95 = crate::sim::analytic::estimate_p95(&replicas, &w_real);
            max_p95 = max_p95.max(p95);
            tiers.push(TierPlan {
                model_name: cascade[i].name.to_string(),
                gpus: alloc[i],
                strategy: Some(strategy),
                workload: w_real,
                processing_ratio: routing.processing_ratios[i],
                predicted_p95: p95,
                disagg: None,
                speculation: None,
            });
        }
        if !feasible {
            continue;
        }
        let plan = CascadePlan {
            policy: PolicySpec::Threshold(policy),
            tiers,
            predicted_latency: max_p95,
            predicted_quality: routing.quality,
            preemption: vec![PreemptionMode::Recompute; c],
        };
        match &best {
            Some((bp, _)) if *bp <= max_p95 => {}
            _ => best = Some((max_p95, plan)),
        }
    }

    best.map(|(_, p)| p)
        .with_context(|| format!("CascadeServe found no plan meeting quality {quality_requirement}"))
}

/// Smallest tp*pp group that fits the model (TP-first, then PP).
fn min_feasible_gpus(model: &ModelSpec, cluster: &ClusterSpec) -> usize {
    for group in 1..=(cluster.gpus_per_server * 8) {
        // Try TP-only then TPxPP shapes of this size.
        for tp in [8usize, 4, 2, 1] {
            if group % tp != 0 {
                continue;
            }
            let pp = group / tp;
            if design_feasible(model, cluster, tp, pp) {
                return group;
            }
        }
    }
    usize::MAX
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;
    use crate::workload::{generate, paper_trace};

    fn setup() -> (Vec<ModelSpec>, ClusterSpec, Judger, Vec<Request>) {
        (
            deepseek_cascade(),
            ClusterSpec::paper_testbed(),
            Judger::new(1),
            generate(&paper_trace(2, 3.0), 800, 9),
        )
    }

    #[test]
    fn standalone_uses_full_cluster() {
        let (cascade, cluster, judger, reqs) = setup();
        let plan = standalone_plan(2, &cascade, &cluster, &judger, &reqs, 32).unwrap();
        assert_eq!(plan.total_gpus(), 32);
        assert_eq!(plan.deployed().count(), 1);
        assert!(plan.predicted_quality > 80.0); // 671B is strong
        // Routing sends everything to tier 2.
        assert_eq!(plan.policy.thresholds(), &[101.0, 101.0]);
    }

    #[test]
    fn standalone_small_model_is_fast_but_weak() {
        let (cascade, cluster, judger, reqs) = setup();
        let small = standalone_plan(0, &cascade, &cluster, &judger, &reqs, 32).unwrap();
        let big = standalone_plan(2, &cascade, &cluster, &judger, &reqs, 32).unwrap();
        assert!(small.predicted_latency < big.predicted_latency);
        assert!(small.predicted_quality < big.predicted_quality);
    }

    #[test]
    fn cascade_serve_meets_quality_and_budget() {
        let (cascade, cluster, judger, reqs) = setup();
        let plan =
            cascade_serve_plan(&cascade, &cluster, &judger, &reqs, 32, 75.0).unwrap();
        assert_eq!(plan.total_gpus(), 32);
        assert!(plan.predicted_quality >= 75.0);
        // Replication-only: every group has pp*tp equal to the minimal
        // feasible unit (no workload-tuned TP boosts).
        for t in plan.deployed() {
            let s = t.strategy.as_ref().unwrap();
            assert!(!s.groups.is_empty());
        }
    }

    #[test]
    fn cascade_serve_impossible_quality_errors() {
        let (cascade, cluster, judger, reqs) = setup();
        assert!(cascade_serve_plan(&cascade, &cluster, &judger, &reqs, 32, 100.0).is_err());
    }

    #[test]
    fn min_feasible_gpus_ordering() {
        let (cascade, cluster, _, _) = setup();
        let small = min_feasible_gpus(&cascade[0], &cluster);
        let mid = min_feasible_gpus(&cascade[1], &cluster);
        let big = min_feasible_gpus(&cascade[2], &cluster);
        assert_eq!(small, 1);
        assert!(mid > small);
        assert!(big > mid, "671B unit {big} vs 70B {mid}");
    }
}
