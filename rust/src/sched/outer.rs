//! Outer optimization (§3.3): weighted Tchebycheff sweep over a
//! routing policy's parameter space.
//!
//! For each candidate policy θ the trace is routed
//! ([`crate::router::route_with`]), the inner MILP produces the
//! deployment plan and its latency L(θ), and the judger supplies Q(θ).
//! The utopia point is z1* = L(all requests at the smallest tier) and
//! z2* = Q(all requests at the largest tier); sweeping (λ1, λ2) over a
//! log scale and minimizing T(θ) = max{λ1(L−z1*), λ2(z2*−Q)} yields a
//! well-spread set of Pareto-optimal cascade plans, from which
//! [`select_plan`] picks the cheapest plan meeting a quality
//! requirement.
//!
//! The sweep is generic over the policy family: [`OuterOptions`] names
//! a [`PolicyKind`] and the grids for each of its parameters
//! (thresholds for every family, plus length cutoffs / entry tiers for
//! the length-predictive policy and margins for the margin policy), so
//! new routing strategies are searchable without touching this module's
//! callers.

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterSpec;
use crate::judge::Judger;
use crate::models::ModelSpec;
use crate::router::{monotone_chains, route_with, PolicyKind, PolicySpec};
use crate::sched::inner::{InnerOptions, InnerSolver};
use crate::sched::plan::{CascadePlan, TierPlan};
use crate::workload::Request;

/// Options for the outer sweep.
#[derive(Debug, Clone)]
pub struct OuterOptions {
    /// Candidate threshold values per judger-score axis.
    pub threshold_grid: Vec<f64>,
    /// Which routing-policy family to sweep.
    pub policy_kind: PolicyKind,
    /// Prompt-length cutoffs tried by [`PolicyKind::Length`].
    pub length_cutoffs: Vec<f64>,
    /// Entry tiers tried by [`PolicyKind::Length`] for long requests.
    pub entry_tiers: Vec<usize>,
    /// Margins tried by [`PolicyKind::Margin`].
    pub margins: Vec<f64>,
    /// (λ1, λ2) weight pairs; default is a log sweep of λ1/λ2 from 0.1
    /// to 10 (§3.3).
    pub lambda_pairs: Vec<(f64, f64)>,
    pub inner: InnerOptions,
}

impl Default for OuterOptions {
    fn default() -> Self {
        let threshold_grid: Vec<f64> =
            (0..=10).map(|i| i as f64 * 10.0).collect();
        // log-spaced ratios 0.1 .. 10.
        let lambda_pairs: Vec<(f64, f64)> = (-4..=4)
            .map(|e| {
                let r = 10f64.powf(e as f64 / 4.0);
                (r / (1.0 + r), 1.0 / (1.0 + r))
            })
            .collect();
        OuterOptions {
            threshold_grid,
            policy_kind: PolicyKind::Threshold,
            length_cutoffs: vec![600.0, 1200.0],
            entry_tiers: vec![1],
            margins: vec![10.0, 25.0],
            lambda_pairs,
            inner: InnerOptions::default(),
        }
    }
}

/// One evaluated routing strategy with its deployment plan.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    pub plan: CascadePlan,
    /// Normalized latency (seconds).
    pub latency: f64,
    /// Judged quality (0-100).
    pub quality: f64,
}

/// All candidate evaluations from a sweep (Figure 13 raw points), plus
/// the Pareto-front subset.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub explored: Vec<ParetoPoint>,
    pub pareto: Vec<ParetoPoint>,
    pub utopia: (f64, f64),
}

/// Enumerate the candidate policies of the configured family over its
/// parameter grids.
pub fn policy_candidates(opts: &OuterOptions, n_tiers: usize) -> Result<Vec<PolicySpec>> {
    let chains = monotone_chains(&opts.threshold_grid, n_tiers.saturating_sub(1));
    let mut out = Vec::new();
    match opts.policy_kind {
        PolicyKind::Threshold => {
            for h in chains {
                out.push(PolicySpec::threshold(h)?);
            }
        }
        PolicyKind::Length => {
            for h in &chains {
                for &cutoff in &opts.length_cutoffs {
                    for &entry in opts.entry_tiers.iter().filter(|&&e| e > 0 && e < n_tiers) {
                        out.push(PolicySpec::length(h.clone(), cutoff, entry)?);
                    }
                }
            }
        }
        PolicyKind::Margin => {
            for h in &chains {
                for &margin in &opts.margins {
                    out.push(PolicySpec::margin(h.clone(), margin)?);
                }
            }
        }
    }
    if out.is_empty() {
        bail!(
            "no candidate policies for kind {:?} (check threshold_grid / family grids)",
            opts.policy_kind
        );
    }
    Ok(out)
}

fn evaluate_candidate(
    cascade: &[ModelSpec],
    solver: &InnerSolver,
    judger: &Judger,
    requests: &[Request],
    policy: &PolicySpec,
    n_gpus: usize,
    span: f64,
) -> Option<ParetoPoint> {
    let routing = route_with(cascade, judger, requests, policy, span).ok()?;
    let sol = solver.solve(&routing.tier_workloads, n_gpus).ok()?;
    let tiers: Vec<TierPlan> = (0..cascade.len())
        .map(|i| TierPlan {
            model_name: cascade[i].name.to_string(),
            gpus: sol.gpus[i],
            strategy: sol.strategies[i].clone(),
            workload: routing.tier_workloads[i],
            processing_ratio: routing.processing_ratios[i],
            predicted_p95: sol.tier_p95[i],
            disagg: sol.disagg[i],
            speculation: sol.speculation[i],
        })
        .collect();
    let plan = CascadePlan {
        policy: policy.clone(),
        tiers,
        predicted_latency: sol.max_latency,
        predicted_quality: routing.quality,
        preemption: sol.preemption,
    };
    Some(ParetoPoint { latency: sol.max_latency, quality: routing.quality, plan })
}

/// Extract the non-dominated subset (min latency, max quality).
pub fn pareto_front(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut front: Vec<ParetoPoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.latency < p.latency - 1e-12 && q.quality >= p.quality)
                || (q.latency <= p.latency && q.quality > p.quality + 1e-12)
        });
        if !dominated {
            front.push(p.clone());
        }
    }
    // Sort by latency for presentation; dedupe identical (L, Q).
    front.sort_by(|a, b| a.latency.partial_cmp(&b.latency).unwrap());
    front.dedup_by(|a, b| {
        (a.latency - b.latency).abs() < 1e-12 && (a.quality - b.quality).abs() < 1e-12
    });
    front
}

/// Run the full outer sweep: evaluate the policy family's parameter
/// grid, compute the utopia point, and return explored points + Pareto
/// front.
pub fn optimize(
    cascade: &[ModelSpec],
    cluster: &ClusterSpec,
    judger: &Judger,
    requests: &[Request],
    n_gpus: usize,
    opts: &OuterOptions,
) -> Result<SweepResult> {
    if requests.is_empty() {
        bail!("empty request trace");
    }
    let c = cascade.len();
    let span = requests.last().unwrap().arrival - requests[0].arrival;
    let span = if span > 0.0 { span } else { 1.0 };
    let solver = InnerSolver::new(cascade.to_vec(), cluster.clone(), opts.inner.clone());

    // Utopia point: z1* from the all-to-smallest routing, z2* from
    // all-to-largest — threshold extremes regardless of the swept
    // family, so every sweep shares the same anchors.
    let all_small = evaluate_candidate(
        cascade, &solver, judger, requests,
        &PolicySpec::uniform_threshold(c - 1, 0.0)?, n_gpus, span,
    );
    let all_large = evaluate_candidate(
        cascade, &solver, judger, requests,
        &PolicySpec::uniform_threshold(c - 1, 101.0)?, n_gpus, span,
    );
    let z1 = all_small.as_ref().map(|p| p.latency).unwrap_or(0.0);
    let z2 = all_large.as_ref().map(|p| p.quality).unwrap_or(100.0);

    let mut explored = Vec::new();
    if let Some(p) = all_small {
        explored.push(p);
    }
    if let Some(p) = all_large {
        explored.push(p);
    }
    for policy in policy_candidates(opts, c)? {
        if let Some(p) = evaluate_candidate(
            cascade, &solver, judger, requests, &policy, n_gpus, span,
        ) {
            explored.push(p);
        }
    }

    let pareto = pareto_front(&explored);
    Ok(SweepResult { explored, pareto, utopia: (z1, z2) })
}

/// Tchebycheff scalarization: T(θ) = max{λ1 (L − z1*), λ2 (z2* − Q)}.
pub fn tchebycheff(latency: f64, quality: f64, utopia: (f64, f64), l: (f64, f64)) -> f64 {
    (l.0 * (latency - utopia.0)).max(l.1 * (utopia.1 - quality))
}

/// The Tchebycheff winners across the λ sweep (a well-spread subset of
/// the Pareto front; Figure 6).
pub fn tchebycheff_winners(sweep: &SweepResult, opts: &OuterOptions) -> Vec<ParetoPoint> {
    let mut out: Vec<ParetoPoint> = Vec::new();
    for &lpair in &opts.lambda_pairs {
        let best = sweep
            .explored
            .iter()
            .min_by(|a, b| {
                tchebycheff(a.latency, a.quality, sweep.utopia, lpair)
                    .partial_cmp(&tchebycheff(b.latency, b.quality, sweep.utopia, lpair))
                    .unwrap()
            });
        if let Some(p) = best {
            if !out.iter().any(|q| {
                (q.latency - p.latency).abs() < 1e-12 && (q.quality - p.quality).abs() < 1e-12
            }) {
                out.push(p.clone());
            }
        }
    }
    out
}

/// The §4.4 re-scheduling path: re-run the full bi-level sweep on a
/// monitor window (the recent live sample) and pick the cheapest plan
/// meeting the quality requirement. This is what the adaptation
/// controller runs in its background re-schedule thread; it is just
/// `optimize` + `select_plan` with window-shaped error reporting.
pub fn reschedule(
    cascade: &[ModelSpec],
    cluster: &ClusterSpec,
    judger: &Judger,
    window: &[Request],
    n_gpus: usize,
    opts: &OuterOptions,
    quality_requirement: f64,
) -> Result<CascadePlan> {
    let sweep = optimize(cascade, cluster, judger, window, n_gpus, opts)
        .with_context(|| format!("re-scheduling on a {}-request window", window.len()))?;
    select_plan(&sweep, quality_requirement).with_context(|| {
        format!("no re-scheduled plan meets quality {quality_requirement} on the recent window")
    })
}

/// Pick the lowest-latency plan meeting `quality_requirement`.
pub fn select_plan(sweep: &SweepResult, quality_requirement: f64) -> Option<CascadePlan> {
    sweep
        .pareto
        .iter()
        .filter(|p| p.quality >= quality_requirement)
        .min_by(|a, b| a.latency.partial_cmp(&b.latency).unwrap())
        .map(|p| p.plan.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;
    use crate::workload::{generate, paper_trace};

    fn sweep_with(kind: PolicyKind, rate: f64, n: usize) -> (SweepResult, OuterOptions) {
        let cascade = deepseek_cascade();
        let cluster = ClusterSpec::paper_testbed();
        let judger = Judger::new(1);
        let reqs = generate(&paper_trace(2, rate), n, 5);
        // Small grid for test speed.
        let opts = OuterOptions {
            threshold_grid: vec![0.0, 30.0, 60.0, 90.0],
            policy_kind: kind,
            ..Default::default()
        };
        let s = optimize(&cascade, &cluster, &judger, &reqs, 32, &opts).unwrap();
        (s, opts)
    }

    fn sweep(rate: f64, n: usize) -> (SweepResult, OuterOptions) {
        sweep_with(PolicyKind::Threshold, rate, n)
    }

    #[test]
    fn explores_monotone_grid_and_finds_front() {
        let (s, _) = sweep(4.0, 400);
        assert!(s.explored.len() >= 10, "{}", s.explored.len());
        assert!(!s.pareto.is_empty());
        // Front must be mutually non-dominated.
        for a in &s.pareto {
            for b in &s.pareto {
                let dominates = a.latency < b.latency - 1e-12 && a.quality >= b.quality + 1e-12;
                assert!(!dominates, "front contains dominated point");
            }
        }
    }

    #[test]
    fn utopia_bounds_the_front() {
        let (s, _) = sweep(4.0, 400);
        let (z1, z2) = s.utopia;
        for p in &s.pareto {
            assert!(p.latency >= z1 - 1e-9, "latency {} < utopia {z1}", p.latency);
            // z2* (all-to-largest, the paper's definition) is not a
            // strict bound under a noisy judger: threshold acceptance
            // selects on favorable score draws (a request kept at tier
            // 2 with score 95 counts 95, where the top tier might have
            // drawn 90), so mixed routings can edge past it by up to a
            // success-mode std or so.
            assert!(
                p.quality <= z2 + crate::judge::SUCCESS_STD,
                "quality {} >> utopia {z2}",
                p.quality
            );
        }
    }

    #[test]
    fn front_trades_latency_for_quality() {
        let (s, _) = sweep(4.0, 400);
        if s.pareto.len() >= 2 {
            let first = &s.pareto[0];
            let last = &s.pareto[s.pareto.len() - 1];
            assert!(last.latency >= first.latency);
            assert!(last.quality >= first.quality);
        }
    }

    #[test]
    fn tchebycheff_winners_lie_on_front() {
        let (s, opts) = sweep(4.0, 400);
        let winners = tchebycheff_winners(&s, &opts);
        assert!(!winners.is_empty());
        for w in &winners {
            let on_front = s.pareto.iter().any(|p| {
                (p.latency - w.latency).abs() < 1e-9 && (p.quality - w.quality).abs() < 1e-9
            });
            assert!(on_front, "winner not on Pareto front");
        }
    }

    #[test]
    fn select_plan_meets_quality() {
        let (s, _) = sweep(4.0, 400);
        let max_q = s.pareto.iter().map(|p| p.quality).fold(0.0, f64::max);
        let req = max_q - 5.0;
        let plan = select_plan(&s, req).expect("some plan meets the bar");
        assert!(plan.predicted_quality >= req);
        // And it's the cheapest such plan on the front.
        for p in &s.pareto {
            if p.quality >= req {
                assert!(plan.predicted_latency <= p.latency + 1e-9);
            }
        }
    }

    #[test]
    fn reschedule_on_window_meets_quality() {
        let cascade = deepseek_cascade();
        let cluster = ClusterSpec::paper_testbed();
        let judger = Judger::new(1);
        // A monitor-window-sized sample of the hard trace.
        let window = generate(&paper_trace(1, 8.0), 100, 21);
        let opts = OuterOptions {
            threshold_grid: vec![0.0, 30.0, 60.0, 90.0],
            ..Default::default()
        };
        let plan = reschedule(&cascade, &cluster, &judger, &window, 32, &opts, 75.0).unwrap();
        assert!(plan.predicted_quality >= 75.0);
        assert_eq!(plan.tiers.len(), cascade.len());
        // An unreachable bar errors instead of silently degrading.
        assert!(reschedule(&cascade, &cluster, &judger, &window, 32, &opts, 100.1).is_err());
    }

    #[test]
    fn impossible_quality_returns_none() {
        let (s, _) = sweep(4.0, 400);
        assert!(select_plan(&s, 100.1).is_none());
    }

    #[test]
    fn candidate_enumeration_covers_all_families() {
        let opts = OuterOptions {
            threshold_grid: vec![0.0, 50.0, 100.0],
            ..Default::default()
        };
        let th = policy_candidates(&opts, 3).unwrap();
        assert_eq!(th.len(), 6); // monotone pairs over a 3-value grid
        let len_opts = OuterOptions { policy_kind: PolicyKind::Length, ..opts.clone() };
        let le = policy_candidates(&len_opts, 3).unwrap();
        // chains x cutoffs x entry tiers
        assert_eq!(le.len(), 6 * len_opts.length_cutoffs.len() * len_opts.entry_tiers.len());
        let mar_opts = OuterOptions { policy_kind: PolicyKind::Margin, ..opts.clone() };
        let ma = policy_candidates(&mar_opts, 3).unwrap();
        assert_eq!(ma.len(), 6 * mar_opts.margins.len());
        assert!(th.iter().all(|p| p.kind() == PolicyKind::Threshold));
        assert!(le.iter().all(|p| p.kind() == PolicyKind::Length));
        assert!(ma.iter().all(|p| p.kind() == PolicyKind::Margin));
    }

    #[test]
    fn alternate_families_sweep_end_to_end() {
        for kind in [PolicyKind::Length, PolicyKind::Margin] {
            let (s, _) = sweep_with(kind, 4.0, 300);
            assert!(!s.pareto.is_empty(), "{kind:?} produced an empty front");
            // Swept candidates carry the requested family (the two
            // threshold utopia anchors are also in `explored`).
            assert!(
                s.explored.iter().any(|p| p.plan.policy.kind() == kind),
                "{kind:?} sweep explored no {kind:?} policies"
            );
        }
    }

    #[test]
    fn scalarization_example_from_paper() {
        // §3.3 worked example: utopia (10ms, 0.95), λ = (0.6, 0.4).
        let utopia = (0.010, 0.95);
        let t1 = tchebycheff(0.012, 0.90, utopia, (0.6, 0.4));
        let t2 = tchebycheff(0.011, 0.92, utopia, (0.6, 0.4));
        assert!((t1 - 1.2e-3).abs() < 1e-9 || (t1 - 0.02).abs() < 1e-9 || t1 > 0.0);
        // The paper's numbers use ms: 0.6*(12-10)=1.2 vs 0.4*0.05=0.02.
        let t1_ms = tchebycheff(12.0, 0.90, (10.0, 0.95), (0.6, 0.4));
        let t2_ms = tchebycheff(11.0, 0.92, (10.0, 0.95), (0.6, 0.4));
        assert!((t1_ms - 1.2).abs() < 1e-9);
        assert!((t2_ms - 0.6).abs() < 1e-9);
        assert!(t2_ms < t1_ms);
        let _ = (t1, t2);
    }
}
