//! The bi-level scheduling algorithm — Cascadia's core contribution
//! (§3).
//!
//! * [`inner`] — given a routing strategy (per-tier workloads), find
//!   the GPU allocation and parallelism strategy per tier minimizing
//!   the maximum per-tier p95 latency, via MILP over precomputed
//!   `l_i(f)` tables (with an exact DP cross-check).
//! * [`outer`] — weighted Tchebycheff sweep over a routing policy's
//!   parameter space ([`crate::router::RoutingPolicy`] families):
//!   enumerate candidate policies, call the inner level for each,
//!   scalarize (latency, quality) against the utopia point, and emit
//!   the Pareto front; [`outer::select_plan`] then picks the plan for a
//!   quality requirement.
//! * [`plan`] — the `CascadePlan` artifact handed to the coordinator;
//!   it carries the chosen policy and round-trips through JSON so
//!   `cascadia schedule` output feeds `cascadia serve` directly.

pub mod inner;
pub mod outer;
pub mod plan;

pub use inner::{solve_inner, InnerOptions, InnerSolution};
pub use outer::{optimize, policy_candidates, select_plan, OuterOptions, ParetoPoint, SweepResult};
pub use plan::{CascadePlan, TierPlan};
