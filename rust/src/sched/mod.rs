//! The bi-level scheduling algorithm — Cascadia's core contribution
//! (§3).
//!
//! * [`inner`] — given a routing strategy (per-tier workloads), find
//!   the GPU allocation and parallelism strategy per tier minimizing
//!   the maximum per-tier p95 latency, via MILP over precomputed
//!   `l_i(f)` tables (with an exact DP cross-check).
//! * [`outer`] — weighted Tchebycheff sweep over routing thresholds:
//!   evaluate candidate thresholds, call the inner level for each,
//!   scalarize (latency, quality) against the utopia point, and emit
//!   the Pareto front; [`outer::select_plan`] then picks the plan for a
//!   quality requirement.
//! * [`plan`] — the `CascadePlan` artifact handed to the coordinator.

pub mod inner;
pub mod outer;
pub mod plan;

pub use inner::{solve_inner, InnerOptions, InnerSolution};
pub use outer::{optimize, select_plan, OuterOptions, ParetoPoint};
pub use plan::{CascadePlan, TierPlan};
