//! Inner optimization (§3.2): MILP-based GPU allocation + parallelism
//! strategy search.
//!
//! Given the per-tier workloads `w_i` implied by a routing strategy,
//! this level:
//!
//! 1. precomputes `l_i(f) = S(w_i, f)` for every tier i and GPU count
//!    f ∈ {1..N} — where `S` enumerates all feasible parallelism
//!    strategies ([`crate::parallel`]) and scores them with the
//!    analytic simulator ([`crate::sim::analytic`]), keeping the best;
//! 2. solves the assignment MILP: binaries `x_{i,f}` (exactly one f per
//!    tier), budget `Σ f·x_{i,f} = N`, objective `min L` with
//!    `L ≥ Σ_f l_i(f)·x_{i,f}`; infeasible (memory-floor) pairs are
//!    excluded, matching the paper's explicit `x_{i,f} = 0` fixing.
//!
//! Feasibility is page-granular: a candidate design whose KV budget
//! cannot hold even one full-length request
//! ([`ReplicaModel::fits_context`], the same page math the execution
//! engine's [`crate::engine::KvPool`] enforces at runtime) scores
//! `OVERLOAD_LATENCY` in the analytic simulator and is excluded from
//! the tables, so the scheduler never deploys a tier the engine could
//! only serve by force-expanding its pool.
//!
//! Tiers with zero routed traffic are not deployed (f = 0) — the
//! tier-subset behaviour of Table 1's (80,3)/(70,3) rows. An exact
//! dynamic program over the same `l_i(f)` tables cross-checks the MILP
//! (property-tested equal); `InnerOptions::use_milp` selects which one
//! answers.
//!
//! Results are memoized on a quantized workload key so the outer
//! Tchebycheff sweep (hundreds of routing candidates) stays fast.

// BTreeMap, not HashMap: the inner solver's memo caches sit on the
// deterministic scheduling path (same inputs must yield the same plan),
// so keyed structures iterate in a stable order (`determinism` lint).
use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::cluster::ClusterSpec;
use crate::engine::PreemptionMode;
use crate::milp::simplex::Sense;
use crate::milp::{MilpProblem, Rel};
use crate::models::ModelSpec;
use crate::parallel::{enumerate_strategies, Strategy};
use crate::perf::{ReplicaModel, Workload, DEFAULT_PAGE_TOKENS, DEFAULT_PREFILL_CHUNK};
use crate::sched::plan::{DisaggSpec, SpecSpec};
use crate::sim::analytic::{
    estimate_p95_disagg, estimate_p95_groups_engine, EngineSemantics, SpecSem, OVERLOAD_LATENCY,
};

/// Options for the inner solver.
#[derive(Debug, Clone)]
pub struct InnerOptions {
    /// Solve the assignment with the MILP (paper §3.2); otherwise use
    /// the exact DP (same optimum; used for cross-checks and speed).
    pub use_milp: bool,
    /// Ablation (Figure 11 i): force the uniform strategy — TP within a
    /// server, DP across servers — instead of searching.
    pub uniform_parallelism: bool,
    /// Ablation (Figure 11 ii): force equal GPU split across deployed
    /// tiers instead of optimizing the allocation.
    pub uniform_allocation: bool,
    /// Prompt tokens requests share as a common prefix (system
    /// prompts): the feasibility screen credits the shared pages the
    /// execution engine's prefix trie holds once (0 = no sharing).
    pub shared_prefix_tokens: f64,
    /// Prefill chunk budget the runtime engine interleaves at; the
    /// estimate charges the matching chunk-limited TTFT. The default
    /// is the engine's `DEFAULT_PREFILL_CHUNK` (the scheduler models
    /// the runtime it deploys), which adds one interleaved decode
    /// iteration per extra chunk for prompts longer than the budget —
    /// set `f64::INFINITY` (or <= 0) to reproduce the pre-chunking
    /// estimate exactly.
    pub prefill_chunk: f64,
    /// Preemption discipline to model in the analytic estimates:
    /// `None` keeps the legacy estimate (no eviction-overhead term,
    /// the pre-swap behaviour); `Some(mode)` adds the saturation-gated
    /// overhead term, with `Swap` charging the cheaper of the PCIe
    /// round trip and recompute per victim — the same per-victim
    /// choice the runtime scheduler makes, so the MILP/Pareto layer
    /// sees the recompute/swap tradeoff per design point.
    pub preemption: Option<PreemptionMode>,
    /// Assumed per-position draft/verify acceptance rate for
    /// cross-tier speculative decoding. `None` keeps the legacy
    /// estimates bit-identical (no speculation considered);
    /// `Some(alpha)` lets the post-allocation refinement try draft
    /// depths on each deep tier, drafting with the nearest deployed
    /// shallower tier, and adopt a depth only where the speculative
    /// estimate ([`crate::sim::analytic::spec_decode_cost`]) beats the
    /// plain one.
    pub speculation: Option<f64>,
}

impl Default for InnerOptions {
    fn default() -> Self {
        InnerOptions {
            use_milp: true,
            uniform_parallelism: false,
            uniform_allocation: false,
            shared_prefix_tokens: 0.0,
            prefill_chunk: DEFAULT_PREFILL_CHUNK as f64,
            preemption: None,
            speculation: None,
        }
    }
}

impl InnerOptions {
    /// The engine semantics the analytic estimates should model.
    pub fn engine_semantics(&self) -> EngineSemantics {
        EngineSemantics {
            shared_prefix_tokens: self.shared_prefix_tokens.max(0.0),
            prefill_chunk: if self.prefill_chunk > 0.0 {
                self.prefill_chunk
            } else {
                f64::INFINITY
            },
            preemption: self.preemption,
        }
    }
}

/// Whether swap-to-host beats recompute for a mean-`ctx_tokens` victim
/// on this replica design: the PCIe round trip of the victim's pages
/// is cheaper than re-prefilling the context, and the host actually
/// has swap space. This is the per-design-point policy choice the
/// scheduler bakes into the plan ([`InnerSolution::preemption`]); the
/// runtime makes the same comparison per victim at eviction time.
pub fn swap_beats_recompute(rm: &ReplicaModel, ctx_tokens: f64) -> bool {
    if rm.swap_pages_total(DEFAULT_PAGE_TOKENS) == 0 {
        return false;
    }
    rm.swap_round_trip_seconds(ctx_tokens, DEFAULT_PAGE_TOKENS)
        < rm.prefill_latency(ctx_tokens)
}

/// Inner-level result.
#[derive(Debug, Clone)]
pub struct InnerSolution {
    /// GPUs per tier (f_i; 0 = not deployed).
    pub gpus: Vec<usize>,
    /// Chosen strategy per tier (None iff f_i = 0).
    pub strategies: Vec<Option<Strategy>>,
    /// Predicted p95 per tier (0 for undeployed tiers).
    pub tier_p95: Vec<f64>,
    /// max_i tier_p95 — the MILP objective L.
    pub max_latency: f64,
    /// Branch-and-bound nodes (0 when the DP answered).
    pub milp_nodes: usize,
    /// Per-tier eviction discipline: swap-to-host where that tier's
    /// per-victim PCIe round trip undercuts its recompute cost
    /// ([`swap_beats_recompute`], judged with the tier's own replica
    /// design), recompute otherwise (and for undeployed tiers).
    /// Indexed like `gpus`; flows into
    /// [`crate::sched::plan::CascadePlan::preemption`].
    pub preemption: Vec<PreemptionMode>,
    /// Per-tier prefill/decode split (`None` = unified pool). A tier
    /// whose chosen strategy is a single homogeneous replica group of
    /// two or more replicas is re-scored at every split point with
    /// [`estimate_p95_disagg`] — which charges the one-way KV-page
    /// migration of each handoff over the modeled interconnect — and
    /// the split is adopted only where it beats the unified estimate;
    /// `tier_p95` and `max_latency` reflect the refined values.
    pub disagg: Vec<Option<DisaggSpec>>,
    /// Per-tier cross-tier speculation (`None` = plain decode). Only
    /// populated when [`InnerOptions::speculation`] supplies an
    /// assumed acceptance rate: each deployed tier `i >= 1` with a
    /// deployed shallower tier re-scores its chosen design at draft
    /// depths k in {2, 4, 8} — charging the shallow tier's per-token
    /// draft cost — and adopts the best depth only where it beats the
    /// plain estimate. Never set on tier 0 or on a tier running a
    /// prefill/decode split (draft state does not survive the KV
    /// handoff; the server rejects the combination). `tier_p95` and
    /// `max_latency` reflect the refined values.
    pub speculation: Vec<Option<SpecSpec>>,
}

/// Best parallelism strategy and its p95 for (model, budget, workload)
/// under default engine semantics (no shared prefix, whole-prompt
/// prefill) — see [`best_strategy_for_engine`].
pub fn best_strategy_for(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    budget: usize,
    w: &Workload,
    uniform: bool,
) -> Option<(Strategy, f64)> {
    best_strategy_for_engine(model, cluster, budget, w, uniform, &EngineSemantics::default())
}

/// Best parallelism strategy and its p95 for (model, budget, workload),
/// scored under the given execution-engine semantics.
pub fn best_strategy_for_engine(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    budget: usize,
    w: &Workload,
    uniform: bool,
    sem: &EngineSemantics,
) -> Option<(Strategy, f64)> {
    if budget == 0 {
        return None;
    }
    let avg_ctx = w.avg_input + w.avg_output / 2.0;
    // One ReplicaModel per distinct (tp, pp) design — the enumeration
    // visits thousands of strategies built from tens of designs
    // (EXPERIMENTS.md §Perf).
    let mut design_cache: BTreeMap<(usize, usize), ReplicaModel> = BTreeMap::new();
    let mut score = |s: &Strategy| -> f64 {
        for g in &s.groups {
            design_cache
                .entry((g.tp, g.pp))
                .or_insert_with(|| ReplicaModel::new(model, cluster, g.tp, g.pp, avg_ctx));
        }
        let groups: Vec<(&ReplicaModel, usize)> = s
            .groups
            .iter()
            .map(|g| (&design_cache[&(g.tp, g.pp)], g.count))
            .collect();
        crate::sim::analytic::estimate_p95_groups_engine(&groups, w, sem)
    };

    if uniform {
        // TP within a server, DP across: replica = TP over
        // min(budget, gpus_per_server) (largest feasible power of two),
        // replicated over the remaining GPUs.
        let mut tp = cluster.gpus_per_server.min(budget);
        while tp > 1 && (!tp.is_power_of_two()
            || !crate::parallel::design_feasible(model, cluster, tp, 1))
        {
            tp -= 1;
        }
        if !crate::parallel::design_feasible(model, cluster, tp, 1) {
            return None;
        }
        let count = (budget / tp).max(1);
        let s = Strategy::uniform(tp, 1, count);
        if s.gpus() > budget {
            return None;
        }
        let p = score(&s);
        return Some((s, p));
    }

    let mut best: Option<(Strategy, f64)> = None;
    for s in enumerate_strategies(model, cluster, budget) {
        let p = score(&s);
        match &best {
            Some((_, bp)) if *bp <= p => {}
            _ => best = Some((s, p)),
        }
    }
    best
}

/// Latency table: l[tier][f] for f in 0..=n_gpus (index 0 unused for
/// deployed tiers), plus the strategy that achieved each entry.
#[derive(Debug, Clone)]
pub struct LatencyTable {
    pub l: Vec<Vec<f64>>,
    pub strategies: Vec<Vec<Option<Strategy>>>,
}

/// The inner solver with its memo cache. One instance is reused across
/// an entire outer-level sweep.
pub struct InnerSolver {
    pub cascade: Vec<ModelSpec>,
    pub cluster: ClusterSpec,
    pub opts: InnerOptions,
    /// (tier, quantized workload, n_gpus) -> full l_i(f) curve.
    #[allow(clippy::type_complexity)]
    curve_cache: Mutex<BTreeMap<(usize, u64, usize), (Vec<f64>, Vec<Option<Strategy>>)>>,
}

/// Quantize a workload for memoization: 2% rate buckets, 5% length
/// buckets (log-scaled). The simulator's own tolerance dwarfs this.
fn quantize(w: &Workload) -> u64 {
    let q = |x: f64, step: f64| -> u64 {
        if x <= 0.0 {
            0
        } else {
            ((x.ln() / step).round() as i64).unsigned_abs()
        }
    };
    q(w.rate, 0.02) ^ (q(w.avg_input, 0.05) << 21) ^ (q(w.avg_output, 0.05) << 42)
}

impl InnerSolver {
    pub fn new(cascade: Vec<ModelSpec>, cluster: ClusterSpec, opts: InnerOptions) -> InnerSolver {
        InnerSolver { cascade, cluster, opts, curve_cache: Mutex::new(BTreeMap::new()) }
    }

    /// The full `l_i(f)` curve for one tier: enumerate strategies ONCE
    /// at the full budget, score each, then take the running min over
    /// `f >= gpus(s)` — a strategy's latency does not depend on the
    /// budget it sits inside, so per-f re-enumeration is pure waste
    /// (32x saving; EXPERIMENTS.md §Perf).
    fn curve(&self, tier: usize, w: &Workload, n_gpus: usize) -> (Vec<f64>, Vec<Option<Strategy>>) {
        let key = (tier, quantize(w), n_gpus);
        if let Some(hit) = self.curve_cache.lock().unwrap().get(&key) {
            return hit.clone();
        }
        let model = &self.cascade[tier];
        let mut l = vec![OVERLOAD_LATENCY; n_gpus + 1];
        let mut strategies: Vec<Option<Strategy>> = vec![None; n_gpus + 1];

        let sem = self.opts.engine_semantics();
        if self.opts.uniform_parallelism {
            // The ablation's uniform strategy depends on f directly.
            for f in 1..=n_gpus {
                if let Some((s, p)) =
                    best_strategy_for_engine(model, &self.cluster, f, w, true, &sem)
                {
                    l[f] = p;
                    strategies[f] = Some(s);
                }
            }
        } else {
            let avg_ctx = w.avg_input + w.avg_output / 2.0;
            let mut design_cache: BTreeMap<(usize, usize), ReplicaModel> = BTreeMap::new();
            for s in enumerate_strategies(model, &self.cluster, n_gpus) {
                for g in &s.groups {
                    design_cache.entry((g.tp, g.pp)).or_insert_with(|| {
                        ReplicaModel::new(model, &self.cluster, g.tp, g.pp, avg_ctx)
                    });
                }
                let groups: Vec<(&ReplicaModel, usize)> = s
                    .groups
                    .iter()
                    .map(|g| (&design_cache[&(g.tp, g.pp)], g.count))
                    .collect();
                let p = crate::sim::analytic::estimate_p95_groups_engine(&groups, w, &sem);
                let f = s.gpus();
                if f <= n_gpus && p < l[f] {
                    l[f] = p;
                    strategies[f] = Some(s);
                }
            }
            // Running min: a budget f may be served best by a strategy
            // using fewer GPUs.
            for f in 2..=n_gpus {
                if l[f - 1] < l[f] {
                    l[f] = l[f - 1];
                    strategies[f] = strategies[f - 1].clone();
                }
            }
        }
        let out = (l, strategies);
        self.curve_cache.lock().unwrap().insert(key, out.clone());
        out
    }

    /// Precompute l_i(f) for all tiers and budgets.
    pub fn tables(&self, tier_workloads: &[Workload], n_gpus: usize) -> LatencyTable {
        let c = self.cascade.len();
        let mut l = vec![vec![OVERLOAD_LATENCY; n_gpus + 1]; c];
        let mut strategies = vec![vec![None; n_gpus + 1]; c];
        for (i, w) in tier_workloads.iter().enumerate() {
            if w.rate <= 0.0 {
                continue; // undeployed tier: no table needed
            }
            let (li, si) = self.curve(i, w, n_gpus);
            l[i] = li;
            strategies[i] = si;
        }
        LatencyTable { l, strategies }
    }

    /// Solve the inner problem for the given per-tier workloads.
    pub fn solve(&self, tier_workloads: &[Workload], n_gpus: usize) -> Result<InnerSolution> {
        let c = self.cascade.len();
        assert_eq!(tier_workloads.len(), c);
        let active: Vec<usize> =
            (0..c).filter(|&i| tier_workloads[i].rate > 0.0).collect();
        if active.is_empty() {
            bail!("no tier has traffic");
        }

        let table = self.tables(tier_workloads, n_gpus);

        // Warm start: the exact DP optimum (provably equal to the MILP
        // optimum on this family) primes branch-and-bound pruning; the
        // MILP still runs and certifies optimality, ~1000x faster
        // (EXPERIMENTS.md §Perf).
        let dp_bound: Option<f64> = if self.opts.use_milp && !self.opts.uniform_allocation {
            solve_dp(&table, &active, n_gpus, c).ok().map(|alloc| {
                active
                    .iter()
                    .map(|&i| table.l[i][alloc[i]])
                    .fold(0.0f64, f64::max)
            })
        } else {
            None
        };

        let alloc: Vec<usize> = if self.opts.uniform_allocation {
            // Ablation: equal split over active tiers (remainder to the
            // largest tier, mimicking "uniform resource allocation").
            let share = n_gpus / active.len();
            let mut a = vec![0usize; c];
            for &i in &active {
                a[i] = share;
            }
            let used: usize = a.iter().sum();
            if let Some(&last) = active.last() {
                a[last] += n_gpus - used;
            }
            a
        } else if self.opts.use_milp {
            self.solve_milp(&table, &active, n_gpus, dp_bound)?
        } else {
            solve_dp(&table, &active, n_gpus, self.cascade.len())?
        };

        let mut strategies = vec![None; c];
        let mut tier_p95 = vec![0.0; c];
        for &i in &active {
            let f = alloc[i];
            if f == 0 || table.l[i][f] >= OVERLOAD_LATENCY {
                bail!(
                    "tier {} ({}) has traffic but no feasible allocation (f={})",
                    i,
                    self.cascade[i].name,
                    f
                );
            }
            strategies[i] = table.strategies[i][f].clone();
            tier_p95[i] = table.l[i][f];
        }

        // Per-tier preemption choice: each deployed tier judges swap
        // vs recompute with its own replica design at its own mean
        // context (deep-tier re-serves carry the longest contexts,
        // which is exactly where the PCIe round trip undercuts
        // re-prefilling); undeployed tiers default to recompute.
        let preemption: Vec<PreemptionMode> = (0..c)
            .map(|i| {
                let Some(s) = &strategies[i] else { return PreemptionMode::Recompute };
                let Some(g) = s.groups.first() else { return PreemptionMode::Recompute };
                let w = &tier_workloads[i];
                let ctx = w.avg_input + w.avg_output;
                let rm = ReplicaModel::new(&self.cascade[i], &self.cluster, g.tp, g.pp, ctx);
                if swap_beats_recompute(&rm, ctx) {
                    PreemptionMode::Swap
                } else {
                    PreemptionMode::Recompute
                }
            })
            .collect();

        // Prefill/decode split refinement: for each deployed tier whose
        // strategy is one homogeneous group of >= 2 replicas, enumerate
        // every split of the group into dedicated prefill and decode
        // pools and re-score it with the disaggregated estimate, which
        // charges each handoff's one-way KV-page migration over the
        // modeled interconnect. Adopt the best split only where it
        // beats the unified pool — long-prompt tiers shed prefill
        // head-of-line blocking, short-prompt tiers stay unified.
        let mut disagg: Vec<Option<DisaggSpec>> = vec![None; c];
        let sem = self.opts.engine_semantics();
        for &i in &active {
            let Some(s) = &strategies[i] else { continue };
            if s.groups.len() != 1 {
                continue;
            }
            let g = &s.groups[0];
            if g.count < 2 {
                continue;
            }
            let w = &tier_workloads[i];
            let avg_ctx = w.avg_input + w.avg_output / 2.0;
            let rm = ReplicaModel::new(&self.cascade[i], &self.cluster, g.tp, g.pp, avg_ctx);
            let mut best = tier_p95[i];
            for p in 1..g.count {
                let est = estimate_p95_disagg(&rm, p, g.count - p, w, &sem);
                if est < best {
                    best = est;
                    disagg[i] =
                        Some(DisaggSpec { prefill_replicas: p, decode_replicas: g.count - p });
                }
            }
            tier_p95[i] = best;
        }

        // Cross-tier speculation refinement: with an assumed
        // acceptance rate, each deployed tier i >= 1 re-scores its
        // chosen design with the speculative decode term
        // ([`crate::sim::analytic::spec_decode_cost`]) at draft depths
        // k in {2, 4, 8}, drafting with the nearest deployed shallower
        // tier's replica design, and adopts the best depth only where
        // it beats the tier's current estimate. Split tiers stay
        // plain: draft state does not survive the prefill->decode KV
        // handoff, and the server rejects the combination.
        let mut speculation: Vec<Option<SpecSpec>> = vec![None; c];
        if let Some(alpha) = self.opts.speculation {
            let alpha = alpha.clamp(0.0, 1.0);
            for &i in &active {
                if i == 0 || disagg[i].is_some() {
                    continue;
                }
                let Some(s) = &strategies[i] else { continue };
                let Some(j) = (0..i).rev().find(|&j| strategies[j].is_some()) else {
                    continue;
                };
                let w = &tier_workloads[i];
                let avg_ctx = w.avg_input + w.avg_output / 2.0;
                let Some(dg) = strategies[j].as_ref().and_then(|ds| ds.groups.first()) else {
                    continue;
                };
                let draft_rm =
                    ReplicaModel::new(&self.cascade[j], &self.cluster, dg.tp, dg.pp, avg_ctx);
                let draft_s = draft_rm.decode_iteration(1);
                let rms: Vec<ReplicaModel> = s
                    .groups
                    .iter()
                    .map(|g| ReplicaModel::new(&self.cascade[i], &self.cluster, g.tp, g.pp, avg_ctx))
                    .collect();
                let groups: Vec<(&ReplicaModel, usize)> =
                    rms.iter().zip(&s.groups).map(|(rm, g)| (rm, g.count)).collect();
                let mut best = tier_p95[i];
                for k in [2usize, 4, 8] {
                    let mut sem_s = sem;
                    sem_s.speculation =
                        Some(SpecSem { draft_k: k, acceptance: alpha, draft_s_per_token: draft_s });
                    let est = estimate_p95_groups_engine(&groups, w, &sem_s);
                    if est < best {
                        best = est;
                        speculation[i] = Some(SpecSpec { draft_k: k, acceptance: alpha });
                    }
                }
                tier_p95[i] = best;
            }
        }
        let max_latency = active.iter().map(|&i| tier_p95[i]).fold(0.0f64, f64::max);

        Ok(InnerSolution {
            gpus: alloc,
            strategies,
            tier_p95,
            max_latency,
            milp_nodes: 0,
            preemption,
            disagg,
            speculation,
        })
    }

    /// §3.2 MILP: variables x_{i,f} (binary, for active tiers and
    /// feasible f) and L (continuous, last variable).
    fn solve_milp(
        &self,
        table: &LatencyTable,
        active: &[usize],
        n_gpus: usize,
        warm_bound: Option<f64>,
    ) -> Result<Vec<usize>> {
        // Variable layout: for each active tier, one binary per feasible
        // f; then L.
        let mut var_of: Vec<Vec<(usize, usize)>> = Vec::new(); // per active tier: (var, f)
        let mut n_vars = 0usize;
        for &i in active {
            let mut vars = Vec::new();
            for f in 1..=n_gpus {
                if table.l[i][f] < OVERLOAD_LATENCY {
                    vars.push((n_vars, f));
                    n_vars += 1;
                }
            }
            if vars.is_empty() {
                bail!("tier {i} has no feasible GPU allocation");
            }
            var_of.push(vars);
        }
        let l_var = n_vars;
        n_vars += 1;

        let mut obj = vec![0.0; n_vars];
        obj[l_var] = 1.0;
        let mut p = MilpProblem::new(n_vars, obj, Sense::Minimize);
        p.initial_upper_bound = warm_bound;

        // (i) exactly one f per tier.
        for vars in &var_of {
            let mut row = vec![0.0; n_vars];
            for &(v, _) in vars {
                row[v] = 1.0;
            }
            p.constrain(row, Rel::Eq, 1.0);
        }
        // (ii) GPU budget: sum f x_{i,f} = N.
        let mut row = vec![0.0; n_vars];
        for vars in &var_of {
            for &(v, f) in vars {
                row[v] = f as f64;
            }
        }
        p.constrain(row, Rel::Eq, n_gpus as f64);
        // (iii) L >= sum_f l_i(f) x_{i,f}.
        for (ai, &i) in active.iter().enumerate() {
            let mut row = vec![0.0; n_vars];
            for &(v, f) in &var_of[ai] {
                row[v] = table.l[i][f];
            }
            row[l_var] = -1.0;
            p.constrain(row, Rel::Le, 0.0);
        }
        for vars in &var_of {
            for &(v, _) in vars {
                p.set_binary(v);
            }
        }

        let sol = p
            .solve()
            .map_err(|e| anyhow::anyhow!("inner MILP failed: {e}"))?;
        let mut alloc = vec![0usize; self.cascade.len()];
        for (ai, &i) in active.iter().enumerate() {
            for &(v, f) in &var_of[ai] {
                if sol.x[v] > 0.5 {
                    alloc[i] = f;
                }
            }
        }
        Ok(alloc)
    }
}

/// Exact DP over the same tables: dp[t][g] = min over f of
/// max(l_t(f), dp[t-1][g-f]), budget consumed exactly.
pub fn solve_dp(
    table: &LatencyTable,
    active: &[usize],
    n_gpus: usize,
    n_tiers: usize,
) -> Result<Vec<usize>> {
    let t = active.len();
    const INF: f64 = f64::INFINITY;
    // dp[g] after processing k tiers; choice[k][g] = f chosen.
    let mut dp = vec![INF; n_gpus + 1];
    dp[0] = 0.0;
    let mut choice = vec![vec![0usize; n_gpus + 1]; t];
    for (k, &i) in active.iter().enumerate() {
        let mut next = vec![INF; n_gpus + 1];
        for g in 0..=n_gpus {
            if dp[g].is_infinite() {
                continue;
            }
            for f in 1..=(n_gpus - g) {
                let li = table.l[i][f];
                if li >= OVERLOAD_LATENCY {
                    continue;
                }
                let v = dp[g].max(li);
                if v < next[g + f] {
                    next[g + f] = v;
                    choice[k][g + f] = f;
                }
            }
        }
        dp = next;
    }
    if dp[n_gpus].is_infinite() {
        bail!("DP: no feasible allocation for budget {n_gpus}");
    }
    // Backtrack.
    let mut alloc = vec![0usize; n_tiers];
    let mut g = n_gpus;
    for k in (0..t).rev() {
        let f = choice[k][g];
        alloc[active[k]] = f;
        g -= f;
    }
    Ok(alloc)
}

/// Convenience one-shot API.
pub fn solve_inner(
    cascade: &[ModelSpec],
    cluster: &ClusterSpec,
    tier_workloads: &[Workload],
    n_gpus: usize,
    opts: &InnerOptions,
) -> Result<InnerSolution> {
    InnerSolver::new(cascade.to_vec(), cluster.clone(), opts.clone())
        .solve(tier_workloads, n_gpus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;

    fn cluster() -> ClusterSpec {
        ClusterSpec::paper_testbed()
    }

    fn workloads(rates: [f64; 3]) -> Vec<Workload> {
        rates
            .iter()
            .map(|&r| Workload { rate: r, avg_input: 512.0, avg_output: 256.0 })
            .collect()
    }

    #[test]
    fn allocation_sums_to_budget() {
        let sol = solve_inner(
            &deepseek_cascade(),
            &cluster(),
            &workloads([6.0, 2.0, 0.5]),
            32,
            &InnerOptions::default(),
        )
        .unwrap();
        assert_eq!(sol.gpus.iter().sum::<usize>(), 32);
        for (f, s) in sol.gpus.iter().zip(&sol.strategies) {
            assert_eq!(*f > 0, s.is_some());
            if let Some(s) = s {
                assert!(s.gpus() <= *f);
            }
        }
        assert!(sol.max_latency < 100.0, "latency {}", sol.max_latency);
    }

    #[test]
    fn zero_rate_tier_is_undeployed() {
        let sol = solve_inner(
            &deepseek_cascade(),
            &cluster(),
            &workloads([6.0, 2.0, 0.0]),
            32,
            &InnerOptions::default(),
        )
        .unwrap();
        assert_eq!(sol.gpus[2], 0);
        assert!(sol.strategies[2].is_none());
        assert_eq!(sol.gpus.iter().sum::<usize>(), 32);
    }

    #[test]
    fn milp_matches_dp() {
        let cascade = deepseek_cascade();
        let c = cluster();
        for rates in [[6.0, 2.0, 0.5], [3.0, 3.0, 1.0], [10.0, 1.0, 0.2]] {
            let milp = solve_inner(&cascade, &c, &workloads(rates), 32,
                &InnerOptions { use_milp: true, ..Default::default() }).unwrap();
            let dp = solve_inner(&cascade, &c, &workloads(rates), 32,
                &InnerOptions { use_milp: false, ..Default::default() }).unwrap();
            assert!(
                (milp.max_latency - dp.max_latency).abs() < 1e-6,
                "rates {rates:?}: milp {} dp {}",
                milp.max_latency,
                dp.max_latency
            );
        }
    }

    #[test]
    fn more_loaded_tier_gets_more_gpus() {
        // Same model in all tiers isolates the load effect.
        let m = deepseek_cascade()[1].clone();
        let cascade = vec![m.clone(), m.clone(), m];
        let sol = solve_inner(
            &cascade,
            &cluster(),
            &workloads([4.0, 2.0, 0.5]),
            32,
            &InnerOptions::default(),
        )
        .unwrap();
        assert!(sol.gpus[0] >= sol.gpus[1], "{:?}", sol.gpus);
        assert!(sol.gpus[1] >= sol.gpus[2], "{:?}", sol.gpus);
    }

    #[test]
    fn uniform_allocation_is_worse_or_equal() {
        let cascade = deepseek_cascade();
        let opt = solve_inner(&cascade, &cluster(), &workloads([6.0, 2.0, 0.5]), 32,
            &InnerOptions::default()).unwrap();
        let uni = solve_inner(&cascade, &cluster(), &workloads([6.0, 2.0, 0.5]), 32,
            &InnerOptions { uniform_allocation: true, ..Default::default() }).unwrap();
        assert!(opt.max_latency <= uni.max_latency + 1e-9);
    }

    #[test]
    fn uniform_parallelism_is_worse_or_equal() {
        let cascade = deepseek_cascade();
        let opt = solve_inner(&cascade, &cluster(), &workloads([6.0, 2.0, 0.5]), 32,
            &InnerOptions::default()).unwrap();
        let uni = solve_inner(&cascade, &cluster(), &workloads([6.0, 2.0, 0.5]), 32,
            &InnerOptions { uniform_parallelism: true, ..Default::default() }).unwrap();
        assert!(opt.max_latency <= uni.max_latency + 1e-9);
    }

    #[test]
    fn oversized_context_is_infeasible_page_granularly() {
        // A workload whose mean context can never fit a replica's KV
        // budget must be rejected outright — the request-count clamp
        // alone would have rounded the fractional budget up to one
        // "slot" and deployed it anyway.
        let huge: Vec<Workload> = [1.0, 0.5, 0.1]
            .iter()
            .map(|&r| Workload { rate: r, avg_input: 5e8, avg_output: 5e8 })
            .collect();
        let err = solve_inner(
            &deepseek_cascade(),
            &cluster(),
            &huge,
            32,
            &InnerOptions::default(),
        );
        assert!(err.is_err(), "page-infeasible workloads must not schedule");
    }

    #[test]
    fn infeasible_budget_errors() {
        // 2 GPUs cannot hold the 671B tier if it has traffic.
        let err = solve_inner(
            &deepseek_cascade(),
            &cluster(),
            &workloads([1.0, 0.0, 0.5]),
            2,
            &InnerOptions::default(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn per_design_point_preemption_tracks_the_cost_terms() {
        // On the H100 testbed the PCIe round trip undercuts re-prefill
        // at paper-trace context lengths, so every deployed tier's
        // entry carries the swap knob...
        let sol = solve_inner(
            &deepseek_cascade(),
            &cluster(),
            &workloads([6.0, 2.0, 0.5]),
            32,
            &InnerOptions::default(),
        )
        .unwrap();
        assert_eq!(sol.preemption.len(), sol.gpus.len());
        for (i, &f) in sol.gpus.iter().enumerate() {
            if f > 0 {
                assert_eq!(sol.preemption[i], PreemptionMode::Swap, "tier {i}");
            } else {
                assert_eq!(sol.preemption[i], PreemptionMode::Recompute, "tier {i}");
            }
        }
        // ...and the choice helper itself flips with the terms: a
        // replica with swap space prefers swap at long contexts, and a
        // zero host budget forces recompute.
        let m = &deepseek_cascade()[0];
        let rm = ReplicaModel::new(m, &cluster(), 1, 1, 2048.0);
        assert!(swap_beats_recompute(&rm, 2048.0));
        let mut no_host = cluster();
        no_host.host_swap_bytes_per_gpu = 0.0;
        let rm0 = ReplicaModel::new(m, &no_host, 1, 1, 2048.0);
        assert!(!swap_beats_recompute(&rm0, 2048.0), "no host space, no swap");
    }

    #[test]
    fn preemption_aware_scoring_never_prefers_recompute_to_swap() {
        // With the overhead term enabled, Swap mode charges the
        // cheaper per-victim cost, so its estimate is <= Recompute's
        // on every feasible design.
        let cascade = deepseek_cascade();
        let c = cluster();
        let w = workloads([6.0, 2.0, 0.5]);
        let swap = solve_inner(&cascade, &c, &w, 32,
            &InnerOptions { preemption: Some(PreemptionMode::Swap), ..Default::default() })
            .unwrap();
        let rec = solve_inner(&cascade, &c, &w, 32,
            &InnerOptions { preemption: Some(PreemptionMode::Recompute), ..Default::default() })
            .unwrap();
        assert!(
            swap.max_latency <= rec.max_latency + 1e-9,
            "swap-aware scoring must not lose: {} vs {}",
            swap.max_latency,
            rec.max_latency
        );
        // And the legacy estimate (no term) is reproduced bit-for-bit
        // by the default options.
        let legacy = solve_inner(&cascade, &c, &w, 32, &InnerOptions::default()).unwrap();
        let explicit_none = solve_inner(&cascade, &c, &w, 32,
            &InnerOptions { preemption: None, ..Default::default() })
            .unwrap();
        assert_eq!(legacy.max_latency, explicit_none.max_latency);
    }

    #[test]
    fn memoization_returns_identical_results() {
        let solver = InnerSolver::new(deepseek_cascade(), cluster(), InnerOptions::default());
        let w = workloads([6.0, 2.0, 0.5]);
        let a = solver.solve(&w, 32).unwrap();
        let b = solver.solve(&w, 32).unwrap();
        assert_eq!(a.gpus, b.gpus);
        assert_eq!(a.max_latency, b.max_latency);
        assert_eq!(a.preemption, b.preemption);
        assert_eq!(a.disagg, b.disagg);
        assert_eq!(a.speculation, b.speculation);
    }

    #[test]
    fn disagg_refinement_adopts_splits_only_where_they_win() {
        // Cross-check the solution against the raw latency tables: a
        // tier carrying a split must (a) cover its whole replica group,
        // (b) score exactly what the disaggregated estimate says, and
        // (c) beat the unified table value it replaced; a unified tier
        // must keep its table value untouched.
        let solver = InnerSolver::new(deepseek_cascade(), cluster(), InnerOptions::default());
        let w = workloads([6.0, 2.0, 0.5]);
        let sol = solver.solve(&w, 32).unwrap();
        assert_eq!(sol.disagg.len(), sol.gpus.len());
        let table = solver.tables(&w, 32);
        let sem = solver.opts.engine_semantics();
        for i in 0..sol.gpus.len() {
            if sol.gpus[i] == 0 {
                assert!(sol.disagg[i].is_none(), "undeployed tier {i} split");
                continue;
            }
            let unified = table.l[i][sol.gpus[i]];
            match &sol.disagg[i] {
                Some(d) => {
                    let s = sol.strategies[i].as_ref().unwrap();
                    assert_eq!(s.groups.len(), 1, "splits need a homogeneous pool");
                    let g = &s.groups[0];
                    assert_eq!(d.total(), g.count, "split must cover the pool");
                    assert!(d.prefill_replicas >= 1 && d.decode_replicas >= 1);
                    let avg_ctx = w[i].avg_input + w[i].avg_output / 2.0;
                    let rm = ReplicaModel::new(
                        &solver.cascade[i],
                        &solver.cluster,
                        g.tp,
                        g.pp,
                        avg_ctx,
                    );
                    let est = estimate_p95_disagg(
                        &rm,
                        d.prefill_replicas,
                        d.decode_replicas,
                        &w[i],
                        &sem,
                    );
                    assert!(
                        (est - sol.tier_p95[i]).abs() < 1e-9,
                        "tier {i}: refined p95 {} != estimate {est}",
                        sol.tier_p95[i]
                    );
                    assert!(est < unified, "tier {i}: split {est} must beat unified {unified}");
                }
                None => assert_eq!(sol.tier_p95[i], unified, "tier {i} altered without a split"),
            }
        }
        let refined_max = sol.tier_p95.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            (sol.max_latency - refined_max).abs() < 1e-12,
            "objective must track refined tier p95s"
        );
    }

    #[test]
    fn speculation_refinement_adopts_depth_only_where_it_wins() {
        // Default options never speculate — legacy estimates stay
        // bit-identical.
        let w = workloads([6.0, 2.0, 0.5]);
        let plain = InnerSolver::new(deepseek_cascade(), cluster(), InnerOptions::default())
            .solve(&w, 32)
            .unwrap();
        assert!(plain.speculation.iter().all(|s| s.is_none()));

        // With an assumed acceptance rate, cross-check every tier
        // against a re-derived estimate: a speculating tier must score
        // exactly what the speculative estimate says at its adopted
        // depth and beat its plain p95; a plain tier must have had no
        // winning depth.
        let opts = InnerOptions { speculation: Some(0.9), ..Default::default() };
        let solver = InnerSolver::new(deepseek_cascade(), cluster(), opts);
        let sol = solver.solve(&w, 32).unwrap();
        assert_eq!(sol.speculation.len(), sol.gpus.len());
        assert!(sol.speculation[0].is_none(), "tier 0 has no shallower tier to draft with");
        let sem = solver.opts.engine_semantics();
        for i in 1..sol.gpus.len() {
            if sol.gpus[i] == 0 || sol.disagg[i].is_some() {
                assert!(sol.speculation[i].is_none(), "tier {i} speculates where it must not");
                continue;
            }
            let Some(j) = (0..i).rev().find(|&j| sol.strategies[j].is_some()) else {
                assert!(sol.speculation[i].is_none());
                continue;
            };
            let avg_ctx = w[i].avg_input + w[i].avg_output / 2.0;
            let dg = sol.strategies[j].as_ref().unwrap().groups.first().unwrap();
            let draft_rm =
                ReplicaModel::new(&solver.cascade[j], &solver.cluster, dg.tp, dg.pp, avg_ctx);
            let draft_s = draft_rm.decode_iteration(1);
            let s = sol.strategies[i].as_ref().unwrap();
            let rms: Vec<ReplicaModel> = s
                .groups
                .iter()
                .map(|g| ReplicaModel::new(&solver.cascade[i], &solver.cluster, g.tp, g.pp, avg_ctx))
                .collect();
            let groups: Vec<(&ReplicaModel, usize)> =
                rms.iter().zip(&s.groups).map(|(rm, g)| (rm, g.count)).collect();
            let plain_p95 = plain.tier_p95[i];
            let mut best = plain_p95;
            let mut best_k = None;
            for k in [2usize, 4, 8] {
                let mut sem_s = sem;
                sem_s.speculation =
                    Some(SpecSem { draft_k: k, acceptance: 0.9, draft_s_per_token: draft_s });
                let est = estimate_p95_groups_engine(&groups, &w[i], &sem_s);
                if est < best {
                    best = est;
                    best_k = Some(k);
                }
            }
            match (best_k, sol.speculation[i]) {
                (Some(k), Some(sp)) => {
                    assert_eq!(sp.draft_k, k, "tier {i} adopted the wrong depth");
                    assert!((sp.acceptance - 0.9).abs() < 1e-12);
                    assert!(
                        (sol.tier_p95[i] - best).abs() < 1e-9,
                        "tier {i}: refined p95 {} != estimate {best}",
                        sol.tier_p95[i]
                    );
                    assert!(best < plain_p95, "tier {i}: adoption must win");
                }
                (None, None) => {
                    assert_eq!(sol.tier_p95[i], plain_p95, "tier {i} altered without a win");
                }
                (a, b) => panic!("tier {i}: expected depth {a:?}, plan has {b:?}"),
            }
        }
        let refined_max = sol.tier_p95.iter().cloned().fold(0.0f64, f64::max);
        assert!((sol.max_latency - refined_max).abs() < 1e-12);
        assert!(
            sol.max_latency <= plain.max_latency + 1e-12,
            "speculation can only help the objective"
        );
    }
}
