//! The cascade plan: the scheduler's output artifact, consumed by the
//! serving coordinator and printed by the case-study benches
//! (Tables 1-2).

use crate::parallel::Strategy;
use crate::perf::Workload;
use crate::router::Thresholds;
use crate::util::json::Json;

/// Deployment decision for one model tier.
#[derive(Debug, Clone)]
pub struct TierPlan {
    pub model_name: String,
    /// GPUs allocated (f_i); 0 means the tier is not deployed.
    pub gpus: usize,
    /// Parallelism strategy; `None` iff gpus == 0.
    pub strategy: Option<Strategy>,
    /// Workload this tier is expected to see.
    pub workload: Workload,
    /// Fraction of all requests this tier processes (p_i).
    pub processing_ratio: f64,
    /// Predicted p95 latency of this tier (seconds).
    pub predicted_p95: f64,
}

/// The full cascade plan (§3.1's "cascade plan").
#[derive(Debug, Clone)]
pub struct CascadePlan {
    pub thresholds: Thresholds,
    pub tiers: Vec<TierPlan>,
    /// max_i predicted p95 — the inner objective L(θ).
    pub predicted_latency: f64,
    /// Judged quality Q(θ).
    pub predicted_quality: f64,
}

impl CascadePlan {
    /// Total GPUs used.
    pub fn total_gpus(&self) -> usize {
        self.tiers.iter().map(|t| t.gpus).sum()
    }

    /// Tiers that are actually deployed.
    pub fn deployed(&self) -> impl Iterator<Item = &TierPlan> {
        self.tiers.iter().filter(|t| t.gpus > 0)
    }

    /// Render as JSON for configs/results.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "thresholds",
                Json::arr(self.thresholds.0.iter().map(|&h| Json::num(h)).collect()),
            ),
            ("predicted_latency", Json::num(self.predicted_latency)),
            ("predicted_quality", Json::num(self.predicted_quality)),
            (
                "tiers",
                Json::arr(
                    self.tiers
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("model", Json::str(t.model_name.clone())),
                                ("gpus", Json::num(t.gpus as f64)),
                                (
                                    "strategy",
                                    t.strategy
                                        .as_ref()
                                        .map(|s| Json::str(s.label()))
                                        .unwrap_or(Json::Null),
                                ),
                                ("processing_ratio", Json::num(t.processing_ratio)),
                                ("rate", Json::num(t.workload.rate)),
                                ("avg_input", Json::num(t.workload.avg_input)),
                                ("avg_output", Json::num(t.workload.avg_output)),
                                ("predicted_p95", Json::num(t.predicted_p95)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// One-line summary for logs, in the paper's notation.
    pub fn summary(&self) -> String {
        let h = self
            .thresholds
            .0
            .iter()
            .map(|h| format!("{h:.0}"))
            .collect::<Vec<_>>()
            .join(",");
        let tiers = self
            .tiers
            .iter()
            .map(|t| {
                let s = t
                    .strategy
                    .as_ref()
                    .map(|s| s.label())
                    .unwrap_or_else(|| "-".to_string());
                format!("{}: f={} {} p={:.0}%", t.model_name, t.gpus, s, t.processing_ratio * 100.0)
            })
            .collect::<Vec<_>>()
            .join(" | ");
        format!(
            "H=({h}) L={:.2}s Q={:.1} :: {tiers}",
            self.predicted_latency, self.predicted_quality
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::Strategy;

    fn sample() -> CascadePlan {
        CascadePlan {
            thresholds: Thresholds(vec![70.0, 50.0]),
            tiers: vec![
                TierPlan {
                    model_name: "small".into(),
                    gpus: 4,
                    strategy: Some(Strategy::uniform(1, 1, 4)),
                    workload: Workload { rate: 4.0, avg_input: 500.0, avg_output: 250.0 },
                    processing_ratio: 1.0,
                    predicted_p95: 2.0,
                },
                TierPlan {
                    model_name: "large".into(),
                    gpus: 0,
                    strategy: None,
                    workload: Workload { rate: 0.0, avg_input: 0.0, avg_output: 0.0 },
                    processing_ratio: 0.0,
                    predicted_p95: 0.0,
                },
            ],
            predicted_latency: 2.0,
            predicted_quality: 75.0,
        }
    }

    #[test]
    fn totals_and_deployed() {
        let p = sample();
        assert_eq!(p.total_gpus(), 4);
        assert_eq!(p.deployed().count(), 1);
    }

    #[test]
    fn json_roundtrip_parses() {
        let p = sample();
        let j = p.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.req("predicted_quality").unwrap().as_f64().unwrap(), 75.0);
        let tiers = parsed.req("tiers").unwrap().as_arr().unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].req("strategy").unwrap().as_str().unwrap(), "(DP=4)");
        assert_eq!(tiers[1].req("strategy").unwrap(), &Json::Null);
    }

    #[test]
    fn summary_contains_key_fields() {
        let s = sample().summary();
        assert!(s.contains("H=(70,50)"), "{s}");
        assert!(s.contains("f=4"), "{s}");
        assert!(s.contains("Q=75.0"), "{s}");
    }
}
