//! The cascade plan: the scheduler's output artifact, consumed by the
//! serving coordinator and printed by the case-study benches
//! (Tables 1-2).
//!
//! A plan is the *single* deployment artifact of the system: it carries
//! the routing policy ([`crate::router::PolicySpec`]) alongside the
//! per-tier GPU allocation, parallelism strategy and workload, and it
//! round-trips through JSON so `cascadia schedule` output can be fed
//! directly to `cascadia serve` (see `ServerConfig::from_plan` /
//! `TcpFrontend::from_plan`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::engine::PreemptionMode;
use crate::parallel::Strategy;
use crate::perf::Workload;
use crate::router::{PolicySpec, RoutingPolicy};
use crate::util::json::Json;

/// Prefill/decode role split of one tier's replica pool. Absent on a
/// `TierPlan` means today's unified pool: every replica serves both
/// phases. Present, the tier runs `prefill_replicas` workers that
/// execute chunked prefill only and hand finished sequences — their
/// private KV pages migrating over the modeled interconnect — to one
/// of `decode_replicas` decode-only workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DisaggSpec {
    pub prefill_replicas: usize,
    pub decode_replicas: usize,
}

impl DisaggSpec {
    pub fn total(&self) -> usize {
        self.prefill_replicas + self.decode_replicas
    }
}

/// Cross-tier speculative decoding for one tier: its engines draft
/// `draft_k` tokens per steady decoder on the tier *below* and verify
/// them in one step (lossless — every emitted token is the tier's own
/// model's choice). `acceptance` is the per-position agreement rate
/// the scheduler assumed when it scored the design; the runtime only
/// needs `draft_k`. Absent on a `TierPlan` means plain decode — legacy
/// plans parse unchanged. Never present on tier 0 (there is no
/// shallower tier to draft with).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecSpec {
    /// Tokens drafted per verify step.
    pub draft_k: usize,
    /// Modeled per-position draft/verify agreement rate in [0, 1].
    pub acceptance: f64,
}

/// Deployment decision for one model tier.
#[derive(Debug, Clone)]
pub struct TierPlan {
    pub model_name: String,
    /// GPUs allocated (f_i); 0 means the tier is not deployed.
    pub gpus: usize,
    /// Parallelism strategy; `None` iff gpus == 0.
    pub strategy: Option<Strategy>,
    /// Workload this tier is expected to see.
    pub workload: Workload,
    /// Fraction of all requests this tier processes (p_i).
    pub processing_ratio: f64,
    /// Predicted p95 latency of this tier (seconds).
    pub predicted_p95: f64,
    /// Optional prefill/decode role split of the tier's replica pool
    /// (`None` = unified, the only mode plans knew before the split
    /// dimension existed — legacy plans parse unchanged).
    pub disagg: Option<DisaggSpec>,
    /// Optional cross-tier speculative decoding (`None` = plain
    /// decode; legacy plans parse unchanged).
    pub speculation: Option<SpecSpec>,
}

/// The full cascade plan (§3.1's "cascade plan").
#[derive(Debug, Clone)]
pub struct CascadePlan {
    /// The routing strategy this deployment was co-optimized with.
    pub policy: PolicySpec,
    pub tiers: Vec<TierPlan>,
    /// max_i predicted p95 — the inner objective L(θ).
    pub predicted_latency: f64,
    /// Judged quality Q(θ).
    pub predicted_quality: f64,
    /// Per-tier eviction discipline the deployed engines should run
    /// (the scheduler picks it per tier from the recompute-vs-swap
    /// cost terms; `ServerConfig::from_plan_with_engine` derives the
    /// matching swap budget and PCIe rates from the plan's own
    /// parallelism, so schedule→serve round-trips the whole policy).
    /// Indexed like `tiers`; an empty or short vector defaults the
    /// missing tiers to [`PreemptionMode::Recompute`] (see
    /// [`CascadePlan::preemption_for`]), so plan literals that never
    /// touch the knob can leave it `Vec::new()`.
    pub preemption: Vec<PreemptionMode>,
}

fn preemption_mode_name(mode: PreemptionMode) -> &'static str {
    match mode {
        PreemptionMode::Recompute => "recompute",
        PreemptionMode::Swap => "swap",
    }
}

fn preemption_mode_from_str(s: &str) -> Result<PreemptionMode> {
    match s {
        "recompute" => Ok(PreemptionMode::Recompute),
        "swap" => Ok(PreemptionMode::Swap),
        other => anyhow::bail!("unknown preemption mode '{other}'"),
    }
}

impl CascadePlan {
    /// Total GPUs used.
    pub fn total_gpus(&self) -> usize {
        self.tiers.iter().map(|t| t.gpus).sum()
    }

    /// Tiers that are actually deployed.
    pub fn deployed(&self) -> impl Iterator<Item = &TierPlan> {
        self.tiers.iter().filter(|t| t.gpus > 0)
    }

    /// Eviction discipline of tier `i`. The vector may be shorter than
    /// `tiers` (plan literals predating the per-tier knob leave it
    /// empty); missing entries are [`PreemptionMode::Recompute`].
    pub fn preemption_for(&self, i: usize) -> PreemptionMode {
        self.preemption.get(i).copied().unwrap_or(PreemptionMode::Recompute)
    }

    /// Whether any deployed tier runs a prefill/decode split.
    pub fn has_disagg(&self) -> bool {
        self.tiers.iter().any(|t| t.gpus > 0 && t.disagg.is_some())
    }

    /// Speculation config of tier `i` (`None` = plain decode; always
    /// `None` for tier 0 and out-of-range indexes).
    pub fn speculation_for(&self, i: usize) -> Option<SpecSpec> {
        self.tiers.get(i).and_then(|t| t.speculation)
    }

    /// Whether any deployed tier runs speculative decoding.
    pub fn has_speculation(&self) -> bool {
        self.tiers.iter().any(|t| t.gpus > 0 && t.speculation.is_some())
    }

    /// Render as JSON for configs/results; parse back with
    /// [`CascadePlan::from_json`].
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", self.policy.to_json()),
            ("predicted_latency", Json::num(self.predicted_latency)),
            ("predicted_quality", Json::num(self.predicted_quality)),
            (
                "preemption",
                Json::arr(
                    (0..self.tiers.len())
                        .map(|i| Json::str(preemption_mode_name(self.preemption_for(i)).to_string()))
                        .collect(),
                ),
            ),
            (
                "tiers",
                Json::arr(
                    self.tiers
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("model", Json::str(t.model_name.clone())),
                                ("gpus", Json::num(t.gpus as f64)),
                                (
                                    "strategy",
                                    t.strategy
                                        .as_ref()
                                        .map(|s| s.to_json())
                                        .unwrap_or(Json::Null),
                                ),
                                ("processing_ratio", Json::num(t.processing_ratio)),
                                ("rate", Json::num(t.workload.rate)),
                                ("avg_input", Json::num(t.workload.avg_input)),
                                ("avg_output", Json::num(t.workload.avg_output)),
                                ("predicted_p95", Json::num(t.predicted_p95)),
                                (
                                    "disagg",
                                    match &t.disagg {
                                        None => Json::Null,
                                        Some(d) => Json::obj(vec![
                                            (
                                                "prefill_replicas",
                                                Json::num(d.prefill_replicas as f64),
                                            ),
                                            (
                                                "decode_replicas",
                                                Json::num(d.decode_replicas as f64),
                                            ),
                                        ]),
                                    },
                                ),
                                (
                                    "speculation",
                                    match &t.speculation {
                                        None => Json::Null,
                                        Some(s) => Json::obj(vec![
                                            ("draft_k", Json::num(s.draft_k as f64)),
                                            ("acceptance", Json::num(s.acceptance)),
                                        ]),
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a plan back from its [`CascadePlan::to_json`] form.
    pub fn from_json(j: &Json) -> Result<CascadePlan> {
        let policy = PolicySpec::from_json(j.req("policy")?).context("plan policy")?;
        let tiers = j
            .req("tiers")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let strategy = match t.req("strategy")? {
                    Json::Null => None,
                    s => Some(Strategy::from_json(s)?),
                };
                let gpus = t.req("gpus")?.as_usize()?;
                if (gpus == 0 && strategy.is_some()) || (gpus > 0 && strategy.is_none()) {
                    anyhow::bail!("tier {i}: gpus={gpus} inconsistent with strategy presence");
                }
                // Optional for backward compatibility: plans captured
                // before the split dimension existed are unified.
                let disagg = match t.get("disagg") {
                    None | Some(Json::Null) => None,
                    Some(d) => {
                        let prefill = d.req("prefill_replicas")?.as_usize()?;
                        let decode = d.req("decode_replicas")?.as_usize()?;
                        if prefill == 0 || decode == 0 {
                            anyhow::bail!(
                                "tier {i}: disagg split needs at least one replica per role \
                                 (got prefill={prefill} decode={decode})"
                            );
                        }
                        Some(DisaggSpec { prefill_replicas: prefill, decode_replicas: decode })
                    }
                };
                if disagg.is_some() && gpus == 0 {
                    anyhow::bail!("tier {i}: disagg split on an undeployed tier");
                }
                // Optional for backward compatibility: plans captured
                // before speculation existed decode plainly.
                let speculation = match t.get("speculation") {
                    None | Some(Json::Null) => None,
                    Some(s) => {
                        let draft_k = s.req("draft_k")?.as_usize()?;
                        let acceptance = s.req("acceptance")?.as_f64()?;
                        if draft_k == 0 {
                            anyhow::bail!("tier {i}: speculation needs draft_k >= 1");
                        }
                        if !(0.0..=1.0).contains(&acceptance) {
                            anyhow::bail!(
                                "tier {i}: speculation acceptance {acceptance} outside [0, 1]"
                            );
                        }
                        Some(SpecSpec { draft_k, acceptance })
                    }
                };
                if speculation.is_some() && gpus == 0 {
                    anyhow::bail!("tier {i}: speculation on an undeployed tier");
                }
                if speculation.is_some() && i == 0 {
                    anyhow::bail!(
                        "tier 0 cannot speculate: there is no shallower tier to draft with"
                    );
                }
                Ok(TierPlan {
                    model_name: t.req("model")?.as_str()?.to_string(),
                    gpus,
                    strategy,
                    workload: Workload {
                        rate: t.req("rate")?.as_f64()?,
                        avg_input: t.req("avg_input")?.as_f64()?,
                        avg_output: t.req("avg_output")?.as_f64()?,
                    },
                    processing_ratio: t.req("processing_ratio")?.as_f64()?,
                    predicted_p95: t.req("predicted_p95")?.as_f64()?,
                    disagg,
                    speculation,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if tiers.is_empty() {
            anyhow::bail!("plan has no tiers");
        }
        policy.validate(tiers.len())?;
        // Optional for backward compatibility: plans captured before
        // the swap policy existed default to recompute, and plans from
        // the global-knob era carry a single string that applies to
        // every tier.
        let preemption = match j.get("preemption") {
            Some(Json::Str(s)) => vec![preemption_mode_from_str(s)?; tiers.len()],
            Some(v) => {
                let modes = v
                    .as_arr()?
                    .iter()
                    .map(|m| preemption_mode_from_str(m.as_str()?))
                    .collect::<Result<Vec<_>>>()?;
                if modes.len() != tiers.len() {
                    anyhow::bail!(
                        "preemption vector has {} entries for {} tiers",
                        modes.len(),
                        tiers.len()
                    );
                }
                modes
            }
            None => Vec::new(),
        };
        Ok(CascadePlan {
            policy,
            tiers,
            predicted_latency: j.req("predicted_latency")?.as_f64()?,
            predicted_quality: j.req("predicted_quality")?.as_f64()?,
            preemption,
        })
    }

    /// Parse from JSON text (e.g. a `cascadia schedule` capture).
    pub fn from_json_text(text: &str) -> Result<CascadePlan> {
        CascadePlan::from_json(&Json::parse(text).context("parsing plan JSON")?)
    }

    /// Load from a plan file written by `cascadia schedule`.
    pub fn load(path: impl AsRef<Path>) -> Result<CascadePlan> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading plan {}", path.as_ref().display()))?;
        CascadePlan::from_json_text(&text)
    }

    /// Whether `other` can replace this plan on a running server
    /// without redeploying model weights: the cascade identity (tier
    /// count and model per tier) must match — only the allocation,
    /// parallelism, and routing policy may differ. This is the
    /// hot-swap compatibility contract of `ServeControl::apply_plan`.
    pub fn hot_swappable_with(&self, other: &CascadePlan) -> bool {
        self.tiers.len() == other.tiers.len()
            && self
                .tiers
                .iter()
                .zip(&other.tiers)
                .all(|(a, b)| a.model_name == b.model_name)
    }

    /// One-line summary for logs, in the paper's notation.
    pub fn summary(&self) -> String {
        let tiers = self
            .tiers
            .iter()
            .map(|t| {
                let s = t
                    .strategy
                    .as_ref()
                    .map(|s| s.label())
                    .unwrap_or_else(|| "-".to_string());
                let d = t
                    .disagg
                    .map(|d| format!(" D={}p+{}d", d.prefill_replicas, d.decode_replicas))
                    .unwrap_or_default();
                let d = format!(
                    "{d}{}",
                    t.speculation
                        .map(|s| format!(" S=k{}@{:.2}", s.draft_k, s.acceptance))
                        .unwrap_or_default()
                );
                format!(
                    "{}: f={} {} p={:.0}%{d}",
                    t.model_name,
                    t.gpus,
                    s,
                    t.processing_ratio * 100.0
                )
            })
            .collect::<Vec<_>>()
            .join(" | ");
        let preempt = if (0..self.tiers.len()).all(|i| self.preemption_for(i) == PreemptionMode::Recompute)
        {
            String::new()
        } else if (0..self.tiers.len()).all(|i| self.preemption_for(i) == PreemptionMode::Swap) {
            " P=swap".to_string()
        } else {
            format!(
                " P={}",
                (0..self.tiers.len())
                    .map(|i| preemption_mode_name(self.preemption_for(i)))
                    .collect::<Vec<_>>()
                    .join("/")
            )
        };
        format!(
            "{} L={:.2}s Q={:.1}{preempt} :: {tiers}",
            self.policy.label(),
            self.predicted_latency,
            self.predicted_quality,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::Strategy;

    fn sample() -> CascadePlan {
        CascadePlan {
            policy: PolicySpec::threshold(vec![70.0, 50.0]).unwrap(),
            tiers: vec![
                TierPlan {
                    model_name: "small".into(),
                    gpus: 4,
                    strategy: Some(Strategy::uniform(1, 1, 4)),
                    workload: Workload { rate: 4.0, avg_input: 500.0, avg_output: 250.0 },
                    processing_ratio: 1.0,
                    predicted_p95: 2.0,
                    disagg: None,
                    speculation: None,
                },
                TierPlan {
                    model_name: "mid".into(),
                    gpus: 0,
                    strategy: None,
                    workload: Workload { rate: 0.0, avg_input: 0.0, avg_output: 0.0 },
                    processing_ratio: 0.0,
                    predicted_p95: 0.0,
                    disagg: None,
                    speculation: None,
                },
                TierPlan {
                    model_name: "large".into(),
                    gpus: 8,
                    strategy: Some(Strategy::uniform(4, 2, 1)),
                    workload: Workload { rate: 1.0, avg_input: 700.0, avg_output: 300.0 },
                    processing_ratio: 0.2,
                    predicted_p95: 3.0,
                    disagg: None,
                    speculation: None,
                },
            ],
            predicted_latency: 3.0,
            predicted_quality: 75.0,
            preemption: Vec::new(),
        }
    }

    #[test]
    fn totals_and_deployed() {
        let p = sample();
        assert_eq!(p.total_gpus(), 12);
        assert_eq!(p.deployed().count(), 2);
    }

    #[test]
    fn json_roundtrip_parses() {
        let p = sample();
        let j = p.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.req("predicted_quality").unwrap().as_f64().unwrap(), 75.0);
        let tiers = parsed.req("tiers").unwrap().as_arr().unwrap();
        assert_eq!(tiers.len(), 3);
        assert_eq!(
            tiers[0].req("strategy").unwrap().req("label").unwrap().as_str().unwrap(),
            "(DP=4)"
        );
        assert_eq!(tiers[1].req("strategy").unwrap(), &Json::Null);
    }

    #[test]
    fn full_plan_roundtrip() {
        let p = sample();
        let back = CascadePlan::from_json_text(&p.to_json().to_string()).unwrap();
        assert_eq!(back.policy, p.policy);
        assert_eq!(back.total_gpus(), p.total_gpus());
        assert_eq!(back.tiers.len(), p.tiers.len());
        for (a, b) in back.tiers.iter().zip(&p.tiers) {
            assert_eq!(a.model_name, b.model_name);
            assert_eq!(a.gpus, b.gpus);
            assert_eq!(a.strategy, b.strategy);
            assert_eq!(a.workload.rate, b.workload.rate);
            assert_eq!(a.processing_ratio, b.processing_ratio);
            assert_eq!(a.predicted_p95, b.predicted_p95);
        }
        assert_eq!(back.predicted_latency, p.predicted_latency);
        assert_eq!(back.predicted_quality, p.predicted_quality);
    }

    #[test]
    fn from_json_rejects_inconsistent_plans() {
        // Policy arity must match the tier count.
        let mut p = sample();
        p.policy = PolicySpec::threshold(vec![70.0]).unwrap();
        assert!(CascadePlan::from_json_text(&p.to_json().to_string()).is_err());
        assert!(CascadePlan::from_json_text("{}").is_err());
        assert!(CascadePlan::from_json_text("not json").is_err());
    }

    #[test]
    fn preemption_round_trips_and_defaults_to_recompute() {
        let mut p = sample();
        p.preemption = vec![PreemptionMode::Swap; 3];
        let back = CascadePlan::from_json_text(&p.to_json().to_string()).unwrap();
        assert_eq!(back.preemption, vec![PreemptionMode::Swap; 3]);
        assert!(p.summary().contains("P=swap"), "{}", p.summary());
        // A plan captured before the knob existed still parses.
        let legacy = sample();
        let mut text = legacy.to_json().to_string();
        text = text.replace("\"preemption\":[\"recompute\",\"recompute\",\"recompute\"],", "");
        assert!(text.len() < legacy.to_json().to_string().len(), "replace must hit");
        let parsed = CascadePlan::from_json_text(&text).unwrap();
        assert_eq!(parsed.preemption_for(0), PreemptionMode::Recompute);
        assert_eq!(parsed.preemption_for(2), PreemptionMode::Recompute);
        // Unknown modes are rejected.
        let bad = legacy.to_json().to_string().replace("recompute", "teleport");
        assert!(CascadePlan::from_json_text(&bad).is_err());
    }

    #[test]
    fn preemption_accepts_legacy_single_value_and_per_tier_vectors() {
        // Global-knob era: one string applies to every tier.
        let legacy = sample().to_json().to_string().replace(
            "\"preemption\":[\"recompute\",\"recompute\",\"recompute\"]",
            "\"preemption\":\"swap\"",
        );
        let parsed = CascadePlan::from_json_text(&legacy).unwrap();
        assert_eq!(parsed.preemption, vec![PreemptionMode::Swap; 3]);
        // Per-tier: shallow recompute, deep swap.
        let mut p = sample();
        p.preemption =
            vec![PreemptionMode::Recompute, PreemptionMode::Recompute, PreemptionMode::Swap];
        let back = CascadePlan::from_json_text(&p.to_json().to_string()).unwrap();
        assert_eq!(back.preemption_for(0), PreemptionMode::Recompute);
        assert_eq!(back.preemption_for(2), PreemptionMode::Swap);
        assert!(p.summary().contains("P=recompute/recompute/swap"), "{}", p.summary());
        // Arity mismatches are rejected.
        let short = sample().to_json().to_string().replace(
            "\"preemption\":[\"recompute\",\"recompute\",\"recompute\"]",
            "\"preemption\":[\"swap\"]",
        );
        assert!(CascadePlan::from_json_text(&short).is_err());
    }

    #[test]
    fn disagg_round_trips_and_validates() {
        let mut p = sample();
        p.tiers[0].disagg = Some(DisaggSpec { prefill_replicas: 2, decode_replicas: 1 });
        let back = CascadePlan::from_json_text(&p.to_json().to_string()).unwrap();
        assert_eq!(
            back.tiers[0].disagg,
            Some(DisaggSpec { prefill_replicas: 2, decode_replicas: 1 })
        );
        assert_eq!(back.tiers[1].disagg, None);
        assert!(back.has_disagg());
        assert!(p.summary().contains("D=2p+1d"), "{}", p.summary());
        // A role with zero replicas is rejected.
        let bad = p
            .to_json()
            .to_string()
            .replace("\"decode_replicas\":1", "\"decode_replicas\":0");
        assert!(CascadePlan::from_json_text(&bad).is_err());
        // A split on an undeployed tier is rejected.
        let mut q = sample();
        q.tiers[1].disagg = Some(DisaggSpec { prefill_replicas: 1, decode_replicas: 1 });
        assert!(CascadePlan::from_json_text(&q.to_json().to_string()).is_err());
        // Legacy plans without the key parse as unified.
        assert!(!sample().has_disagg());
    }

    #[test]
    fn speculation_round_trips_and_validates() {
        let mut p = sample();
        p.tiers[2].speculation = Some(SpecSpec { draft_k: 4, acceptance: 0.8 });
        let back = CascadePlan::from_json_text(&p.to_json().to_string()).unwrap();
        assert_eq!(back.speculation_for(2), Some(SpecSpec { draft_k: 4, acceptance: 0.8 }));
        assert_eq!(back.speculation_for(0), None);
        assert!(back.has_speculation());
        assert!(p.summary().contains("S=k4@0.80"), "{}", p.summary());
        // Tier 0 has no shallower tier to draft with.
        let mut q = sample();
        q.tiers[0].speculation = Some(SpecSpec { draft_k: 2, acceptance: 0.5 });
        assert!(CascadePlan::from_json_text(&q.to_json().to_string()).is_err());
        // An undeployed tier cannot speculate.
        let mut u = sample();
        u.tiers[1].speculation = Some(SpecSpec { draft_k: 2, acceptance: 0.5 });
        assert!(CascadePlan::from_json_text(&u.to_json().to_string()).is_err());
        // draft_k 0 and out-of-range acceptance are rejected.
        let text = p.to_json().to_string();
        let bad_k = text.replace("\"draft_k\":4", "\"draft_k\":0");
        assert!(bad_k != text, "replace must hit");
        assert!(CascadePlan::from_json_text(&bad_k).is_err());
        let bad_a = text.replace("\"acceptance\":0.8", "\"acceptance\":1.5");
        assert!(bad_a != text, "replace must hit");
        assert!(CascadePlan::from_json_text(&bad_a).is_err());
        // Legacy plans without the key parse as plain decode.
        assert!(!sample().has_speculation());
        assert_eq!(sample().speculation_for(2), None);
    }

    #[test]
    fn hot_swappable_requires_same_cascade() {
        let a = sample();
        // Same models, different allocation/policy: swappable.
        let mut b = sample();
        b.policy = PolicySpec::threshold(vec![90.0, 60.0]).unwrap();
        b.tiers[0].gpus = 2;
        assert!(a.hot_swappable_with(&b));
        // Different model identity: not swappable.
        let mut c = sample();
        c.tiers[1].model_name = "other".into();
        assert!(!a.hot_swappable_with(&c));
        // Different tier count: not swappable.
        let mut d = sample();
        d.tiers.pop();
        assert!(!a.hot_swappable_with(&d));
    }

    #[test]
    fn summary_contains_key_fields() {
        let s = sample().summary();
        assert!(s.contains("H=(70,50)"), "{s}");
        assert!(s.contains("f=4"), "{s}");
        assert!(s.contains("Q=75.0"), "{s}");
    }
}
