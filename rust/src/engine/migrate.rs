//! Prefill→decode KV-page handoff for disaggregated tiers.
//!
//! A tier running a `disagg` split (see [`crate::sched::plan::DisaggSpec`])
//! serves every request on two engines: a prefill-role engine runs the
//! chunked prefill and the first decode token, then hands the sequence —
//! its payload, output-so-far, and private KV page count — to a
//! decode-role engine chosen by least-loaded-pages. The pages
//! themselves are modeled, not copied: private pages are "moved" over
//! the replica-pair interconnect (the decode backend charges
//! [`crate::perf::ReplicaModel::migrate_seconds`] through the
//! [`crate::engine::StepBackend::migrate`] hook) while shared prefix
//! pages re-claim through the decode pool's own trie and never travel.
//!
//! [`MigrationHub`] is the tier-local router between the two pools. It
//! is deliberately dumb: a per-decoder FIFO plus a pages-based
//! least-loaded pick at push time, a soft in-transit page budget that
//! closes the hub under backlog (prefill engines then keep sequences
//! local — disaggregation degrades to unified serving instead of
//! queueing unboundedly), and a retire path that re-routes a dead
//! decoder's queue to survivors so the exactly-once guarantee holds
//! across mid-migration worker death and hot-swap scale-downs.
//!
//! This module is inside `cascadia-lint`'s determinism scope (the DES
//! models the identical handoff): no wall-clock reads, no hash-order
//! iteration. `Instant`s only ride through as carried request state.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::util::sync::{CondvarExt, LockExt};

/// A sequence in transit from a prefill-role engine to a decode-role
/// engine. Everything the destination needs to resume decoding travels
/// with it; the source engine has already released its pages and
/// forgotten the sequence by the time this value exists.
#[derive(Debug)]
pub struct MigratedSeq<T> {
    /// Caller payload, returned untouched on completion.
    pub payload: T,
    pub prompt: Vec<i32>,
    /// Tokens generated on the prefill side (the first decode token —
    /// handoff happens at `generated <= 1`).
    pub output: Vec<i32>,
    pub max_new: usize,
    /// Prompt page hashes at the tier's page size; the decode engine
    /// re-claims shared prefix pages through its OWN trie from these
    /// (shared pages never migrate).
    pub hashes: Option<Arc<Vec<u64>>>,
    /// Private (unshared) KV pages the handoff moves across the
    /// interconnect — what the decode backend charges transit for.
    pub pages: usize,
    /// Remaining whole-request tokens when the source backend was
    /// adapted (None for native step backends).
    pub cached: Option<VecDeque<i32>>,
    /// Global request id stamped on trace events.
    pub trace_key: u64,
    /// Carried timing state (set on the prefill side; the decode side
    /// finishes the TTFT/e2e accounting against them).
    pub submitted_at: Instant,
    pub admitted_at: Option<Instant>,
    pub first_token_at: Option<Instant>,
}

impl<T> MigratedSeq<T> {
    /// Tokens already produced (prefill-side decode progress).
    pub fn generated(&self) -> usize {
        self.output.len()
    }
}

#[derive(Debug)]
struct DecoderSlot<T> {
    queue: VecDeque<MigratedSeq<T>>,
    /// Pages of the sequences queued here, not yet admitted.
    queued_pages: usize,
    /// Pool occupancy the decode worker last reported (its engine's
    /// `kv_in_use()` after each step).
    reported_pages: usize,
    live: bool,
}

impl<T> DecoderSlot<T> {
    fn load(&self) -> usize {
        self.reported_pages + self.queued_pages
    }
}

#[derive(Debug)]
struct HubState<T> {
    slots: Vec<DecoderSlot<T>>,
    closed: bool,
    /// Lifetime handoffs accepted / pages routed through the hub.
    routed: u64,
    routed_pages: u64,
    /// Handoffs rejected (no live decoder, or pushed after close).
    rejected: u64,
}

/// Tier-local router between a prefill worker pool and a decode worker
/// pool. Shared by `Arc` across the tier's workers.
pub struct MigrationHub<T> {
    state: Mutex<HubState<T>>,
    wake: Condvar,
    /// Soft bound on total in-transit (queued, unadmitted) pages; at or
    /// above it [`MigrationHub::open`] reports false and prefill
    /// engines keep sequences local until the backlog drains.
    budget_pages: usize,
}

impl<T> MigrationHub<T> {
    /// `budget_pages` caps the pages queued across all decoders before
    /// the hub closes to new handoffs (0 = unbounded).
    pub fn new(budget_pages: usize) -> MigrationHub<T> {
        MigrationHub {
            state: Mutex::new(HubState {
                slots: Vec::new(),
                closed: false,
                routed: 0,
                routed_pages: 0,
                rejected: 0,
            }),
            wake: Condvar::new(),
            budget_pages: if budget_pages == 0 { usize::MAX } else { budget_pages },
        }
    }

    /// Register one decode worker; returns its slot index (the handle
    /// for [`MigrationHub::pop_wait`] / [`MigrationHub::report_pages`] /
    /// [`MigrationHub::retire`]).
    pub fn register_decoder(&self) -> usize {
        let mut s = self.state.plock();
        s.slots.push(DecoderSlot {
            queue: VecDeque::new(),
            queued_pages: 0,
            reported_pages: 0,
            live: true,
        });
        s.slots.len() - 1
    }

    /// Update a decoder's reported pool occupancy (feeds the
    /// least-loaded pick).
    pub fn report_pages(&self, idx: usize, pages: usize) {
        let mut s = self.state.plock();
        if let Some(slot) = s.slots.get_mut(idx) {
            slot.reported_pages = pages;
        }
    }

    /// Whether prefill engines should hand off right now: some decoder
    /// is live and the in-transit backlog is under budget. A closed
    /// hub makes prefill engines decode locally (unified degradation),
    /// never drop or stall.
    pub fn open(&self) -> bool {
        let s = self.state.plock();
        !s.closed
            && s.slots.iter().any(|slot| slot.live)
            && s.slots.iter().map(|slot| slot.queued_pages).sum::<usize>() < self.budget_pages
    }

    /// Route one migrated sequence to the least-loaded live decoder
    /// (reported pool pages + queued pages; ties go to the lowest
    /// index, so routing is deterministic for a given load picture).
    /// `Err` hands the sequence back when no live decoder exists or the
    /// hub is closed — the caller re-queues it for unified serving.
    pub fn push(&self, m: MigratedSeq<T>) -> Result<(), MigratedSeq<T>> {
        let mut s = self.state.plock();
        if s.closed {
            s.rejected += 1;
            return Err(m);
        }
        let pick = s
            .slots
            .iter()
            .enumerate()
            .filter(|(_, slot)| slot.live)
            .min_by_key(|(i, slot)| (slot.load(), *i))
            .map(|(i, _)| i);
        match pick {
            Some(i) => {
                s.routed += 1;
                s.routed_pages += m.pages as u64;
                let slot = &mut s.slots[i];
                slot.queued_pages += m.pages;
                slot.queue.push_back(m);
                drop(s);
                self.wake.notify_all();
                Ok(())
            }
            None => {
                s.rejected += 1;
                Err(m)
            }
        }
    }

    /// Drain decoder `idx`'s queue without blocking.
    pub fn try_drain(&self, idx: usize) -> Vec<MigratedSeq<T>> {
        let mut s = self.state.plock();
        Self::drain_slot(&mut s, idx)
    }

    /// Block until decoder `idx` has queued work or the hub closes;
    /// returns the drained queue (empty ⇒ closed and nothing pending —
    /// the worker should exit).
    pub fn pop_wait(&self, idx: usize) -> Vec<MigratedSeq<T>> {
        let mut s = self.state.plock();
        loop {
            let drained = Self::drain_slot(&mut s, idx);
            if !drained.is_empty() || s.closed {
                return drained;
            }
            s = self.wake.pwait(s);
        }
    }

    fn drain_slot(s: &mut HubState<T>, idx: usize) -> Vec<MigratedSeq<T>> {
        match s.slots.get_mut(idx) {
            Some(slot) => {
                slot.queued_pages = 0;
                slot.queue.drain(..).collect()
            }
            None => Vec::new(),
        }
    }

    /// Take decoder `idx` out of rotation (worker death or hot-swap
    /// scale-down) and re-route its queued sequences to surviving
    /// decoders. Sequences that cannot be placed (no survivor) come
    /// back for the caller to re-queue upstream — nothing is dropped.
    pub fn retire(&self, idx: usize) -> Vec<MigratedSeq<T>> {
        let orphans = {
            let mut s = self.state.plock();
            match s.slots.get_mut(idx) {
                Some(slot) => {
                    slot.live = false;
                    slot.reported_pages = 0;
                    slot.queued_pages = 0;
                    slot.queue.drain(..).collect::<Vec<_>>()
                }
                None => Vec::new(),
            }
        };
        let mut leftovers = Vec::new();
        for m in orphans {
            if let Err(back) = self.push(m) {
                leftovers.push(back);
            }
        }
        self.wake.notify_all();
        leftovers
    }

    /// Close the hub: [`MigrationHub::open`] turns false, pushes are
    /// rejected, and blocked decoders wake with their final drains.
    pub fn close(&self) {
        self.state.plock().closed = true;
        self.wake.notify_all();
    }

    /// Live decoders currently registered.
    pub fn n_live(&self) -> usize {
        self.state.plock().slots.iter().filter(|s| s.live).count()
    }

    /// Total queued (in-transit, unadmitted) sequences.
    pub fn n_queued(&self) -> usize {
        self.state.plock().slots.iter().map(|s| s.queue.len()).sum()
    }

    /// Lifetime (handoffs routed, pages routed, handoffs rejected).
    pub fn counts(&self) -> (u64, u64, u64) {
        let s = self.state.plock();
        (s.routed, s.routed_pages, s.rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mig(pages: usize) -> MigratedSeq<u32> {
        MigratedSeq {
            payload: 0,
            prompt: vec![1; 8],
            output: vec![7],
            max_new: 4,
            hashes: None,
            pages,
            cached: None,
            trace_key: 0,
            submitted_at: Instant::now(),
            admitted_at: None,
            first_token_at: None,
        }
    }

    #[test]
    fn push_routes_to_least_loaded_decoder() {
        let hub: MigrationHub<u32> = MigrationHub::new(0);
        let a = hub.register_decoder();
        let b = hub.register_decoder();
        hub.report_pages(a, 40);
        hub.report_pages(b, 10);
        hub.push(mig(4)).unwrap();
        assert_eq!(hub.try_drain(a).len(), 0);
        // Queued pages count as load: after 8 queued pages on b, a
        // (40) still loses to b (10 + 8), so b keeps winning until its
        // queue catches up.
        hub.push(mig(8)).unwrap();
        hub.report_pages(b, 40);
        hub.push(mig(2)).unwrap();
        let to_b = hub.try_drain(b);
        let to_a = hub.try_drain(a);
        assert_eq!(to_b.len(), 2);
        assert_eq!(to_a.len(), 1, "load ties/reversals spill to the other decoder");
        let (routed, pages, rejected) = hub.counts();
        assert_eq!(routed, 3);
        assert_eq!(pages, 14);
        assert_eq!(rejected, 0);
    }

    #[test]
    fn budget_closes_and_drain_reopens_the_hub() {
        let hub: MigrationHub<u32> = MigrationHub::new(10);
        let d = hub.register_decoder();
        assert!(hub.open());
        hub.push(mig(6)).unwrap();
        assert!(hub.open(), "under budget stays open");
        hub.push(mig(6)).unwrap();
        assert!(!hub.open(), "12 queued pages ≥ budget 10 closes the hub");
        // open() is advisory — push still lands (the prefill engine
        // checks open() BEFORE starting a handoff).
        assert_eq!(hub.try_drain(d).len(), 2);
        assert!(hub.open(), "draining the backlog reopens the hub");
    }

    #[test]
    fn no_live_decoder_bounces_the_sequence_back() {
        let hub: MigrationHub<u32> = MigrationHub::new(0);
        assert!(!hub.open(), "no decoders registered");
        let back = hub.push(mig(3)).unwrap_err();
        assert_eq!(back.pages, 3);
        let d = hub.register_decoder();
        assert!(hub.open());
        let leftovers = hub.retire(d);
        assert!(leftovers.is_empty(), "empty queue retires clean");
        assert!(!hub.open(), "retiring the only decoder closes the hub");
        assert!(hub.push(mig(3)).is_err());
        assert_eq!(hub.counts().2, 2, "both bounces counted as rejected");
    }

    #[test]
    fn retire_reroutes_queued_work_to_survivors() {
        let hub: MigrationHub<u32> = MigrationHub::new(0);
        let a = hub.register_decoder();
        let b = hub.register_decoder();
        hub.report_pages(b, 1_000); // everything routes to a first
        hub.push(mig(1)).unwrap();
        hub.push(mig(1)).unwrap();
        assert_eq!(hub.n_queued(), 2);
        let leftovers = hub.retire(a);
        assert!(leftovers.is_empty(), "survivor b absorbs a's queue");
        assert_eq!(hub.try_drain(b).len(), 2, "nothing lost mid-migration");
        // Retiring the last decoder returns the orphans instead.
        hub.push(mig(1)).unwrap();
        let orphans = hub.retire(b);
        assert_eq!(orphans.len(), 1, "unplaceable sequences come back to the caller");
        assert_eq!(hub.n_live(), 0);
    }

    #[test]
    fn close_wakes_blocked_decoders_and_rejects_pushes() {
        let hub: Arc<MigrationHub<u32>> = Arc::new(MigrationHub::new(0));
        let d = hub.register_decoder();
        let h2 = Arc::clone(&hub);
        let waiter = std::thread::spawn(move || h2.pop_wait(d));
        hub.close();
        let drained = waiter.join().unwrap();
        assert!(drained.is_empty(), "closed + empty queue = clean exit signal");
        assert!(hub.push(mig(1)).is_err(), "closed hub accepts nothing");
        assert!(!hub.open());
    }
}
