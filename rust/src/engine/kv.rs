//! Paged KV-cache pool: fixed-size token pages with per-sequence page
//! tables, refcounted prefix sharing, and copy-on-write divergence.
//!
//! The pool is the memory model of the continuous-batching engine: a
//! replica's KV budget (derived from the [`crate::perf::ReplicaModel`]
//! memory terms, see [`crate::perf::ReplicaModel::kv_pages_total`]) is
//! carved into pages of `page_tokens` tokens, and every in-flight
//! sequence holds an explicit page list. Admission and per-iteration
//! growth go through all-or-nothing [`KvPool::grow_to`] calls, so the
//! scheduler always sees exact occupancy and can preempt instead of
//! overcommitting.
//!
//! **Prefix sharing.** Pages are refcounted, and prefilled prompt pages
//! can be *published* into a prefix trie keyed on chained token-page
//! hashes ([`prompt_page_hashes`]): page `i`'s key commits to every
//! token in pages `0..=i`, so a trie walk is exactly a prefix-tree
//! descent flattened into a hash map. A sequence admitted with a
//! matching prompt prefix ([`KvPool::claim_prefix`]) maps its table
//! onto the shared pages (refcount bump, zero allocation, zero
//! prefill) — system prompts, same-tier retries, and cascade re-serves
//! of one request at deeper tiers all hit this path.
//!
//! **Copy-on-write.** Shared pages are read-only to claimers: the
//! registered hash covers a token range, and every holder reads only
//! its own context length, so concurrent holders never conflict on
//! reads. The first *write* into a page another sequence can observe
//! (appending a token into a partially-filled shared page) triggers a
//! CoW copy inside [`KvPool::grow_to`] — the writer gets a private
//! page, the shared one keeps serving its other holders. A page whose
//! refcount drops to zero leaves the trie and returns to the free
//! list, so the trie can never outlive the sequences anchoring it
//! (leak accounting: after a full drain the trie is empty and the free
//! list is back to capacity).
//!
//! Pages are identified by index so the page *tables* are real (the
//! shape a paged-attention kernel would consume), and shrinking the
//! pool defragments live tables down into the surviving id range with
//! explicit move accounting.

use std::collections::HashMap;

/// Engine-wide sequence identifier.
pub type SeqId = u64;

/// Pool-invariant assertion: a false condition means the allocator's
/// bookkeeping is broken (dead-page decref, table/free-list desync), so
/// panic with the failing check *and* a one-line pool-state snapshot —
/// the context a page-leak post-mortem actually needs. Always on:
/// unlike `debug_assert!`, release builds serving real traffic keep the
/// check.
macro_rules! kv_invariant {
    // `if c {} else { panic }` rather than `if !c` so arbitrary boolean
    // conditions never trip clippy's nonminimal_bool at the call site.
    ($pool:expr, $cond:expr, $($msg:tt)+) => {
        if $cond {
        } else {
            panic!(
                "kv pool invariant violated: {} [{}]",
                format_args!($($msg)+),
                $pool.state_line(),
            );
        }
    };
}

/// Pool-invariant unwrap: like [`kv_invariant!`] but for lookups whose
/// `None` means a broken invariant. The operand must be an *owned*
/// `Option` (e.g. `Vec::pop`, `HashMap::remove`) so the pool is free to
/// format its state in the failure arm.
macro_rules! kv_expect {
    ($pool:expr, $opt:expr, $($msg:tt)+) => {
        match $opt {
            Some(v) => v,
            None => panic!(
                "kv pool invariant violated: {} [{}]",
                format_args!($($msg)+),
                $pool.state_line(),
            ),
        }
    };
}

/// Allocation failure: the pool is `short` pages of satisfying the
/// request. Nothing was allocated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PagesShort(pub usize);

/// Swap-out failure: the host swap space is `short` pages of holding
/// the victim's private pages. Nothing was moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SwapShort(pub usize);

/// Chained FNV-1a page hashes of a prompt: one entry per page the
/// prompt occupies, where entry `i` commits to the token count and
/// content of every page up to and including `i`. Two prompts share a
/// hash prefix exactly when they share the corresponding token-page
/// prefix, which is what makes the flat trie lookup sound.
pub fn prompt_page_hashes(prompt: &[i32], page_tokens: usize) -> Vec<u64> {
    let pt = page_tokens.max(1);
    let mut out = Vec::with_capacity(prompt.len().div_ceil(pt));
    let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV offset basis
    for chunk in prompt.chunks(pt) {
        h = fnv1a(h, &(chunk.len() as u64).to_le_bytes());
        for &t in chunk {
            h = fnv1a(h, &t.to_le_bytes());
        }
        out.push(h);
    }
    out
}

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Per-page allocator metadata.
#[derive(Debug, Clone, Copy, Default)]
struct PageMeta {
    /// Sequences holding this page (0 = dead/free).
    refs: u32,
    /// Trie key when the page is published as a shareable prefix page.
    hash: Option<u64>,
}

/// Per-sequence allocation state.
#[derive(Debug, Default)]
struct SeqPages {
    /// Page table, context order. A prefix of it may be shared.
    pages: Vec<usize>,
    /// Pages claimed from the trie at admission (for retraction).
    claimed_pages: usize,
    /// Context tokens the table has been grown to (write frontier).
    tokens: usize,
}

/// A sequence parked in host swap space ([`KvPool::swap_out`]): its
/// private pages live on the host; shared prefix pages stay
/// device-resident with the victim's refcount intact, so other holders
/// (and the trie) are untouched and swap-in never recomputes them.
#[derive(Debug)]
struct SwappedSeq {
    /// Leading table pages that stayed device-resident (shared at
    /// swap-out time; the parked sequence still holds its reference).
    resident: Vec<usize>,
    /// Pages moved to host swap space (the victim's private tail).
    host_pages: usize,
    /// Context tokens the table covered at swap-out (restored on
    /// swap-in — the chunk-checkpoint frontier).
    tokens: usize,
    /// Claimed-page accounting carried across the park.
    claimed_pages: usize,
}

/// A pool of fixed-size KV pages with refcounted per-sequence page
/// tables and a prefix trie for shared-prompt serving.
#[derive(Debug)]
pub struct KvPool {
    page_tokens: usize,
    capacity: usize,
    /// Unallocated page ids below `capacity` (LIFO free list).
    free: Vec<usize>,
    /// Metadata for every page id ever minted (index = page id).
    meta: Vec<PageMeta>,
    /// Per-sequence page tables, in allocation order.
    tables: HashMap<SeqId, SeqPages>,
    /// Flattened prefix trie: chained page hash -> published page id.
    trie: HashMap<u64, usize>,
    /// Sequences parked in host swap space.
    swapped: HashMap<SeqId, SwappedSeq>,
    /// Host swap budget in pages (0 = swap disabled).
    swap_capacity: usize,
    /// Legal over-budget remainder after a capacity shrink below usage
    /// (hot-swap): swap-outs stay blocked until the parked pages drain
    /// back under the target, and `validate` tells this stranded state
    /// apart from a budget-enforcement bug.
    swap_overcommit: usize,
    /// Host pages currently parked.
    swapped_pages: usize,
    peak_swapped_pages: usize,
    /// Physical pages live (refcount > 0); shared pages count once.
    in_use: usize,
    peak_in_use: usize,
    allocs: u64,
    frees: u64,
    defrag_moves: u64,
    shared_claims: u64,
    cow_copies: u64,
    swap_outs: u64,
    swap_ins: u64,
    /// Pages moved across PCIe, both directions.
    swap_page_moves: u64,
}

impl KvPool {
    /// A pool of `capacity` pages of `page_tokens` tokens each (both
    /// clamped to at least 1).
    pub fn new(capacity: usize, page_tokens: usize) -> KvPool {
        let capacity = capacity.max(1);
        KvPool {
            page_tokens: page_tokens.max(1),
            capacity,
            free: (0..capacity).rev().collect(),
            meta: vec![PageMeta::default(); capacity],
            tables: HashMap::new(),
            trie: HashMap::new(),
            swapped: HashMap::new(),
            swap_capacity: 0,
            swap_overcommit: 0,
            swapped_pages: 0,
            peak_swapped_pages: 0,
            in_use: 0,
            peak_in_use: 0,
            allocs: 0,
            frees: 0,
            defrag_moves: 0,
            shared_claims: 0,
            cow_copies: 0,
            swap_outs: 0,
            swap_ins: 0,
            swap_page_moves: 0,
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// One-line allocator snapshot embedded in [`kv_invariant!`] /
    /// [`kv_expect!`] panics.
    fn state_line(&self) -> String {
        format!(
            "capacity={} free={} in_use={} tables={} swapped={} trie={} \
             swapped_pages={}/{}",
            self.capacity,
            self.free.len(),
            self.in_use,
            self.tables.len(),
            self.swapped.len(),
            self.trie.len(),
            self.swapped_pages,
            self.swap_capacity,
        )
    }

    /// Target capacity in pages. After a shrink below current usage the
    /// pool is temporarily over-committed: `in_use` may exceed this
    /// until sequences retire, and no allocation succeeds meanwhile.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Physical pages live. A page shared by many sequences counts
    /// once — this is what occupancy/budget invariants compare.
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// High-water mark of physical pages simultaneously allocated.
    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Pages a context of `tokens` tokens occupies (at least 1).
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.page_tokens)
    }

    pub fn holds(&self, seq: SeqId) -> bool {
        self.tables.contains_key(&seq)
    }

    /// The sequence's page table (empty slice when unknown).
    pub fn pages_of(&self, seq: SeqId) -> &[usize] {
        self.tables.get(&seq).map(|t| t.pages.as_slice()).unwrap_or(&[])
    }

    /// Published prefix pages currently claimable (trie size).
    pub fn trie_len(&self) -> usize {
        self.trie.len()
    }

    /// Holder count of page `pid` (0 = free/dead/out-of-range) —
    /// exposed for invariant checks in tests.
    pub fn page_refs(&self, pid: usize) -> u32 {
        self.meta.get(pid).map(|m| m.refs).unwrap_or(0)
    }

    /// Lifetime count of pages claimed through the prefix trie.
    pub fn shared_claims(&self) -> u64 {
        self.shared_claims
    }

    /// Lifetime count of copy-on-write page copies.
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    // ---- Host swap space ----

    /// Bound the host swap space to `pages` (0 disables swap-out). The
    /// budget is a target like [`KvPool::capacity`]: shrinking below
    /// current usage blocks further swap-outs until parked sequences
    /// resume or retire, it never drops parked state (the stranded
    /// remainder is recorded so [`KvPool::validate`] accepts it).
    pub fn set_swap_capacity(&mut self, pages: usize) {
        self.swap_capacity = pages;
        self.swap_overcommit = self.swapped_pages.saturating_sub(pages);
    }

    pub fn swap_capacity(&self) -> usize {
        self.swap_capacity
    }

    /// Host pages currently parked in swap space.
    pub fn swapped_pages(&self) -> usize {
        self.swapped_pages
    }

    /// High-water mark of host pages simultaneously parked.
    pub fn peak_swapped_pages(&self) -> usize {
        self.peak_swapped_pages
    }

    /// Host pages still free in the swap budget.
    pub fn swap_free(&self) -> usize {
        self.swap_capacity.saturating_sub(self.swapped_pages)
    }

    /// Sequences currently parked in host swap space.
    pub fn swapped_seqs(&self) -> usize {
        self.swapped.len()
    }

    pub fn is_swapped(&self, seq: SeqId) -> bool {
        self.swapped.contains_key(&seq)
    }

    /// Lifetime (swap-outs, swap-ins, pages moved across PCIe in both
    /// directions).
    pub fn swap_counts(&self) -> (u64, u64, u64) {
        (self.swap_outs, self.swap_ins, self.swap_page_moves)
    }

    /// The split [`KvPool::swap_out`] would apply to `seq`'s table:
    /// (shared prefix pages that stay device-resident, private pages
    /// that move to host). (0, 0) for unknown sequences.
    pub fn swap_split(&self, seq: SeqId) -> (usize, usize) {
        let Some(t) = self.tables.get(&seq) else { return (0, 0) };
        let shared = t.pages.iter().take_while(|&&pid| self.meta[pid].refs > 1).count();
        (shared, t.pages.len() - shared)
    }

    /// Park `seq` in host swap space: its private pages (everything
    /// past the shared prefix) leave the device pool and free their
    /// ids; shared prefix pages stay resident with the sequence's
    /// refcount intact, so concurrent holders and the trie never
    /// notice. All-or-nothing against the swap budget; returns the
    /// pages moved to host on success.
    pub fn swap_out(&mut self, seq: SeqId) -> Result<usize, SwapShort> {
        let (shared, private) = self.swap_split(seq);
        debug_assert!(self.tables.contains_key(&seq), "swap_out of unknown sequence");
        if private > self.swap_free() {
            return Err(SwapShort(private - self.swap_free()));
        }
        let Some(table) = self.tables.remove(&seq) else { return Err(SwapShort(0)) };
        let mut resident = table.pages;
        let tail = resident.split_off(shared);
        for pid in tail {
            // Private pages return to the free list; any shared page
            // past the first private one just loses this holder's ref
            // (its KV still rides to host with the victim's copy).
            self.decref(pid);
        }
        self.swapped.insert(
            seq,
            SwappedSeq {
                resident,
                host_pages: private,
                tokens: table.tokens,
                claimed_pages: table.claimed_pages,
            },
        );
        self.swapped_pages += private;
        self.peak_swapped_pages = self.peak_swapped_pages.max(self.swapped_pages);
        self.swap_outs += 1;
        self.swap_page_moves += private as u64;
        Ok(private)
    }

    /// Device pages a parked sequence needs to resume AND immediately
    /// grow to `need_tokens` of context (pass 0 for no growth): its
    /// host pages, plus the new pages past its checkpointed frontier,
    /// plus one page of copy-on-write margin when it grows (the first
    /// write may land in a shared resident page). The scheduler gates
    /// resumption on this so a sequence is never swapped in just to be
    /// re-evicted by its own next reservation — that round trip moves
    /// every private page across PCIe twice for zero progress.
    pub fn swap_in_headroom(&self, seq: SeqId, need_tokens: usize) -> usize {
        let Some(sw) = self.swapped.get(&seq) else { return 0 };
        let grow = if need_tokens > 0 {
            self.pages_for(need_tokens).saturating_sub(self.pages_for(sw.tokens)) + 1
        } else {
            0
        };
        sw.host_pages + grow
    }

    /// Bring a parked sequence back: re-allocate its private pages from
    /// the device pool and restore its table (resident prefix + fresh
    /// pages) at the checkpointed token frontier. All-or-nothing: on
    /// `Err` the sequence stays parked and the error carries the
    /// missing page count. Returns the pages moved back on success.
    pub fn swap_in(&mut self, seq: SeqId) -> Result<usize, PagesShort> {
        let host_pages = match self.swapped.get(&seq) {
            Some(sw) => sw.host_pages,
            None => {
                debug_assert!(false, "swap_in of a sequence that is not parked");
                return Err(PagesShort(0));
            }
        };
        if host_pages > self.free.len() {
            return Err(PagesShort(host_pages - self.free.len()));
        }
        let sw = kv_expect!(
            self,
            self.swapped.remove(&seq),
            "swap-in of a sequence {seq} that is not parked"
        );
        let mut pages = sw.resident;
        for _ in 0..host_pages {
            pages.push(self.alloc_page());
        }
        self.tables.insert(
            seq,
            SeqPages { pages, claimed_pages: sw.claimed_pages, tokens: sw.tokens },
        );
        self.swapped_pages -= host_pages;
        self.swap_ins += 1;
        self.swap_page_moves += host_pages as u64;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Ok(host_pages)
    }

    /// Drop one reference to `pid`; at zero the page leaves the trie
    /// and (if inside the capacity bound) returns to the free list.
    fn decref(&mut self, pid: usize) {
        kv_invariant!(self, self.meta[pid].refs > 0, "decref of dead page {pid}");
        let m = &mut self.meta[pid];
        m.refs -= 1;
        if m.refs == 0 {
            if let Some(h) = m.hash.take() {
                self.trie.remove(&h);
            }
            self.in_use -= 1;
            self.frees += 1;
            // Pages beyond a shrunk capacity leave the pool entirely
            // (rediscovered if the pool grows back over them).
            if pid < self.capacity {
                self.free.push(pid);
            }
        }
    }

    /// Mint one fresh private page off the free list (caller checked).
    fn alloc_page(&mut self) -> usize {
        let pid = kv_expect!(
            self,
            self.free.pop(),
            "allocation from an empty free list (caller skipped the bound check)"
        );
        self.meta[pid] = PageMeta { refs: 1, hash: None };
        self.in_use += 1;
        self.allocs += 1;
        pid
    }

    /// Walk the prefix trie along `hashes` and map every hit onto
    /// `seq`'s (empty) page table with a refcount bump — no pages are
    /// allocated and no prefill is owed for the claimed span. Returns
    /// the prompt tokens covered (capped at `prompt_tokens`; a
    /// full-length walk means the tail page was published too and the
    /// whole prompt's KV is resident).
    pub fn claim_prefix(&mut self, seq: SeqId, hashes: &[u64], prompt_tokens: usize) -> usize {
        debug_assert!(
            !self.swapped.contains_key(&seq),
            "claim_prefix on a swapped sequence"
        );
        debug_assert!(
            self.tables.get(&seq).map(|t| t.pages.is_empty()).unwrap_or(true),
            "claim_prefix on a sequence that already holds pages"
        );
        let mut claimed = Vec::new();
        for h in hashes {
            let Some(&pid) = self.trie.get(h) else { break };
            claimed.push(pid);
        }
        if claimed.is_empty() {
            return 0;
        }
        for &pid in &claimed {
            self.meta[pid].refs += 1;
        }
        let tokens = (claimed.len() * self.page_tokens).min(prompt_tokens.max(1));
        self.shared_claims += claimed.len() as u64;
        let entry = self.tables.entry(seq).or_default();
        entry.claimed_pages = claimed.len();
        entry.pages = claimed;
        entry.tokens = tokens;
        tokens
    }

    /// Undo an admission-time claim that did NOT become an admission:
    /// releases the sequence's pages like [`KvPool::release`] and
    /// removes them from the shared-claims accounting — a claim that
    /// never served anything must not inflate the sharing telemetry
    /// (a congested head may claim-and-retract for several ticks).
    pub fn retract_claim(&mut self, seq: SeqId) {
        if let Some(t) = self.tables.get(&seq) {
            self.shared_claims -= t.claimed_pages as u64;
        }
        self.release(seq);
    }

    /// Publish `seq`'s prefilled prompt pages into the prefix trie,
    /// one entry per hash (pages the sequence itself claimed already
    /// carry their hash and are skipped; first publisher of a hash
    /// wins). Call only once the pages' KV is actually computed — the
    /// scheduler does this the iteration *after* prefill completes.
    pub fn publish_prefix(&mut self, seq: SeqId, hashes: &[u64]) {
        let Some(entry) = self.tables.get(&seq) else { return };
        let pages: Vec<usize> =
            entry.pages.iter().take(hashes.len()).copied().collect();
        for (pid, &h) in pages.into_iter().zip(hashes) {
            if self.meta[pid].hash.is_none() && !self.trie.contains_key(&h) {
                self.meta[pid].hash = Some(h);
                self.trie.insert(h, pid);
            }
        }
    }

    /// Ensure `seq` holds enough pages for `tokens` tokens of context,
    /// allocating the shortfall and copy-on-writing any shared page the
    /// new tokens would be appended into. All-or-nothing: on `Err`
    /// nothing changed and the error carries the missing page count.
    pub fn grow_to(&mut self, seq: SeqId, tokens: usize) -> Result<(), PagesShort> {
        debug_assert!(
            !self.swapped.contains_key(&seq),
            "grow_to on a swapped sequence — swap_in first"
        );
        let tokens = tokens.max(1);
        let need = self.pages_for(tokens);
        let (have, old_tokens) = self
            .tables
            .get(&seq)
            .map(|t| (t.pages.len(), t.tokens))
            .unwrap_or((0, 0));
        // Pages the new tokens (old_tokens..tokens) are written into
        // that already exist and are shared: each needs a CoW copy.
        let mut cow_slots: Vec<usize> = Vec::new();
        if tokens > old_tokens && have > 0 {
            let first = old_tokens / self.page_tokens;
            let last = ((tokens - 1) / self.page_tokens).min(have.saturating_sub(1));
            if first <= last {
                let table = &self.tables[&seq];
                for idx in first..=last {
                    if self.meta[table.pages[idx]].refs > 1 {
                        cow_slots.push(idx);
                    }
                }
            }
        }
        let shortfall = need.saturating_sub(have) + cow_slots.len();
        if shortfall > self.free.len() {
            return Err(PagesShort(shortfall - self.free.len()));
        }
        kv_invariant!(
            self,
            cow_slots.is_empty() || self.tables.contains_key(&seq),
            "cow on unknown sequence {seq}"
        );
        for idx in cow_slots {
            let fresh = self.alloc_page();
            let old = {
                let Some(table) = self.tables.get_mut(&seq) else {
                    unreachable!("presence checked before the cow loop")
                };
                std::mem::replace(&mut table.pages[idx], fresh)
            };
            self.decref(old);
            self.cow_copies += 1;
        }
        for _ in have..need {
            let pid = self.alloc_page();
            self.tables.entry(seq).or_default().pages.push(pid);
        }
        let entry = self.tables.entry(seq).or_default();
        entry.tokens = entry.tokens.max(tokens);
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Ok(())
    }

    /// Extend `seq`'s context by `n` tokens past its current write
    /// frontier (speculative draft slack). Same all-or-nothing contract
    /// as [`grow_to`](Self::grow_to).
    pub fn grow_by(&mut self, seq: SeqId, n: usize) -> Result<(), PagesShort> {
        let cur = self.tables.get(&seq).map(|t| t.tokens).unwrap_or(0);
        self.grow_to(seq, cur + n.max(1))
    }

    /// Shrink `seq`'s write frontier back to `tokens`, freeing the tail
    /// pages past it — the rejected-draft rollback. The dropped pages
    /// are the generated region past the verified context: fresh or
    /// CoW-private by construction, never published and never shared, so
    /// the trie and any shared prefix are untouched. Growing targets and
    /// unknown sequences are a no-op.
    pub fn rollback_to(&mut self, seq: SeqId, tokens: usize) {
        let tokens = tokens.max(1);
        let keep = self.pages_for(tokens);
        let dropped = {
            let Some(t) = self.tables.get_mut(&seq) else {
                return;
            };
            if t.tokens <= tokens {
                return;
            }
            t.tokens = tokens;
            if t.pages.len() > keep {
                t.pages.split_off(keep)
            } else {
                Vec::new()
            }
        };
        kv_invariant!(
            self,
            keep >= self.tables[&seq].claimed_pages,
            "rollback into the claimed prefix of sequence {seq}"
        );
        for pid in dropped {
            kv_invariant!(
                self,
                self.meta[pid].refs == 1 && self.meta[pid].hash.is_none(),
                "rollback freed a shared or published page {pid}"
            );
            self.decref(pid);
        }
    }

    /// Release every page reference `seq` holds; returns the count of
    /// pages physically freed (shared pages with surviving holders stay
    /// live — and stay claimable). A sequence parked in host swap space
    /// drops its host pages and its resident-prefix refs. Unknown
    /// sequences are a no-op (0).
    pub fn release(&mut self, seq: SeqId) -> usize {
        if let Some(sw) = self.swapped.remove(&seq) {
            self.swapped_pages -= sw.host_pages;
            let before = self.frees;
            for pid in sw.resident {
                self.decref(pid);
            }
            return (self.frees - before) as usize;
        }
        let Some(table) = self.tables.remove(&seq) else {
            return 0;
        };
        let before = self.frees;
        for pid in table.pages {
            self.decref(pid);
        }
        (self.frees - before) as usize
    }

    /// Retarget the pool to `capacity` pages.
    ///
    /// Growth adds fresh page ids. Shrinking drops free ids beyond the
    /// bound and defragments live pages down into the surviving id
    /// range where free ids allow — each relocation is one physical
    /// move (`defrag_moves`), applied once even when the page is shared
    /// by many tables, and the trie follows the move. If usage exceeds
    /// the new capacity the pool runs over-committed: stranded high ids
    /// stay valid for their holders, and allocations fail until usage
    /// drops back under the target.
    pub fn resize(&mut self, capacity: usize) {
        let capacity = capacity.max(1);
        if capacity >= self.capacity {
            if capacity == self.capacity {
                return;
            }
            for id in self.capacity..capacity {
                if id >= self.meta.len() {
                    self.meta.push(PageMeta::default());
                    self.free.push(id);
                } else if self.meta[id].refs == 0 {
                    // Ids stranded above the old bound by an earlier
                    // shrink: dead ones become allocatable again; held
                    // ones stay with their owners.
                    self.free.push(id);
                }
            }
            self.capacity = capacity;
            return;
        }
        self.capacity = capacity;
        self.free.retain(|&id| id < capacity);
        // Relocate each live high page once, shared or not, and remap
        // every table (and the trie) through one old->new map.
        let mut remap: HashMap<usize, usize> = HashMap::new();
        for old in capacity..self.meta.len() {
            if self.meta[old].refs == 0 {
                continue;
            }
            let Some(dst) = self.free.pop() else { break };
            self.meta[dst] = std::mem::take(&mut self.meta[old]);
            if let Some(h) = self.meta[dst].hash {
                self.trie.insert(h, dst);
            }
            remap.insert(old, dst);
            self.defrag_moves += 1;
        }
        if !remap.is_empty() {
            for table in self.tables.values_mut() {
                for slot in table.pages.iter_mut() {
                    if let Some(&dst) = remap.get(slot) {
                        *slot = dst;
                    }
                }
            }
            // Parked sequences' resident prefixes hold refs too — the
            // defrag must carry them along or swap-in resurrects stale
            // ids.
            for sw in self.swapped.values_mut() {
                for slot in sw.resident.iter_mut() {
                    if let Some(&dst) = remap.get(slot) {
                        *slot = dst;
                    }
                }
            }
        }
    }

    /// Pages relocated by shrink-time defragmentation so far.
    pub fn defrag_moves(&self) -> u64 {
        self.defrag_moves
    }

    /// Lifetime (allocated, freed) physical page counts.
    pub fn alloc_counts(&self) -> (u64, u64) {
        (self.allocs, self.frees)
    }

    /// Full-state invariant check, for soak tests: refcounts equal the
    /// table references holding each page, device accounting closes
    /// (every dead in-bound id is on the free list exactly once, the
    /// live count matches `in_use`), the trie points only at live
    /// published pages, and the host swap space is within budget and
    /// consistent with the parked sequences. Returns the first
    /// violation as text.
    pub fn validate(&self) -> Result<(), String> {
        use std::collections::HashSet;
        // Reference counts: one per table slot (live tables + parked
        // residents).
        let mut refs = vec![0u32; self.meta.len()];
        for (seq, t) in &self.tables {
            for &pid in &t.pages {
                if pid >= self.meta.len() {
                    return Err(format!("seq {seq} references out-of-range page {pid}"));
                }
                refs[pid] += 1;
            }
        }
        for (seq, sw) in &self.swapped {
            for &pid in &sw.resident {
                if pid >= self.meta.len() {
                    return Err(format!(
                        "swapped seq {seq} references out-of-range page {pid}"
                    ));
                }
                refs[pid] += 1;
            }
        }
        let mut live = 0usize;
        for (pid, m) in self.meta.iter().enumerate() {
            if m.refs != refs[pid] {
                return Err(format!(
                    "page {pid}: refcount {} but {} table references",
                    m.refs, refs[pid]
                ));
            }
            if m.refs > 0 {
                live += 1;
            }
            // Sharing only ever originates from a published prefix
            // claim; CoW hands writers fresh private pages. A multiply
            // held page with no hash means a write landed on (or a
            // table slot leaked onto) a page another sequence can
            // observe.
            if m.refs > 1 && m.hash.is_none() {
                return Err(format!(
                    "page {pid} is held by {} sequences but was never published",
                    m.refs
                ));
            }
        }
        if live != self.in_use {
            return Err(format!("in_use {} but {live} pages have holders", self.in_use));
        }
        // Free list: exactly the dead ids below the capacity bound.
        let free: HashSet<usize> = self.free.iter().copied().collect();
        if free.len() != self.free.len() {
            return Err("free list contains duplicates".into());
        }
        for &pid in &self.free {
            if pid >= self.capacity {
                return Err(format!("free id {pid} beyond capacity {}", self.capacity));
            }
            if self.meta[pid].refs > 0 {
                return Err(format!("page {pid} is both free and held"));
            }
        }
        for pid in 0..self.capacity.min(self.meta.len()) {
            if self.meta[pid].refs == 0 && !free.contains(&pid) {
                return Err(format!("dead in-bound page {pid} is not on the free list"));
            }
        }
        // Trie: every entry is a live page carrying that hash.
        for (&h, &pid) in &self.trie {
            if pid >= self.meta.len() || self.meta[pid].refs == 0 {
                return Err(format!("trie hash {h:#x} points at dead page {pid}"));
            }
            if self.meta[pid].hash != Some(h) {
                return Err(format!("trie hash {h:#x} disagrees with page {pid} meta"));
            }
        }
        // Host swap space: per-seq host pages sum to the aggregate and
        // fit the budget.
        let parked: usize = self.swapped.values().map(|s| s.host_pages).sum();
        if parked != self.swapped_pages {
            return Err(format!(
                "swapped_pages {} but parked sequences hold {parked}",
                self.swapped_pages
            ));
        }
        if self.swapped_pages > self.swap_capacity + self.swap_overcommit {
            return Err(format!(
                "swap space over budget: {} > {} (+{} stranded by a shrink)",
                self.swapped_pages, self.swap_capacity, self.swap_overcommit
            ));
        }
        // A sequence is either live or parked, never both.
        for seq in self.swapped.keys() {
            if self.tables.contains_key(seq) {
                return Err(format!("seq {seq} is both live and swapped"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_for_rounds_up() {
        let p = KvPool::new(8, 16);
        assert_eq!(p.pages_for(1), 1);
        assert_eq!(p.pages_for(16), 1);
        assert_eq!(p.pages_for(17), 2);
        assert_eq!(p.pages_for(0), 1, "empty context still needs a page");
    }

    #[test]
    fn grow_is_incremental_and_all_or_nothing() {
        let mut p = KvPool::new(4, 16);
        p.grow_to(1, 20).unwrap(); // 2 pages
        assert_eq!(p.in_use(), 2);
        assert_eq!(p.pages_of(1).len(), 2);
        // Growing within the held pages is free.
        p.grow_to(1, 30).unwrap();
        assert_eq!(p.in_use(), 2);
        // A second sequence takes the rest.
        p.grow_to(2, 32).unwrap();
        assert_eq!(p.free_pages(), 0);
        // Next growth fails atomically with the exact shortfall.
        assert_eq!(p.grow_to(1, 33), Err(PagesShort(1)));
        assert_eq!(p.pages_of(1).len(), 2, "failed grow must not allocate");
        assert_eq!(p.in_use(), 4);
    }

    #[test]
    fn grow_by_and_rollback_round_trip_draft_slack() {
        let mut p = KvPool::new(8, 16);
        p.grow_to(1, 20).unwrap(); // 2 pages, frontier 20
        p.grow_by(1, 12).unwrap(); // frontier 32, still 2 pages
        assert_eq!(p.pages_of(1).len(), 2);
        p.grow_by(1, 8).unwrap(); // frontier 40, 3 pages
        assert_eq!(p.pages_of(1).len(), 3);
        // Reject the whole draft: back to the verified frontier.
        p.rollback_to(1, 20);
        assert_eq!(p.pages_of(1).len(), 2);
        assert_eq!(p.in_use(), 2);
        // Growing target / unknown seq are no-ops.
        p.rollback_to(1, 64);
        p.rollback_to(99, 1);
        assert_eq!(p.pages_of(1).len(), 2);
        p.grow_to(1, 33).unwrap(); // frontier was rolled back to 20
        assert_eq!(p.pages_of(1).len(), 3);
        p.validate().unwrap();
    }

    #[test]
    fn rollback_preserves_shared_prefix_and_trie() {
        let pt = 16;
        let hashes = prompt_page_hashes(&vec![7; 24], pt); // full page + half page
        let mut p = KvPool::new(8, pt);
        p.grow_to(1, 24).unwrap();
        p.publish_prefix(1, &hashes);
        assert_eq!(p.claim_prefix(2, &hashes, 24), 24);
        // Seq 2 speculates 10 tokens past its prompt: the shared tail
        // page it appends into is CoW'd, plus one fresh page.
        p.grow_by(2, 10).unwrap();
        assert_eq!(p.pages_of(2).len(), 3);
        assert_eq!(p.cow_copies(), 1);
        // Everything rejected: rollback frees only the private tail;
        // the CoW'd page holds verified prompt context and stays.
        p.rollback_to(2, 24);
        assert_eq!(p.pages_of(2).len(), 2);
        // The publisher's pages and the trie are untouched: a third
        // sequence still claims the full prompt.
        assert_eq!(p.claim_prefix(3, &hashes, 24), 24);
        p.validate().unwrap();
        p.release(1);
        p.release(2);
        p.release(3);
        assert_eq!(p.in_use(), 0);
        p.validate().unwrap();
    }

    #[test]
    fn release_recycles_pages() {
        let mut p = KvPool::new(4, 16);
        p.grow_to(7, 64).unwrap(); // all 4 pages
        assert_eq!(p.peak_in_use(), 4);
        assert_eq!(p.release(7), 4);
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.free_pages(), 4);
        assert_eq!(p.release(7), 0, "double release is a no-op");
        p.grow_to(8, 64).unwrap();
        assert_eq!(p.in_use(), 4);
        assert_eq!(p.peak_in_use(), 4);
    }

    // ---- Prefill→decode migration at the pool level ----
    //
    // A handoff is two pool operations: the source releases the
    // departing sequence (private pages recycle, shared pages stay
    // resident for surviving claimants), and the destination re-claims
    // any locally published prefix before allocating only the private
    // remainder — the page count the interconnect transfer is charged
    // for.

    #[test]
    fn migration_source_release_recycles_private_pages_in_one_call() {
        let mut p = KvPool::new(32, 16);
        p.grow_to(1, 193).unwrap(); // whole-prompt admission: 13 pages
        p.grow_to(1, 194).unwrap(); // the first token fits the tail page
        assert_eq!(p.pages_of(1).len(), 13);
        assert_eq!(p.release(1), 13, "the handoff frees the full table at once");
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.free_pages(), 32);
        p.validate().unwrap();
    }

    #[test]
    fn migration_source_release_keeps_shared_pages_for_groupmates() {
        let pt = 16;
        let hashes = prompt_page_hashes(&vec![7; 64], pt); // 4 full pages
        let mut p = KvPool::new(16, pt);
        p.grow_to(1, 64).unwrap();
        p.publish_prefix(1, &hashes);
        assert_eq!(
            p.claim_prefix(2, &hashes, 64),
            64,
            "a groupmate claims the whole published prompt"
        );
        // Seq 1 hands off: its pages decref but must stay resident —
        // the migrating sequence does not strand its groupmate.
        assert_eq!(p.release(1), 0, "shared pages with a live claimant must not free");
        assert_eq!(p.in_use(), 4);
        assert!(p.holds(2));
        for &pid in p.pages_of(2) {
            assert_eq!(p.page_refs(pid), 1);
        }
        // The last holder leaving frees them physically.
        assert_eq!(p.release(2), 4);
        assert_eq!(p.in_use(), 0);
        p.validate().unwrap();
    }

    #[test]
    fn migration_destination_claims_prefix_and_allocates_only_the_remainder() {
        // The decode pool already serves a groupmate with the same
        // 3-page published prefix; a migrated-in sequence (64 prompt
        // tokens + 1 generated) claims those pages locally and
        // allocates only the private remainder — exactly the pages the
        // interconnect transfer is billed for.
        let pt = 16;
        let hashes = prompt_page_hashes(&vec![7; 64], pt);
        let mut p = KvPool::new(16, pt);
        p.grow_to(10, 48).unwrap();
        p.publish_prefix(10, &hashes[..3]);
        let before = p.in_use();
        assert_eq!(p.claim_prefix(11, &hashes, 64), 48, "3 shared pages re-claimed");
        p.grow_to(11, 65).unwrap();
        assert_eq!(p.pages_of(11).len(), 5);
        assert_eq!(
            p.in_use() - before,
            2,
            "only the private remainder allocates (= pages pulled over the link)"
        );
        p.validate().unwrap();
    }

    #[test]
    fn migration_churn_conserves_the_pool() {
        // Admission/handoff churn across rounds: every release returns
        // what the growth took, the free list and tables stay
        // consistent, and nothing leaks.
        let mut p = KvPool::new(64, 16);
        for round in 0u64..8 {
            for s in 0u64..4 {
                p.grow_to(round * 4 + s, 100 + (s as usize) * 17).unwrap();
            }
            // Two sequences hand off mid-round, two more admit behind
            // them, then the round drains.
            p.release(round * 4);
            p.release(round * 4 + 1);
            p.grow_to(1000 + round, 200).unwrap();
            p.release(round * 4 + 2);
            p.release(round * 4 + 3);
            p.release(1000 + round);
            assert_eq!(p.in_use(), 0, "round {round} leaked pages");
            assert_eq!(p.free_pages(), 64);
            p.validate().unwrap();
        }
    }

    #[test]
    fn page_tables_are_disjoint_without_sharing() {
        let mut p = KvPool::new(6, 8);
        p.grow_to(1, 24).unwrap();
        p.grow_to(2, 24).unwrap();
        let mut all: Vec<usize> = p.pages_of(1).to_vec();
        all.extend_from_slice(p.pages_of(2));
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "no page may be shared without a claim");
        assert!(all.iter().all(|&id| id < 6));
    }

    #[test]
    fn resize_up_adds_fresh_pages() {
        let mut p = KvPool::new(2, 16);
        p.grow_to(1, 32).unwrap();
        assert_eq!(p.grow_to(1, 33), Err(PagesShort(1)));
        p.resize(4);
        assert_eq!(p.capacity(), 4);
        p.grow_to(1, 33).unwrap();
        assert_eq!(p.in_use(), 3);
    }

    #[test]
    fn resize_down_defrags_live_tables() {
        let mut p = KvPool::new(8, 16);
        p.grow_to(1, 16 * 2).unwrap();
        p.grow_to(2, 16 * 4).unwrap();
        p.release(1); // frees low ids, seq 2 likely holds some high ids
        p.resize(4);
        assert_eq!(p.capacity(), 4);
        assert!(p.pages_of(2).iter().all(|&id| id < 4), "tables must be defragged into range");
        assert_eq!(p.in_use(), 4);
        // Fully occupied at the new bound: nothing more fits.
        assert!(p.grow_to(3, 1).is_err());
    }

    #[test]
    fn overcommitted_pool_blocks_allocs_until_drain() {
        let mut p = KvPool::new(8, 16);
        p.grow_to(1, 16 * 6).unwrap();
        p.resize(2); // usage (6) > capacity (2): over-committed
        assert!(p.in_use() > p.capacity());
        assert!(p.grow_to(2, 1).is_err());
        p.release(1);
        assert_eq!(p.in_use(), 0);
        p.grow_to(2, 1).unwrap();
        assert!(p.in_use() <= p.capacity());
    }

    #[test]
    fn accounting_counters_track_traffic() {
        let mut p = KvPool::new(4, 16);
        p.grow_to(1, 32).unwrap();
        p.release(1);
        let (a, f) = p.alloc_counts();
        assert_eq!((a, f), (2, 2));
        assert_eq!(p.defrag_moves(), 0);
        assert_eq!(p.shared_claims(), 0);
        assert_eq!(p.cow_copies(), 0);
    }

    // ---- Prefix sharing / CoW ----

    fn prompt(seed: i32, len: usize) -> Vec<i32> {
        (0..len as i32).map(|i| seed.wrapping_mul(131).wrapping_add(i)).collect()
    }

    #[test]
    fn page_hashes_chain_over_prefixes() {
        let a = prompt(1, 48);
        let mut b = a.clone();
        b[40] += 1; // diverge inside page 2
        let ha = prompt_page_hashes(&a, 16);
        let hb = prompt_page_hashes(&b, 16);
        assert_eq!(ha.len(), 3);
        assert_eq!(ha[0], hb[0]);
        assert_eq!(ha[1], hb[1]);
        assert_ne!(ha[2], hb[2], "divergent page must change the chain");
        // Different lengths in the tail page also differ.
        let hc = prompt_page_hashes(&a[..40], 16);
        assert_eq!(hc[0..2], ha[0..2]);
        assert_ne!(hc[2], ha[2]);
    }

    #[test]
    fn claim_maps_shared_pages_without_allocation() {
        let mut p = KvPool::new(16, 16);
        let tokens = prompt(3, 64); // 4 full pages
        let hashes = prompt_page_hashes(&tokens, 16);
        p.grow_to(1, 64).unwrap();
        p.publish_prefix(1, &hashes);
        assert_eq!(p.trie_len(), 4);
        let claimed = p.claim_prefix(2, &hashes, 64);
        assert_eq!(claimed, 64, "identical prompt claims every page");
        assert_eq!(p.in_use(), 4, "sharing allocates nothing");
        assert_eq!(p.pages_of(2), p.pages_of(1));
        assert_eq!(p.shared_claims(), 4);
        // Partial prefix (first 2 pages) claims only the shared span.
        let mut other = tokens.clone();
        other[40] = -7;
        let oh = prompt_page_hashes(&other, 16);
        assert_eq!(p.claim_prefix(3, &oh, 64), 32);
        assert_eq!(p.pages_of(3), &p.pages_of(1)[..2]);
    }

    #[test]
    fn retracted_claims_do_not_inflate_accounting() {
        // A congested head may claim and immediately retract for many
        // ticks; only claims that stick may count.
        let mut p = KvPool::new(8, 16);
        let tokens = prompt(6, 32);
        let hashes = prompt_page_hashes(&tokens, 16);
        p.grow_to(1, 32).unwrap();
        p.publish_prefix(1, &hashes);
        for _ in 0..5 {
            p.claim_prefix(2, &hashes, 32);
            p.retract_claim(2);
        }
        assert_eq!(p.shared_claims(), 0, "retracted claims must not count");
        assert!(!p.holds(2));
        assert_eq!(p.in_use(), 2, "only the publisher's pages remain");
        p.claim_prefix(3, &hashes, 32);
        assert_eq!(p.shared_claims(), 2, "a claim that sticks counts once");
    }

    #[test]
    fn cow_fires_on_first_divergent_write() {
        let mut p = KvPool::new(16, 16);
        let tokens = prompt(5, 40); // 2 full pages + 8-token tail
        let hashes = prompt_page_hashes(&tokens, 16);
        p.grow_to(1, 40).unwrap();
        p.publish_prefix(1, &hashes);
        let claimed = p.claim_prefix(2, &hashes, 40);
        assert_eq!(claimed, 40);
        let shared_tail = p.pages_of(2)[2];
        assert_eq!(shared_tail, p.pages_of(1)[2]);
        // Seq 2 appends its first divergent token into the partial
        // shared tail page: CoW must give it a private copy.
        p.grow_to(2, 41).unwrap();
        assert_eq!(p.cow_copies(), 1);
        assert_ne!(p.pages_of(2)[2], shared_tail, "writer must diverge onto a copy");
        assert_eq!(p.pages_of(1)[2], shared_tail, "the publisher keeps the original");
        // Full shared pages are never copied: growth past them appends.
        p.grow_to(2, 60).unwrap();
        assert_eq!(p.cow_copies(), 1);
    }

    #[test]
    fn refcounts_keep_shared_pages_alive_until_last_holder() {
        let mut p = KvPool::new(8, 16);
        let tokens = prompt(9, 32);
        let hashes = prompt_page_hashes(&tokens, 16);
        p.grow_to(1, 32).unwrap();
        p.publish_prefix(1, &hashes);
        p.claim_prefix(2, &hashes, 32);
        assert_eq!(p.release(1), 0, "shared pages outlive the publisher");
        assert_eq!(p.in_use(), 2);
        assert_eq!(p.trie_len(), 2, "claimable while any holder lives");
        // A third claimer can still ride the surviving holder's pages.
        assert_eq!(p.claim_prefix(3, &hashes, 32), 32);
        p.release(2);
        assert_eq!(p.release(3), 2, "last holder frees the pages");
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.trie_len(), 0, "trie never outlives its pages");
        assert_eq!(p.free_pages(), 8);
    }

    // ---- Host swap space ----

    #[test]
    fn swap_out_frees_private_pages_and_swap_in_restores_the_table() {
        let mut p = KvPool::new(8, 16);
        p.set_swap_capacity(16);
        p.grow_to(1, 40).unwrap(); // 3 pages, all private
        assert_eq!(p.swap_split(1), (0, 3));
        let moved = p.swap_out(1).unwrap();
        assert_eq!(moved, 3);
        assert!(p.is_swapped(1));
        assert!(!p.holds(1));
        assert_eq!(p.in_use(), 0, "private pages leave the device pool");
        assert_eq!(p.free_pages(), 8);
        assert_eq!(p.swapped_pages(), 3);
        // Another sequence can use the freed pages meanwhile.
        p.grow_to(2, 80).unwrap(); // 5 pages
        assert_eq!(p.in_use(), 5);
        // Swap-in restores the table at the checkpointed frontier.
        let back = p.swap_in(1).unwrap();
        assert_eq!(back, 3);
        assert!(p.holds(1) && !p.is_swapped(1));
        assert_eq!(p.pages_of(1).len(), 3);
        assert_eq!(p.swapped_pages(), 0);
        // Growing from the restored frontier is incremental.
        p.grow_to(1, 41).unwrap();
        assert_eq!(p.pages_of(1).len(), 3, "41 tokens still fit 3 pages");
        assert_eq!(p.swap_counts(), (1, 1, 6));
        p.validate().unwrap();
    }

    #[test]
    fn swap_budget_is_enforced_all_or_nothing() {
        let mut p = KvPool::new(8, 16);
        p.set_swap_capacity(2);
        p.grow_to(1, 48).unwrap(); // 3 private pages > budget 2
        assert_eq!(p.swap_out(1), Err(SwapShort(1)));
        assert!(p.holds(1), "failed swap-out must not touch the table");
        assert_eq!(p.in_use(), 3);
        assert_eq!(p.swapped_pages(), 0);
        p.grow_to(2, 32).unwrap(); // 2 pages: fits the budget
        assert_eq!(p.swap_out(2), Ok(2));
        // Budget full: nothing else parks.
        p.grow_to(3, 16).unwrap();
        assert_eq!(p.swap_out(3), Err(SwapShort(1)));
        p.validate().unwrap();
    }

    #[test]
    fn shared_prefix_stays_resident_across_swap() {
        let mut p = KvPool::new(16, 16);
        p.set_swap_capacity(16);
        let tokens = prompt(11, 48); // 3 pages
        let hashes = prompt_page_hashes(&tokens, 16);
        p.grow_to(1, 48).unwrap();
        p.publish_prefix(1, &hashes);
        p.claim_prefix(2, &hashes, 48);
        p.grow_to(2, 49).unwrap(); // CoW tail: 2 shared + 1 private? no —
                                   // 48 is 3 full pages; token 49 appends a 4th private page
        assert_eq!(p.swap_split(2), (3, 1));
        let moved = p.swap_out(2).unwrap();
        assert_eq!(moved, 1, "only the private tail rides to host");
        // The shared pages still serve claims (trie untouched) and the
        // parked holder's refs keep them alive.
        assert_eq!(p.trie_len(), 3);
        assert_eq!(p.claim_prefix(3, &hashes, 48), 48);
        assert_eq!(p.release(1), 0, "parked seq 2 still anchors the shared pages");
        p.release(3);
        assert_eq!(p.in_use(), 3, "resident prefix survives for the parked holder");
        // Swap-in rides the surviving shared pages and reallocates the
        // private tail only.
        assert_eq!(p.swap_in(2), Ok(1));
        assert_eq!(p.pages_of(2).len(), 4);
        assert_eq!(p.release(2), 4, "last holder frees shared and private alike");
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.trie_len(), 0);
        p.validate().unwrap();
    }

    #[test]
    fn swap_in_is_all_or_nothing_on_device_pressure() {
        let mut p = KvPool::new(4, 16);
        p.set_swap_capacity(8);
        p.grow_to(1, 48).unwrap(); // 3 pages
        p.swap_out(1).unwrap();
        p.grow_to(2, 32).unwrap(); // 2 of 4 pages: only 2 free
        assert_eq!(p.swap_in(1), Err(PagesShort(1)));
        assert!(p.is_swapped(1), "failed swap-in leaves the sequence parked");
        assert_eq!(p.swapped_pages(), 3);
        p.release(2);
        p.swap_in(1).unwrap();
        assert_eq!(p.in_use(), 3);
        p.validate().unwrap();
    }

    #[test]
    fn shrinking_the_swap_budget_below_usage_is_legal_but_blocks_outs() {
        let mut p = KvPool::new(8, 16);
        p.set_swap_capacity(8);
        p.grow_to(1, 64).unwrap(); // 4 pages
        p.swap_out(1).unwrap();
        // A hot-swap shrinks the budget under the parked pages: the
        // stranded state validates, but nothing else may park.
        p.set_swap_capacity(2);
        p.validate().unwrap();
        assert_eq!(p.swap_free(), 0);
        p.grow_to(2, 16).unwrap();
        assert_eq!(p.swap_out(2), Err(SwapShort(1)));
        // Draining back under the target re-opens the space.
        p.swap_in(1).unwrap();
        p.set_swap_capacity(2);
        assert_eq!(p.swap_free(), 2);
        p.swap_out(2).unwrap();
        p.validate().unwrap();
        p.release(1);
        p.release(2);
        assert_eq!(p.swapped_pages(), 0);
        p.validate().unwrap();
    }

    #[test]
    fn releasing_a_parked_sequence_drops_host_pages() {
        let mut p = KvPool::new(8, 16);
        p.set_swap_capacity(8);
        p.grow_to(1, 64).unwrap();
        p.swap_out(1).unwrap();
        assert_eq!(p.swapped_pages(), 4);
        assert_eq!(p.release(1), 0, "host pages are not device frees");
        assert!(!p.is_swapped(1));
        assert_eq!(p.swapped_pages(), 0, "retiring a parked seq frees its swap space");
        assert_eq!(p.free_pages(), 8);
        p.validate().unwrap();
    }

    #[test]
    fn defrag_remaps_parked_resident_prefixes() {
        let mut p = KvPool::new(8, 16);
        p.set_swap_capacity(8);
        p.grow_to(9, 48).unwrap(); // pin low ids 0..3
        let tokens = prompt(4, 32);
        let hashes = prompt_page_hashes(&tokens, 16);
        p.grow_to(1, 32).unwrap(); // high ids
        p.publish_prefix(1, &hashes);
        p.claim_prefix(2, &hashes, 32);
        p.grow_to(2, 33).unwrap(); // private 3rd page
        p.swap_out(2).unwrap(); // parks with a 2-page resident prefix
        p.release(9);
        p.resize(4); // forces the shared pages down into 0..4
        assert!(p.pages_of(1).iter().all(|&id| id < 4));
        p.validate().unwrap();
        // Swap-in must see the moved ids, not the stale ones.
        p.swap_in(2).unwrap();
        assert_eq!(&p.pages_of(2)[..2], p.pages_of(1), "resident prefix follows the move");
        p.validate().unwrap();
    }

    #[test]
    fn defrag_preserves_sharing_and_trie() {
        let mut p = KvPool::new(8, 16);
        p.grow_to(9, 48).unwrap(); // occupy low ids 0..3
        let tokens = prompt(4, 32);
        let hashes = prompt_page_hashes(&tokens, 16);
        p.grow_to(1, 32).unwrap(); // high-ish ids
        p.publish_prefix(1, &hashes);
        p.claim_prefix(2, &hashes, 32);
        p.release(9);
        p.resize(4); // forces the shared pages down into 0..4
        assert!(p.pages_of(1).iter().all(|&id| id < 4));
        assert_eq!(p.pages_of(1), p.pages_of(2), "sharing survives relocation");
        assert_eq!(p.trie_len(), 2);
        // The trie still resolves to the moved pages.
        let mut q = KvPool::new(4, 16); // sanity: independent pool unaffected
        assert_eq!(q.claim_prefix(1, &hashes, 32), 0);
        assert_eq!(p.claim_prefix(5, &hashes, 32), 32, "claims follow the move");
    }
}
