//! Paged KV-cache pool: fixed-size token pages with per-sequence page
//! tables.
//!
//! The pool is the memory model of the continuous-batching engine: a
//! replica's KV budget (derived from the [`crate::perf::ReplicaModel`]
//! memory terms, see [`crate::perf::ReplicaModel::kv_pages_total`]) is
//! carved into pages of `page_tokens` tokens, and every in-flight
//! sequence holds an explicit page list. Admission and per-iteration
//! growth go through all-or-nothing [`KvPool::grow_to`] calls, so the
//! scheduler always sees exact occupancy and can preempt instead of
//! overcommitting.
//!
//! Pages are identified by index so the page *tables* are real (the
//! shape a paged-attention kernel would consume), and shrinking the
//! pool defragments live tables down into the surviving id range with
//! explicit move accounting.

use std::collections::HashMap;

/// Engine-wide sequence identifier.
pub type SeqId = u64;

/// Allocation failure: the pool is `short` pages of satisfying the
/// request. Nothing was allocated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PagesShort(pub usize);

/// A pool of fixed-size KV pages with per-sequence page tables.
#[derive(Debug)]
pub struct KvPool {
    page_tokens: usize,
    capacity: usize,
    /// Unallocated page ids below `capacity` (LIFO free list).
    free: Vec<usize>,
    /// Per-sequence page tables, in allocation order.
    tables: HashMap<SeqId, Vec<usize>>,
    in_use: usize,
    peak_in_use: usize,
    allocs: u64,
    frees: u64,
    defrag_moves: u64,
}

impl KvPool {
    /// A pool of `capacity` pages of `page_tokens` tokens each (both
    /// clamped to at least 1).
    pub fn new(capacity: usize, page_tokens: usize) -> KvPool {
        let capacity = capacity.max(1);
        KvPool {
            page_tokens: page_tokens.max(1),
            capacity,
            free: (0..capacity).rev().collect(),
            tables: HashMap::new(),
            in_use: 0,
            peak_in_use: 0,
            allocs: 0,
            frees: 0,
            defrag_moves: 0,
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Target capacity in pages. After a shrink below current usage the
    /// pool is temporarily over-committed: `in_use` may exceed this
    /// until sequences retire, and no allocation succeeds meanwhile.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// High-water mark of pages simultaneously allocated.
    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Pages a context of `tokens` tokens occupies (at least 1).
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.page_tokens)
    }

    pub fn holds(&self, seq: SeqId) -> bool {
        self.tables.contains_key(&seq)
    }

    /// The sequence's page table (empty slice when unknown).
    pub fn pages_of(&self, seq: SeqId) -> &[usize] {
        self.tables.get(&seq).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Ensure `seq` holds enough pages for `tokens` tokens of context,
    /// allocating the shortfall. All-or-nothing: on `Err` nothing
    /// changed and the error carries the missing page count.
    pub fn grow_to(&mut self, seq: SeqId, tokens: usize) -> Result<(), PagesShort> {
        let need = self.pages_for(tokens);
        let have = self.tables.get(&seq).map(|t| t.len()).unwrap_or(0);
        if need <= have {
            return Ok(());
        }
        let shortfall = need - have;
        if shortfall > self.free.len() {
            return Err(PagesShort(shortfall - self.free.len()));
        }
        let table = self.tables.entry(seq).or_default();
        for _ in 0..shortfall {
            table.push(self.free.pop().expect("free list checked above"));
        }
        self.in_use += shortfall;
        self.allocs += shortfall as u64;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Ok(())
    }

    /// Release every page `seq` holds; returns the page count freed.
    /// Unknown sequences are a no-op (0).
    pub fn release(&mut self, seq: SeqId) -> usize {
        let Some(table) = self.tables.remove(&seq) else {
            return 0;
        };
        let n = table.len();
        for page in table {
            // Pages beyond a shrunk capacity leave the pool entirely.
            if page < self.capacity {
                self.free.push(page);
            }
        }
        self.in_use -= n;
        self.frees += n as u64;
        n
    }

    /// Retarget the pool to `capacity` pages.
    ///
    /// Growth adds fresh page ids. Shrinking drops free ids beyond the
    /// bound and defragments live page tables down into the surviving
    /// id range where free ids allow (each relocation counts as one
    /// `defrag_moves` — the copy a real allocator would perform). If
    /// usage exceeds the new capacity the pool runs over-committed:
    /// stranded high ids stay valid for their owners, and allocations
    /// fail until usage drops back under the target.
    pub fn resize(&mut self, capacity: usize) {
        let capacity = capacity.max(1);
        if capacity > self.capacity {
            // Ids stranded above the old bound by an earlier shrink may
            // still be held; only genuinely unowned ids become free.
            let held: std::collections::HashSet<usize> =
                self.tables.values().flatten().copied().collect();
            for id in self.capacity..capacity {
                if !held.contains(&id) {
                    self.free.push(id);
                }
            }
            self.capacity = capacity;
            return;
        }
        if capacity == self.capacity {
            return;
        }
        self.capacity = capacity;
        self.free.retain(|&id| id < capacity);
        // Defragment: relocate live pages with ids beyond the bound
        // onto surviving free ids.
        for table in self.tables.values_mut() {
            for slot in table.iter_mut() {
                if *slot >= capacity {
                    if let Some(dst) = self.free.pop() {
                        *slot = dst;
                        self.defrag_moves += 1;
                    }
                }
            }
        }
    }

    /// Pages relocated by shrink-time defragmentation so far.
    pub fn defrag_moves(&self) -> u64 {
        self.defrag_moves
    }

    /// Lifetime (allocated, freed) page counts.
    pub fn alloc_counts(&self) -> (u64, u64) {
        (self.allocs, self.frees)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_for_rounds_up() {
        let p = KvPool::new(8, 16);
        assert_eq!(p.pages_for(1), 1);
        assert_eq!(p.pages_for(16), 1);
        assert_eq!(p.pages_for(17), 2);
        assert_eq!(p.pages_for(0), 1, "empty context still needs a page");
    }

    #[test]
    fn grow_is_incremental_and_all_or_nothing() {
        let mut p = KvPool::new(4, 16);
        p.grow_to(1, 20).unwrap(); // 2 pages
        assert_eq!(p.in_use(), 2);
        assert_eq!(p.pages_of(1).len(), 2);
        // Growing within the held pages is free.
        p.grow_to(1, 30).unwrap();
        assert_eq!(p.in_use(), 2);
        // A second sequence takes the rest.
        p.grow_to(2, 32).unwrap();
        assert_eq!(p.free_pages(), 0);
        // Next growth fails atomically with the exact shortfall.
        assert_eq!(p.grow_to(1, 33), Err(PagesShort(1)));
        assert_eq!(p.pages_of(1).len(), 2, "failed grow must not allocate");
        assert_eq!(p.in_use(), 4);
    }

    #[test]
    fn release_recycles_pages() {
        let mut p = KvPool::new(4, 16);
        p.grow_to(7, 64).unwrap(); // all 4 pages
        assert_eq!(p.peak_in_use(), 4);
        assert_eq!(p.release(7), 4);
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.free_pages(), 4);
        assert_eq!(p.release(7), 0, "double release is a no-op");
        p.grow_to(8, 64).unwrap();
        assert_eq!(p.in_use(), 4);
        assert_eq!(p.peak_in_use(), 4);
    }

    #[test]
    fn page_tables_are_disjoint() {
        let mut p = KvPool::new(6, 8);
        p.grow_to(1, 24).unwrap();
        p.grow_to(2, 24).unwrap();
        let mut all: Vec<usize> = p.pages_of(1).to_vec();
        all.extend_from_slice(p.pages_of(2));
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "no page may be shared");
        assert!(all.iter().all(|&id| id < 6));
    }

    #[test]
    fn resize_up_adds_fresh_pages() {
        let mut p = KvPool::new(2, 16);
        p.grow_to(1, 32).unwrap();
        assert_eq!(p.grow_to(1, 33), Err(PagesShort(1)));
        p.resize(4);
        assert_eq!(p.capacity(), 4);
        p.grow_to(1, 33).unwrap();
        assert_eq!(p.in_use(), 3);
    }

    #[test]
    fn resize_down_defrags_live_tables() {
        let mut p = KvPool::new(8, 16);
        p.grow_to(1, 16 * 2).unwrap();
        p.grow_to(2, 16 * 4).unwrap();
        p.release(1); // frees low ids, seq 2 likely holds some high ids
        p.resize(4);
        assert_eq!(p.capacity(), 4);
        assert!(p.pages_of(2).iter().all(|&id| id < 4), "tables must be defragged into range");
        assert_eq!(p.in_use(), 4);
        // Fully occupied at the new bound: nothing more fits.
        assert!(p.grow_to(3, 1).is_err());
    }

    #[test]
    fn overcommitted_pool_blocks_allocs_until_drain() {
        let mut p = KvPool::new(8, 16);
        p.grow_to(1, 16 * 6).unwrap();
        p.resize(2); // usage (6) > capacity (2): over-committed
        assert!(p.in_use() > p.capacity());
        assert!(p.grow_to(2, 1).is_err());
        p.release(1);
        assert_eq!(p.in_use(), 0);
        p.grow_to(2, 1).unwrap();
        assert!(p.in_use() <= p.capacity());
    }

    #[test]
    fn accounting_counters_track_traffic() {
        let mut p = KvPool::new(4, 16);
        p.grow_to(1, 32).unwrap();
        p.release(1);
        let (a, f) = p.alloc_counts();
        assert_eq!((a, f), (2, 2));
        assert_eq!(p.defrag_moves(), 0);
    }
}
