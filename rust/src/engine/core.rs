//! The continuous-batching execution core: one engine per worker
//! thread, driving its tier backend at decode-iteration granularity.
//!
//! [`EngineCore`] replaces the serving engine's whole-batch inner loop
//! (see [`crate::coordinator::server`]): requests are submitted at any
//! time, every [`EngineCore::step`] call runs ONE iteration planned by
//! the [`IterationScheduler`] against the paged [`KvPool`], and
//! finished sequences come back with their full output. Short requests
//! no longer wait for long batchmates, and the KV budget is enforced
//! token-by-token instead of as a static request count.
//!
//! Prefill is **chunked**: the scheduler slices a prompt into
//! `prefill_chunk`-token pieces interleaved with decode iterations, so
//! a long prompt never stalls the whole batch for its full prefill
//! (the Sarathi discipline, now real instead of approximated). Prompts
//! whose prefix pages are already resident — shared system prompts,
//! same-prompt retries, cascade re-serves at a deeper tier — claim
//! those pages from the pool's prefix trie and prefill only the
//! remainder; a full-prompt hit skips the backend's prefill entirely
//! and decodes its first token immediately (the prefix-hit fast path).
//!
//! Backends plug in behind the existing
//! [`TierBackend`](crate::coordinator::server::TierBackend) trait. A
//! backend that can step token-by-token exposes a [`StepBackend`]
//! through `TierBackend::step_backend` (the calibrated simulated
//! backends do — their decode cost is
//! [`crate::perf::ReplicaModel::decode_iteration`] at the live batch
//! size). A whole-request backend is adapted transparently: its
//! `generate` runs when prefill completes and the engine releases the
//! cached tokens one iteration at a time, so KV-page accounting,
//! admission order, and preemption behave identically either way
//! (prefix sharing is disabled for adapted backends — they recompute
//! whole requests and cannot reuse resident KV).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::server::TierBackend;
use crate::obs::{emit_plan_events, emit_spec_events, EngineTracer, SpecResult};
use crate::perf::{ReplicaModel, DEFAULT_PREFILL_CHUNK};

use super::kv::{prompt_page_hashes, KvPool, SeqId};
use super::migrate::MigratedSeq;
use super::scheduler::{EngineRole, IterationScheduler, PreemptionConfig, PreemptionMode};

/// Iteration-granular generation interface. One instance per worker,
/// obtained through `TierBackend::step_backend`.
pub trait StepBackend {
    /// Process one prefill chunk of a sequence's prompt. Chunks of one
    /// sequence arrive in order across iterations; `last` marks the
    /// chunk completing the prompt, which must return the first
    /// generated token (`Some`). A preempted sequence is prefilled
    /// again from the start on re-admission (recompute semantics).
    ///
    /// A sequence admitted through a full prefix hit (its prompt's KV
    /// pages are shared-resident) receives NO prefill call at all —
    /// its first token comes from [`StepBackend::decode`].
    fn prefill_chunk(&mut self, seq: SeqId, chunk: &[i32], last: bool)
        -> Result<Option<i32>>;

    /// Advance every listed sequence one decode token; returns exactly
    /// one token per sequence, in order. `seqs.len()` is the live
    /// batch size — cost models key off it.
    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>>;

    /// Drop all state for `seq` (completed or recompute-preempted).
    fn release(&mut self, seq: SeqId);

    /// Notification that `pages` KV pages of `seq` moved across PCIe
    /// (`to_host` = swap-out; otherwise swap-in). The sequence's state
    /// is NOT dropped — it resumes from its checkpoint. Calibrated
    /// backends charge `pages ×` the replica's per-page swap time
    /// here; the default is a no-op.
    fn swap(&mut self, seq: SeqId, pages: usize, to_host: bool) {
        let _ = (seq, pages, to_host);
    }

    /// Notification that `seq` arrived by prefill→decode migration with
    /// `pages` private KV pages moved over the replica-pair
    /// interconnect (shared prefix pages re-claimed locally and are not
    /// counted). Fired once, on the DECODE side, at admission — the
    /// one-way transit cost lands on the engine that waits for it.
    /// Calibrated backends charge
    /// [`crate::perf::ReplicaModel::migrate_seconds`] here; the default
    /// is a no-op.
    fn migrate(&mut self, seq: SeqId, pages: usize) {
        let _ = (seq, pages);
    }

    /// Draft up to `k` speculative tokens for `seq` past its verified
    /// context using the cheap draft model of a cross-tier pair. `None`
    /// (the default) means the backend cannot draft — the engine falls
    /// back to a plain decode step for the sequence, so speculation
    /// degrades, never breaks.
    fn draft(&mut self, seq: SeqId, k: usize) -> Result<Option<Vec<i32>>> {
        let _ = (seq, k);
        Ok(None)
    }

    /// Verify a draft for `seq` in ONE deep-model step. Returns how
    /// many leading draft tokens the verify model agrees with and the
    /// verify model's own next token after the accepted prefix; the
    /// emitted stream is `draft[..accepted]` + `next` — every token the
    /// verify model would have produced decoding alone, which is the
    /// losslessness contract. `None` (the default) declines to verify
    /// and the engine falls back to a plain decode step.
    fn verify(&mut self, seq: SeqId, draft: &[i32]) -> Result<Option<VerifyOutcome>> {
        let _ = (seq, draft);
        Ok(None)
    }
}

/// Result of one speculative verify step ([`StepBackend::verify`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VerifyOutcome {
    /// Leading draft tokens the verify model reproduced exactly.
    pub accepted: usize,
    /// The verify model's own token following the accepted prefix
    /// (the "bonus" token — emitted even when `accepted == 0`, so a
    /// verify step always produces at least one token).
    pub next: i32,
}

/// Sizing of one worker's engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineConfig {
    /// KV pages in this replica's pool.
    pub pool_pages: usize,
    /// Tokens per KV page.
    pub page_tokens: usize,
    /// Request-count bound on the running batch (on top of the page
    /// bound).
    pub max_running: usize,
    /// Prefill tokens charged into any one iteration (`usize::MAX` =
    /// whole-prompt admission, the pre-chunking discipline).
    pub prefill_chunk: usize,
    /// Publish/claim prompt pages through the pool's prefix trie.
    pub share_prefixes: bool,
    /// Eviction discipline + the cost terms of its per-victim choice
    /// (default: recompute, no host swap space).
    pub preemption: PreemptionConfig,
}

impl PreemptionConfig {
    /// Swap-aware preemption sized from a replica's cost model: the
    /// host swap budget in pages, the PCIe per-page move time, and the
    /// recompute (prefill) rate the per-victim choice compares it to.
    pub fn from_replica(
        rm: &ReplicaModel,
        page_tokens: usize,
        mode: PreemptionMode,
    ) -> PreemptionConfig {
        PreemptionConfig {
            mode,
            swap_pages: rm.swap_pages_total(page_tokens),
            prefill_s_per_token: rm.prefill_seconds_per_token(),
            swap_s_per_page: rm.page_swap_seconds(page_tokens),
            page_bytes: rm.kv_page_bytes(page_tokens),
        }
    }
}

impl EngineConfig {
    /// Pool sizing for one replica of the given design: the page count
    /// its KV memory budget holds
    /// ([`ReplicaModel::kv_pages_total`]) and its request-count batch
    /// bound ([`ReplicaModel::max_batch`]). Chunked prefill and prefix
    /// sharing are on by default.
    pub fn for_replica(rm: &ReplicaModel, page_tokens: usize) -> EngineConfig {
        EngineConfig {
            pool_pages: rm.kv_pages_total(page_tokens).max(1),
            page_tokens: page_tokens.max(1),
            max_running: rm.max_batch.max(1),
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            share_prefixes: true,
            preemption: PreemptionConfig::default(),
        }
    }

    /// [`EngineConfig::for_replica`] with the eviction discipline set
    /// and its swap budget/cost terms derived from the same replica
    /// model — what [`crate::coordinator::server::ServerConfig`] builds
    /// from a plan's preemption knob.
    pub fn for_replica_with_preemption(
        rm: &ReplicaModel,
        page_tokens: usize,
        mode: PreemptionMode,
    ) -> EngineConfig {
        EngineConfig {
            preemption: PreemptionConfig::from_replica(rm, page_tokens, mode),
            ..EngineConfig::for_replica(rm, page_tokens)
        }
    }

    /// Nominal sizing for a tier with no scheduled deployment (the
    /// policy routes no steady-state traffic there, but skip targets
    /// must exist): room for a handful of full-length sequences.
    pub fn nominal(page_tokens: usize) -> EngineConfig {
        let pt = page_tokens.max(1);
        EngineConfig {
            // 16 sequences of 8192 tokens.
            pool_pages: (16usize * 8192).div_ceil(pt),
            page_tokens: pt,
            max_running: 16,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            share_prefixes: true,
            preemption: PreemptionConfig::default(),
        }
    }
}

/// A completed request leaving the engine.
#[derive(Debug)]
pub struct Finished<T> {
    pub payload: T,
    pub output: Vec<i32>,
    /// Seconds from first admission into the running batch to
    /// completion (co-running residence, not exclusive compute).
    pub exec_seconds: f64,
    /// Seconds from submission into the engine to the first generated
    /// token (queue wait + chunked prefill — the TTFT the chunk budget
    /// trades against).
    pub ttft_seconds: f64,
    /// Absolute instant of the first generated token, for end-to-end
    /// TTFT accounting upstream.
    pub first_token_at: Option<Instant>,
}

/// What one [`EngineCore::step`] did.
#[derive(Debug)]
pub struct StepOutcome<T> {
    pub completed: Vec<Finished<T>>,
    /// KV pages allocated at the iteration's high-water point.
    pub pages_in_use: usize,
    /// Sequences occupying a batch slot this iteration (decoding or
    /// prefilling).
    pub batch: usize,
    /// Sequences preempted-with-recompute this iteration.
    pub preempted: usize,
    /// Sequences swapped out to host this iteration (their progress is
    /// checkpointed, not recomputed).
    pub swap_outs: usize,
    /// Sequences resumed from host swap this iteration.
    pub swap_ins: usize,
    /// KV pages moved across PCIe this iteration (both directions).
    pub swap_pages: usize,
    /// Forced pool expansions this iteration (0 unless the pool is
    /// smaller than a single sequence).
    pub forced_expansions: usize,
    /// Prompt tokens of prefill work processed this iteration.
    pub prefill_tokens: usize,
    /// Prompt tokens newly served from shared prefix pages this
    /// iteration (no prefill owed for them).
    pub prefix_hit_tokens: usize,
    /// Pages newly claimed through the prefix trie this iteration.
    pub shared_claims: usize,
    /// Copy-on-write page copies performed this iteration.
    pub cow_copies: usize,
    /// Sequences handed off to a decode-role engine this iteration
    /// (prefill-role engines only). The caller routes them through the
    /// tier's [`crate::engine::MigrationHub`]; each carries its private
    /// page count for transit accounting.
    pub migrated_out: Vec<MigratedSeq<T>>,
    /// Migrated sequences admitted into the running batch this
    /// iteration (decode-role engines only).
    pub migrated_in: usize,
    /// Private KV pages moved by migration this iteration, both
    /// directions (out on prefill-role engines, in on decode-role).
    pub migrate_pages: usize,
    /// Draft tokens the verify model accepted this iteration (each one
    /// a decode iteration the deep model did not run).
    pub spec_accepted: usize,
    /// Draft tokens rejected this iteration (their slack pages already
    /// rolled back).
    pub spec_rejected: usize,
}

#[derive(Debug)]
struct SeqData<T> {
    payload: T,
    prompt: Vec<i32>,
    max_new: usize,
    output: Vec<i32>,
    /// Remaining whole-request tokens when the backend is adapted
    /// (None for native step backends).
    cached: Option<VecDeque<i32>>,
    /// Prompt page hashes (kept when prefix sharing is on) so a
    /// prefill→decode handoff ships them instead of rehashing.
    hashes: Option<Arc<Vec<u64>>>,
    submitted_at: Instant,
    admitted_at: Option<Instant>,
    first_token_at: Option<Instant>,
    /// Global request id stamped on trace events (defaults to the
    /// engine-local sequence id when the caller supplies none).
    trace_key: u64,
}

/// Engine invariant: every id the iteration scheduler hands back refers
/// to a sequence this engine submitted and has not yet retired. A miss
/// means the scheduler's and the engine's bookkeeping diverged — the
/// batch state is unrecoverable, so panic with the id and phase instead
/// of serving wrong tokens.
fn known<V>(entry: Option<V>, id: SeqId, phase: &str) -> V {
    match entry {
        Some(v) => v,
        None => panic!("engine invariant violated: {phase} for unknown sequence {id}"),
    }
}

/// The per-worker continuous-batching engine. `T` is the caller's
/// per-request payload, returned untouched on completion.
pub struct EngineCore<T> {
    backend: Box<dyn TierBackend>,
    sched: IterationScheduler,
    data: HashMap<SeqId, SeqData<T>>,
    next_id: SeqId,
    iterations: u64,
    page_tokens: usize,
    share_prefixes: bool,
    /// Optional trace emitter: every step's plan becomes events, and
    /// (when this tracer is the terminal authority) every retirement
    /// emits `finished`. None = tracing off, zero overhead.
    tracer: Option<EngineTracer>,
}

impl<T> EngineCore<T> {
    pub fn new(backend: Box<dyn TierBackend>, cfg: EngineConfig) -> EngineCore<T> {
        let pool = KvPool::new(cfg.pool_pages.max(1), cfg.page_tokens.max(1));
        let mut sched = IterationScheduler::new(pool, cfg.max_running.max(1));
        sched.set_prefill_chunk(cfg.prefill_chunk);
        sched.set_preemption(cfg.preemption);
        EngineCore {
            backend,
            sched,
            data: HashMap::new(),
            next_id: 0,
            iterations: 0,
            page_tokens: cfg.page_tokens.max(1),
            share_prefixes: cfg.share_prefixes,
            tracer: None,
        }
    }

    /// Attach (or detach) a trace emitter. Safe to call between steps;
    /// events start/stop at the next iteration boundary.
    pub fn set_tracer(&mut self, tracer: Option<EngineTracer>) {
        self.tracer = tracer;
    }

    /// Queue a request; it joins the running batch at a later
    /// iteration boundary, when its prompt's pages fit.
    pub fn submit(&mut self, payload: T, prompt: Vec<i32>, max_new: usize) {
        self.submit_with_prefix(payload, prompt, max_new, None);
    }

    /// Like [`EngineCore::submit`], reusing prompt page hashes computed
    /// upstream (they must be chained at THIS engine's page size —
    /// escalation carries them tier to tier so deeper-tier re-serves
    /// claim shared pages without rehashing). `None` hashes are
    /// computed here when sharing is on.
    pub fn submit_with_prefix(
        &mut self,
        payload: T,
        prompt: Vec<i32>,
        max_new: usize,
        hashes: Option<Arc<Vec<u64>>>,
    ) {
        let key = self.next_id as u64;
        self.submit_traced(payload, prompt, max_new, hashes, key);
    }

    /// Like [`EngineCore::submit_with_prefix`], stamping `trace_key`
    /// (the GLOBAL request id) on this sequence's trace events — the
    /// cascade passes the request index here so escalation chains stay
    /// id-linked across per-tier engines.
    pub fn submit_traced(
        &mut self,
        payload: T,
        prompt: Vec<i32>,
        max_new: usize,
        hashes: Option<Arc<Vec<u64>>>,
        trace_key: u64,
    ) {
        let id = self.next_id;
        self.next_id += 1;
        let max_new = max_new.max(1);
        // Prefix sharing needs a backend that can decode from resident
        // KV; adapted whole-request backends recompute regardless.
        let share = self.share_prefixes && self.backend.step_backend().is_some();
        let h_arc: Option<Arc<Vec<u64>>> = if share {
            Some(match hashes {
                Some(a) => a,
                None => Arc::new(prompt_page_hashes(&prompt, self.page_tokens)),
            })
        } else {
            None
        };
        let h: Vec<u64> = h_arc.as_ref().map(|a| (**a).clone()).unwrap_or_default();
        self.sched.enqueue_shared(id, prompt.len().max(1), max_new, h);
        self.data.insert(
            id,
            SeqData {
                payload,
                prompt,
                max_new,
                output: Vec::new(),
                cached: None,
                hashes: h_arc,
                submitted_at: Instant::now(),
                admitted_at: None,
                first_token_at: None,
                trace_key,
            },
        );
    }

    /// Accept a sequence handed off from a prefill-role engine: its
    /// prompt is already prefilled THERE (this engine owes no prefill
    /// work for it), its private pages arrive by modeled transit (the
    /// [`StepBackend::migrate`] hook fires at admission), and shared
    /// prefix pages re-claim through this pool's own trie from the
    /// carried hashes. It joins the running batch at the next iteration
    /// boundary with pages to hold prompt + generated + 1 tokens.
    pub fn submit_migrated(&mut self, m: MigratedSeq<T>) {
        let id = self.next_id;
        self.next_id += 1;
        let max_new = m.max_new.max(1);
        let share = self.share_prefixes && self.backend.step_backend().is_some();
        let h: Vec<u64> = if share {
            match &m.hashes {
                Some(a) => (**a).clone(),
                None => prompt_page_hashes(&m.prompt, self.page_tokens),
            }
        } else {
            Vec::new()
        };
        self.sched.enqueue_prefilled(id, m.prompt.len().max(1), m.output.len(), max_new, h);
        self.data.insert(
            id,
            SeqData {
                payload: m.payload,
                prompt: m.prompt,
                max_new,
                output: m.output,
                cached: m.cached,
                hashes: m.hashes,
                submitted_at: m.submitted_at,
                admitted_at: m.admitted_at,
                first_token_at: m.first_token_at,
                trace_key: m.trace_key,
            },
        );
    }

    /// Waiting + running sequences inside the engine.
    pub fn n_seqs(&self) -> usize {
        self.sched.n_seqs()
    }

    pub fn n_running(&self) -> usize {
        self.sched.n_running()
    }

    pub fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    /// Retarget the KV pool (the hot-swap lever): scale-up is
    /// immediate, scale-down takes effect as sequences retire.
    pub fn set_pool_pages(&mut self, pages: usize) {
        if pages.max(1) != self.sched.pool().capacity() {
            self.sched.resize_pool(pages);
        }
    }

    pub fn pool_pages(&self) -> usize {
        self.sched.pool().capacity()
    }

    pub fn peak_pages(&self) -> usize {
        self.sched.pool().peak_in_use()
    }

    /// Physical pages currently allocated (leak accounting).
    pub fn kv_in_use(&self) -> usize {
        self.sched.pool().in_use()
    }

    /// Pages currently on the free list (leak accounting).
    pub fn kv_free_pages(&self) -> usize {
        self.sched.pool().free_pages()
    }

    /// Published prefix pages currently claimable (leak accounting —
    /// 0 once every holder retires).
    pub fn kv_trie_len(&self) -> usize {
        self.sched.pool().trie_len()
    }

    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    pub fn preemptions(&self) -> u64 {
        self.sched.preemptions()
    }

    /// Lifetime (swap-outs, swap-ins, pages moved across PCIe both
    /// directions) of the swap-to-host policy.
    pub fn swap_counts(&self) -> (u64, u64, u64) {
        self.sched.swap_counts()
    }

    /// Enable speculative decoding with `k` draft tokens per task
    /// (0 disables it — the hot-swap lever). Only takes hold on native
    /// step backends; adapted whole-request backends replay cached
    /// tokens and gain nothing from drafting, so the knob is a no-op
    /// there. Safe to flip between steps: drafts never span an
    /// iteration, so no draft state is ever stranded.
    pub fn set_speculation(&mut self, k: usize) {
        let k = if self.backend.step_backend().is_some() { k } else { 0 };
        self.sched.set_spec_k(k);
    }

    /// Current draft tokens per speculative task (0 = off).
    pub fn speculation(&self) -> usize {
        self.sched.spec_k()
    }

    /// Lifetime (accepted, rejected) draft-token counts.
    pub fn spec_counts(&self) -> (u64, u64) {
        self.sched.spec_counts()
    }

    /// Tag this engine's disaggregation role. Prefill-role engines hand
    /// sequences off after their first token (while the tier's
    /// migration hub is open); decode-role engines admit them through
    /// [`EngineCore::submit_migrated`]. Unified (the default) does
    /// neither.
    pub fn set_role(&mut self, role: EngineRole) {
        self.sched.set_role(role);
    }

    pub fn role(&self) -> EngineRole {
        self.sched.role()
    }

    /// Gate the next step's handoffs (prefill role only): the worker
    /// loop mirrors the tier hub's backpressure here, so a closed hub
    /// degrades to local (unified) decode instead of queueing.
    pub fn set_migration_open(&mut self, open: bool) {
        self.sched.set_migration_open(open);
    }

    /// Migrated-in sequences waiting for pages (decode role).
    pub fn n_migrate_queued(&self) -> usize {
        self.sched.n_migrate_queued()
    }

    /// Lifetime (handoffs out, handoffs in, private pages out, private
    /// pages in) of prefill→decode migration on this engine.
    pub fn migrate_counts(&self) -> (u64, u64, u64, u64) {
        self.sched.migrate_counts()
    }

    /// Sequences currently parked in host swap space.
    pub fn n_swapped(&self) -> usize {
        self.sched.n_swapped()
    }

    /// Lifetime prompt tokens served from shared prefix pages.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.sched.prefix_hit_tokens()
    }

    /// Lifetime (pages claimed via the prefix trie, CoW copies).
    pub fn sharing_counts(&self) -> (u64, u64) {
        (self.sched.pool().shared_claims(), self.sched.pool().cow_copies())
    }

    /// Record a token (or early end-of-cache) for `id`; true when the
    /// sequence is finished.
    fn note_token(&mut self, id: SeqId, tok: Option<i32>) -> bool {
        match tok {
            Some(t) => {
                let cache_dry = {
                    let d = known(self.data.get_mut(&id), id, "token");
                    d.output.push(t);
                    if d.first_token_at.is_none() {
                        d.first_token_at = Some(Instant::now());
                    }
                    d.cached.as_ref().map(|c| c.is_empty()).unwrap_or(false)
                };
                let budget_done = self.sched.advance(id);
                budget_done || cache_dry
            }
            // The whole-request cache ran dry before this iteration:
            // the backend generated fewer than max_new tokens.
            None => true,
        }
    }

    /// Run ONE decode iteration: plan (retire/admit/preempt against the
    /// pool), process the tick's prefill chunks, advance the decoding
    /// batch one token, and collect finished sequences.
    ///
    /// An `Err` means the backend failed; the engine keeps every
    /// submitted request (none were completed this step) so the caller
    /// can [`EngineCore::drain`] them for re-dispatch — exactly-once
    /// completion is preserved.
    pub fn step(&mut self) -> Result<StepOutcome<T>> {
        let hits_before = self.sched.prefix_hit_tokens();
        let (claims_before, cows_before) =
            (self.sched.pool().shared_claims(), self.sched.pool().cow_copies());
        let plan = self.sched.next_iteration();
        let pages_in_use = self.sched.pool().in_use();

        // Trace the iteration plan before executing it: the emitted
        // sequence is a pure function of the plan, so a DES run over
        // the same scheduler produces the identical per-request event
        // stream (the DES↔live equivalence pin rides on this).
        if let Some(tr) = &self.tracer {
            let t = tr.clock.now();
            let data = &self.data;
            emit_plan_events(&tr.recorder, tr.shard, t, tr.tier, &plan, |id| {
                data.get(&id).map(|d| d.trace_key).unwrap_or(id as u64)
            });
        }

        // Migrated-out sequences have already left the scheduler (pages
        // released, running slot freed); package their state for the
        // decode-role destination and drop them here. The backend's
        // release mirrors retirement — on a prefill-role engine there
        // is no post-handoff work for the sequence.
        let mut migrated_out: Vec<MigratedSeq<T>> = Vec::with_capacity(plan.migrated_out.len());
        for &(id, pages) in &plan.migrated_out {
            if let Some(s) = self.backend.step_backend() {
                s.release(id);
            }
            let d = known(self.data.remove(&id), id, "migrate-out");
            migrated_out.push(MigratedSeq {
                payload: d.payload,
                prompt: d.prompt,
                output: d.output,
                max_new: d.max_new,
                hashes: d.hashes,
                pages,
                cached: d.cached,
                trace_key: d.trace_key,
                submitted_at: d.submitted_at,
                admitted_at: d.admitted_at,
                first_token_at: d.first_token_at,
            });
        }

        // Migrated-in admissions charge their one-way transit here (the
        // decode engine waits out the interconnect move before its
        // first local decode of the sequence).
        for &(id, pages) in &plan.migrated_in {
            if let Some(s) = self.backend.step_backend() {
                s.migrate(id, pages);
            }
        }

        // Recompute-preempted sequences lose engine and backend state;
        // they recompute from their prompt on re-admission.
        for &id in &plan.preempted {
            if let Some(d) = self.data.get_mut(&id) {
                d.output.clear();
                d.cached = None;
            }
            if let Some(s) = self.backend.step_backend() {
                s.release(id);
            }
        }

        // Swap-evicted sequences keep EVERYTHING — engine output,
        // whole-request cache, and backend state; the backend only
        // hears about the PCIe traffic. Resumed sequences likewise just
        // report the move back.
        for &(id, pages) in &plan.swapped_out {
            if let Some(s) = self.backend.step_backend() {
                s.swap(id, pages, true);
            }
        }
        for &(id, pages) in &plan.swapped_in {
            if let Some(s) = self.backend.step_backend() {
                s.swap(id, pages, false);
            }
        }

        let mut done_ids: Vec<SeqId> = Vec::new();

        // Prefill pass: each chunk advances its sequence's prompt; the
        // last chunk produces the first token.
        for chunk in &plan.prefill {
            let id = chunk.id;
            let prompt = {
                let d = known(self.data.get_mut(&id), id, "prefill");
                if d.admitted_at.is_none() {
                    d.admitted_at = Some(Instant::now());
                }
                std::mem::take(&mut d.prompt)
            };
            let end = (chunk.start + chunk.len).min(prompt.len().max(1));
            let piece = &prompt[chunk.start.min(prompt.len())..end.min(prompt.len())];
            // (probe-then-rebind: an `if let Some(s) = ...step_backend()`
            // would hold the borrow through an `else` that needs
            // `generate` on edition 2021)
            let native = self.backend.step_backend().is_some();
            let tok = if native {
                let Some(s) = self.backend.step_backend() else {
                    unreachable!("probed native above")
                };
                let t = s.prefill_chunk(id, piece, chunk.last)?;
                if chunk.last && t.is_none() {
                    anyhow::bail!("step backend returned no first token on final chunk");
                }
                t
            } else if chunk.last {
                let max_new = known(self.data.get(&id), id, "prefill").max_new;
                let full = self.backend.generate(&prompt, max_new)?;
                let mut dq: VecDeque<i32> = full.into_iter().collect();
                let first = dq.pop_front();
                known(self.data.get_mut(&id), id, "prefill").cached = Some(dq);
                // An empty generation finishes immediately (None).
                first
            } else {
                None
            };
            // The prompt is reused on preemption-recompute; put it back.
            known(self.data.get_mut(&id), id, "prefill").prompt = prompt;
            if chunk.last && self.note_token(id, tok) {
                done_ids.push(id);
            }
        }

        // Decode pass: every fully-prefilled sequence advances one
        // token. Full-prefix-hit admissions are in here too — their
        // first engine contact is a decode, never a prefill.
        if !plan.decode.is_empty() {
            for &id in &plan.decode {
                let d = known(self.data.get_mut(&id), id, "decode");
                if d.admitted_at.is_none() {
                    d.admitted_at = Some(Instant::now());
                }
            }
            let toks: Vec<Option<i32>> = if let Some(s) = self.backend.step_backend() {
                let v = s.decode(&plan.decode)?;
                if v.len() != plan.decode.len() {
                    anyhow::bail!(
                        "step backend returned {} tokens for a batch of {}",
                        v.len(),
                        plan.decode.len()
                    );
                }
                v.into_iter().map(Some).collect()
            } else {
                plan.decode
                    .iter()
                    .map(|&id| {
                        known(self.data.get_mut(&id), id, "decode")
                            .cached
                            .as_mut()
                            .and_then(|c| c.pop_front())
                    })
                    .collect()
            };
            for (&id, tok) in plan.decode.iter().zip(toks) {
                if self.note_token(id, tok) {
                    done_ids.push(id);
                }
            }
        }

        // Speculative pass: draft k tokens on the cheap model, verify
        // them in ONE deep-model step; the sequence emits the accepted
        // prefix plus the verifier's own next token — every emitted
        // token is a verify-model token, so the stream is bit-identical
        // to plain decoding (the losslessness contract). A backend that
        // declines to draft or verify degrades the task to one plain
        // decode token. Settled results are traced through the same
        // pure emitter the DES uses.
        let mut spec_accepted = 0usize;
        let mut spec_rejected = 0usize;
        let mut spec_results: Vec<SpecResult> = Vec::with_capacity(plan.spec.len());
        for task in &plan.spec {
            let id = task.id;
            {
                let d = known(self.data.get_mut(&id), id, "spec");
                if d.admitted_at.is_none() {
                    d.admitted_at = Some(Instant::now());
                }
            }
            let s = known(self.backend.step_backend(), id, "spec (adapted backend)");
            let drafted = s.draft(id, task.k)?.filter(|d| !d.is_empty());
            let verdict = match &drafted {
                Some(d) => s.verify(id, d)?,
                None => None,
            };
            let (tokens, accepted): (Vec<i32>, Option<usize>) = match (drafted, verdict) {
                (Some(d), Some(v)) => {
                    let a = v.accepted.min(d.len());
                    let mut out = d[..a].to_vec();
                    out.push(v.next);
                    (out, Some(a))
                }
                // Draft or verify unavailable: one plain decode token.
                (_, _) => {
                    let s = known(self.backend.step_backend(), id, "spec fallback");
                    let v = s.decode(&[id])?;
                    let Some(&tok) = v.first() else {
                        anyhow::bail!("step backend returned no token for a batch of 1");
                    };
                    (vec![tok], None)
                }
            };
            {
                let d = known(self.data.get_mut(&id), id, "spec token");
                d.output.extend_from_slice(&tokens);
                if d.first_token_at.is_none() {
                    d.first_token_at = Some(Instant::now());
                }
            }
            let drafted = if accepted.is_some() { task.k } else { 0 };
            spec_accepted += accepted.unwrap_or(0);
            spec_rejected += accepted.map(|a| task.k - a).unwrap_or(0);
            spec_results.push(SpecResult {
                id,
                drafted,
                accepted: accepted.unwrap_or(0),
                emitted: tokens.len(),
            });
            if self.sched.advance_spec(id, drafted, tokens.len()) {
                done_ids.push(id);
            }
        }
        if !spec_results.is_empty() {
            if let Some(tr) = &self.tracer {
                let t = tr.clock.now();
                let data = &self.data;
                emit_spec_events(
                    &tr.recorder,
                    tr.shard,
                    t,
                    tr.tier,
                    plan.batch(),
                    &spec_results,
                    |id| data.get(&id).map(|d| d.trace_key).unwrap_or(id as u64),
                );
            }
        }

        // Retire finished sequences: free their pages, drop backend
        // state, hand back payload + full output.
        let mut completed = Vec::with_capacity(done_ids.len());
        for id in done_ids {
            self.sched.retire(id);
            if let Some(s) = self.backend.step_backend() {
                s.release(id);
            }
            let d = known(self.data.remove(&id), id, "retire");
            let ttft_seconds = d
                .first_token_at
                .map(|t| t.duration_since(d.submitted_at).as_secs_f64())
                .unwrap_or(0.0);
            if let Some(tr) = &self.tracer {
                // No-op unless this tracer is the terminal authority
                // (standalone engines; the cascade router owns
                // `finished` in full-server mode).
                tr.emit_finished(
                    d.trace_key,
                    ttft_seconds,
                    d.submitted_at.elapsed().as_secs_f64(),
                );
            }
            completed.push(Finished {
                payload: d.payload,
                output: d.output,
                exec_seconds: d
                    .admitted_at
                    .map(|t| t.elapsed().as_secs_f64())
                    .unwrap_or(0.0),
                ttft_seconds,
                first_token_at: d.first_token_at,
            });
        }

        self.iterations += 1;
        let (claims_after, cows_after) =
            (self.sched.pool().shared_claims(), self.sched.pool().cow_copies());
        Ok(StepOutcome {
            completed,
            pages_in_use,
            batch: plan.batch(),
            preempted: plan.preempted.len(),
            swap_outs: plan.swapped_out.len(),
            swap_ins: plan.swapped_in.len(),
            swap_pages: plan.swap_out_pages() + plan.swap_in_pages(),
            forced_expansions: plan.forced_expansions,
            prefill_tokens: plan.prefill_tokens(),
            prefix_hit_tokens: (self.sched.prefix_hit_tokens() - hits_before) as usize,
            shared_claims: (claims_after - claims_before) as usize,
            cow_copies: (cows_after - cows_before) as usize,
            migrated_in: plan.migrated_in.len(),
            migrate_pages: plan.migrate_out_pages() + plan.migrate_in_pages(),
            migrated_out,
            spec_accepted,
            spec_rejected,
        })
    }

    /// Remove and return every in-engine request (FIFO-ish: waiting
    /// then running), freeing all pages — the worker-death path.
    pub fn drain(&mut self) -> Vec<T> {
        let ids = self.sched.drain_ids();
        ids.into_iter()
            .filter_map(|id| self.data.remove(&id).map(|d| d.payload))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    use super::*;

    /// Whole-request backend (exercises the adapter path): outputs
    /// `len` copies of `mark`.
    struct WholeBackend {
        mark: i32,
        len: usize,
    }

    impl TierBackend for WholeBackend {
        fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
            Ok(vec![self.mark; self.len.min(max_new)])
        }
    }

    /// Native step backend: records its prefill/release call counts
    /// (and prefilled token totals) through shared handles so tests can
    /// assert the call pattern after the engine consumes the backend.
    #[derive(Default)]
    struct NativeStep {
        prefills: Arc<AtomicUsize>,
        prefill_tokens: Arc<AtomicUsize>,
        releases: Arc<AtomicUsize>,
        fail_decode: bool,
    }

    impl StepBackend for NativeStep {
        fn prefill_chunk(
            &mut self,
            seq: SeqId,
            chunk: &[i32],
            last: bool,
        ) -> Result<Option<i32>> {
            self.prefill_tokens.fetch_add(chunk.len(), Ordering::SeqCst);
            if last {
                self.prefills.fetch_add(1, Ordering::SeqCst);
                Ok(Some(100 + seq as i32))
            } else {
                Ok(None)
            }
        }
        fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
            if self.fail_decode {
                anyhow::bail!("simulated step failure");
            }
            Ok(seqs.iter().map(|&s| 100 + s as i32).collect())
        }
        fn release(&mut self, seq: SeqId) {
            let _ = seq;
            self.releases.fetch_add(1, Ordering::SeqCst);
        }
    }

    impl TierBackend for NativeStep {
        fn generate(&mut self, _prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
            Ok(vec![0; max_new])
        }
        fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
            Some(self)
        }
    }

    fn cfg(pages: usize) -> EngineConfig {
        EngineConfig {
            pool_pages: pages,
            page_tokens: 16,
            max_running: 8,
            prefill_chunk: usize::MAX,
            share_prefixes: false,
            preemption: PreemptionConfig::default(),
        }
    }

    fn swap_cfg(pages: usize, swap_pages: usize) -> EngineConfig {
        EngineConfig {
            preemption: PreemptionConfig {
                mode: PreemptionMode::Swap,
                swap_pages,
                ..PreemptionConfig::default()
            },
            ..cfg(pages)
        }
    }

    fn run_all<T>(engine: &mut EngineCore<T>, max_steps: usize) -> Vec<Finished<T>> {
        let mut out = Vec::new();
        let mut steps = 0;
        while !engine.is_idle() {
            steps += 1;
            assert!(steps <= max_steps, "engine failed to finish");
            out.extend(engine.step().unwrap().completed);
        }
        out
    }

    #[test]
    fn adapter_reproduces_whole_request_outputs() {
        let mut e: EngineCore<usize> =
            EngineCore::new(Box::new(WholeBackend { mark: 7, len: 3 }), cfg(64));
        e.submit(0, vec![1, 2, 3], 8);
        e.submit(1, vec![4], 8);
        let fins = run_all(&mut e, 32);
        assert_eq!(fins.len(), 2);
        for f in &fins {
            assert_eq!(f.output, vec![7, 7, 7], "adapter must reproduce generate()'s output");
        }
    }

    #[test]
    fn adapter_handles_empty_generation() {
        let mut e: EngineCore<usize> =
            EngineCore::new(Box::new(WholeBackend { mark: 0, len: 0 }), cfg(64));
        e.submit(9, vec![1], 4);
        let fins = run_all(&mut e, 8);
        assert_eq!(fins.len(), 1);
        assert!(fins[0].output.is_empty());
    }

    #[test]
    fn native_backend_steps_token_by_token() {
        let mut e: EngineCore<usize> = EngineCore::new(Box::new(NativeStep::default()), cfg(64));
        for i in 0..3usize {
            e.submit(i, vec![1, 2], 4);
        }
        let fins = run_all(&mut e, 16);
        assert_eq!(fins.len(), 3);
        for f in &fins {
            assert_eq!(f.output.len(), 4, "native sequences run to max_new");
            assert!(f.ttft_seconds <= f.exec_seconds + 1e-6 || f.ttft_seconds >= 0.0);
        }
        assert_eq!(e.iterations(), 4, "4 iterations: 1 prefill tick + 3 decode ticks");
    }

    #[test]
    fn chunked_prefill_spreads_prompt_across_iterations() {
        let backend = NativeStep::default();
        let tokens = Arc::clone(&backend.prefill_tokens);
        let mut e: EngineCore<usize> = EngineCore::new(
            Box::new(backend),
            EngineConfig { prefill_chunk: 32, ..cfg(64) },
        );
        e.submit(0, vec![9; 100], 2);
        // 4 chunk ticks (32+32+32+4) then 1 decode tick.
        let mut producing_steps = 0;
        let mut steps = 0;
        while !e.is_idle() {
            steps += 1;
            assert!(steps < 16);
            let out = e.step().unwrap();
            if out.prefill_tokens > 0 {
                assert!(out.prefill_tokens <= 32, "chunk budget must cap the tick");
            }
            if !out.completed.is_empty() {
                producing_steps += 1;
            }
        }
        assert_eq!(steps, 5, "100-token prompt = 4 chunks + 1 decode");
        assert_eq!(producing_steps, 1);
        assert_eq!(tokens.load(Ordering::SeqCst), 100, "every prompt token prefilled once");
    }

    #[test]
    fn prefix_hit_skips_backend_prefill() {
        let backend = NativeStep::default();
        let tokens = Arc::clone(&backend.prefill_tokens);
        let mut e: EngineCore<usize> = EngineCore::new(
            Box::new(backend),
            EngineConfig { share_prefixes: true, ..cfg(64) },
        );
        let prompt = vec![3; 64];
        e.submit(0, prompt.clone(), 6);
        let _ = e.step().unwrap(); // prefill + first token
        let _ = e.step().unwrap(); // publish + decode
        e.submit(1, prompt, 6);
        let mut hit_tokens = 0;
        let fins = {
            let mut out = Vec::new();
            let mut steps = 0;
            while !e.is_idle() {
                steps += 1;
                assert!(steps < 32);
                let o = e.step().unwrap();
                hit_tokens += o.prefix_hit_tokens;
                out.extend(o.completed);
            }
            out
        };
        assert_eq!(fins.len(), 2);
        assert_eq!(hit_tokens, 64, "the re-serve rides the published pages");
        assert_eq!(
            tokens.load(Ordering::SeqCst),
            64,
            "the identical prompt must not be re-prefilled"
        );
        assert_eq!(e.prefix_hit_tokens(), 64);
        let (claims, _cows) = e.sharing_counts();
        assert!(claims >= 4, "64 tokens = 4 pages claimed");
    }

    #[test]
    fn decode_failure_keeps_requests_for_drain() {
        let backend = NativeStep { fail_decode: true, ..Default::default() };
        let mut e: EngineCore<usize> = EngineCore::new(Box::new(backend), cfg(64));
        e.submit(0, vec![1], 4);
        e.submit(1, vec![1], 4);
        // First step admits + prefills (no decode batch yet: both are
        // newly admitted).
        let out = e.step().unwrap();
        assert!(out.completed.is_empty());
        // Second step decodes and fails.
        let err = e.step();
        assert!(err.is_err());
        let drained = e.drain();
        assert_eq!(drained.len(), 2, "every request survives a backend failure");
        assert!(e.is_idle());
    }

    #[test]
    fn preemption_recomputes_and_completes_exactly_once() {
        // Pool of 4 pages x 16 tokens: two 17-token prompts admit (2
        // pages each) and collide when the first grows its 3rd page.
        let backend = NativeStep::default();
        let prefills = Arc::clone(&backend.prefills);
        let releases = Arc::clone(&backend.releases);
        let mut e: EngineCore<u64> = EngineCore::new(Box::new(backend), cfg(4));
        e.submit(10, vec![0; 17], 20);
        e.submit(11, vec![0; 17], 20);
        let mut fins = Vec::new();
        let mut preempted = 0usize;
        let mut steps = 0;
        while !e.is_idle() {
            steps += 1;
            assert!(steps < 300, "must not deadlock");
            let out = e.step().unwrap();
            preempted += out.preempted;
            assert!(out.pages_in_use <= e.pool_pages(), "occupancy within budget");
            fins.extend(out.completed);
        }
        assert!(preempted >= 1, "the tight pool must preempt");
        let mut ids: Vec<u64> = fins.iter().map(|f| f.payload).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![10, 11], "exactly-once completion across preemption");
        for f in &fins {
            assert_eq!(f.output.len(), 20, "preempted output is recomputed in full");
        }
        // The backend saw one completed prefill per (re-)admission and
        // one release per preemption plus one per completion.
        assert_eq!(prefills.load(Ordering::SeqCst), 2 + preempted);
        assert_eq!(releases.load(Ordering::SeqCst), 2 + preempted);
    }

    #[test]
    fn swap_preemption_never_replays_backend_work() {
        // The recompute twin of this scenario re-prefills victims; with
        // swap-to-host the backend must see exactly one completed
        // prefill and zero releases before completion, and every
        // output token is produced exactly once.
        let backend = NativeStep::default();
        let prefills = Arc::clone(&backend.prefills);
        let releases = Arc::clone(&backend.releases);
        let mut e: EngineCore<u64> = EngineCore::new(Box::new(backend), swap_cfg(4, 64));
        e.submit(10, vec![0; 17], 20);
        e.submit(11, vec![0; 17], 20);
        let mut fins = Vec::new();
        let mut swap_outs = 0usize;
        let mut swap_ins = 0usize;
        let mut steps = 0;
        while !e.is_idle() {
            steps += 1;
            assert!(steps < 300, "must not deadlock");
            let out = e.step().unwrap();
            assert_eq!(out.preempted, 0, "swap must replace recompute");
            swap_outs += out.swap_outs;
            swap_ins += out.swap_ins;
            assert!(out.pages_in_use <= e.pool_pages());
            fins.extend(out.completed);
        }
        assert!(swap_outs >= 1, "the tight pool must swap");
        assert_eq!(swap_outs, swap_ins, "every park resumes");
        let mut ids: Vec<u64> = fins.iter().map(|f| f.payload).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![10, 11], "exactly-once completion across swap");
        for f in &fins {
            assert_eq!(f.output.len(), 20);
        }
        // One prefill per sequence (no recompute) and one release per
        // completion only.
        assert_eq!(prefills.load(Ordering::SeqCst), 2, "checkpoint: no re-prefill");
        assert_eq!(releases.load(Ordering::SeqCst), 2, "no mid-flight state drops");
        let (outs, ins, pages) = e.swap_counts();
        assert_eq!(outs as usize, swap_outs);
        assert_eq!(ins as usize, swap_ins);
        assert!(pages > 0);
        assert_eq!(e.n_swapped(), 0);
    }

    #[test]
    fn swap_preemption_works_through_the_whole_request_adapter() {
        // Adapted backends cache their full generation at prefill
        // completion; a swap must carry the cache through the park
        // (recompute would drop and regenerate it).
        let mut e: EngineCore<usize> =
            EngineCore::new(Box::new(WholeBackend { mark: 9, len: 20 }), swap_cfg(4, 64));
        e.submit(0, vec![1; 17], 20);
        e.submit(1, vec![1; 17], 20);
        let mut fins = Vec::new();
        let mut steps = 0;
        while !e.is_idle() {
            steps += 1;
            assert!(steps < 300);
            fins.extend(e.step().unwrap().completed);
        }
        assert_eq!(fins.len(), 2);
        for f in &fins {
            assert_eq!(f.output, vec![9; 20], "cached tokens survive the park");
        }
    }

    #[test]
    fn pool_rescale_is_live() {
        let mut e: EngineCore<usize> =
            EngineCore::new(Box::new(NativeStep::default()), cfg(64));
        assert_eq!(e.pool_pages(), 64);
        e.set_pool_pages(8);
        assert_eq!(e.pool_pages(), 8);
        e.submit(0, vec![1], 2);
        let _ = e.step().unwrap();
        e.set_pool_pages(128);
        assert_eq!(e.pool_pages(), 128);
        let fins = run_all(&mut e, 8);
        assert_eq!(fins.len(), 1);
    }

    #[test]
    fn standalone_tracer_emits_plan_events_and_one_finished_per_request() {
        use crate::obs::{EngineTracer, EventKind, TraceRecorder};
        let rec = Arc::new(TraceRecorder::new(1, 4096));
        let mut e: EngineCore<usize> =
            EngineCore::new(Box::new(NativeStep::default()), cfg(64));
        e.set_tracer(Some(EngineTracer::standalone(Arc::clone(&rec))));
        e.submit(0, vec![1, 2], 4);
        e.submit(1, vec![3, 4], 4);
        e.submit_traced(2, vec![5, 6], 4, None, 777);
        let fins = run_all(&mut e, 32);
        assert_eq!(fins.len(), 3);
        let by_req = rec.per_request();
        // Default trace keys are the engine-local ids; the explicit key
        // overrides (how the cascade links escalation chains).
        let mut keys: Vec<u64> = by_req.keys().copied().collect();
        keys.sort_unstable();
        assert_eq!(keys, vec![0, 1, 777]);
        for (req, evs) in &by_req {
            let fin: Vec<_> =
                evs.iter().filter(|ev| ev.kind == EventKind::Finished).collect();
            assert_eq!(fin.len(), 1, "exactly one terminal event for req {req}");
            assert!(
                evs.iter().any(|ev| ev.kind == EventKind::PrefillChunk),
                "req {req} saw its prefill traced"
            );
            assert!(
                evs.iter().any(|ev| ev.kind == EventKind::DecodeIter),
                "req {req} saw decode ticks traced"
            );
            assert!(fin[0].fb >= fin[0].fa, "e2e latency >= TTFT");
        }
        assert_eq!(rec.dropped_events(), 0);
    }

    #[test]
    fn detached_tracer_means_no_events() {
        use crate::obs::{EngineTracer, TraceRecorder};
        let rec = Arc::new(TraceRecorder::new(1, 64));
        let mut e: EngineCore<usize> =
            EngineCore::new(Box::new(NativeStep::default()), cfg(64));
        e.set_tracer(Some(EngineTracer::standalone(Arc::clone(&rec))));
        e.set_tracer(None);
        e.submit(0, vec![1], 2);
        let _ = run_all(&mut e, 8);
        assert_eq!(rec.n_events(), 0, "detached tracer must be zero-cost");
    }

    #[test]
    fn engine_config_from_replica_model_is_sane() {
        use crate::cluster::ClusterSpec;
        use crate::models::llama_cascade;
        let m = &llama_cascade()[0];
        let rm = ReplicaModel::new(m, &ClusterSpec::paper_testbed(), 1, 1, 768.0);
        let c = EngineConfig::for_replica(&rm, 16);
        assert!(c.pool_pages > rm.max_batch, "pages are finer-grained than request slots");
        assert_eq!(c.max_running, rm.max_batch);
        assert_eq!(c.prefill_chunk, DEFAULT_PREFILL_CHUNK);
        assert!(c.share_prefixes);
        // The nominal fallback holds full-length sequences.
        let n = EngineConfig::nominal(16);
        assert!(n.pool_pages * n.page_tokens >= 8192);
    }
}
