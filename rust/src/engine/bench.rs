//! The calibrated serving benchmark behind `cascadia bench`:
//! whole-batch lockstep vs the continuous-batching engine on a bursty
//! phase-shift trace, through the REAL [`CascadeServer`] routing path.
//!
//! Both modes serve the identical trace with backends whose costs come
//! from the same [`ReplicaModel`] the scheduler optimizes against:
//!
//! * **lockstep** — a worker's `generate` sleeps the whole-request
//!   cost `prefill + tokens × decode_iteration(1)`: serial execution
//!   cannot amortize the per-iteration weight read across batchmates;
//! * **continuous** — a native [`StepBackend`] charges
//!   `prefill(prompt)` at admission and `decode_iteration(b)` per
//!   iteration at the LIVE batch size `b`, so batching amortization is
//!   exactly what the cost model says it is.
//!
//! Time is compressed by `time_scale` (arrivals and sleeps divided,
//! latencies multiplied back for reporting) and decode is represented
//! at `token_scale` tokens per engine step so a run stays in CI
//! budgets. Arrival rates are derived from the model's own capacity
//! terms — the burst phase is provisioned above lockstep capacity but
//! inside continuous capacity, which is precisely the regime the
//! engine exists for. The report (`BENCH_serving.json`) records both
//! modes' tail latency/throughput, per-tier queue telemetry, and the
//! engine's page occupancy (which must never exceed the pool budget).

use std::time::Duration;

use anyhow::{Context, Result};

use crate::cluster::ClusterSpec;
use crate::coordinator::server::{
    CascadeServer, ExecMode, ResponseJudger, ServerConfig, ServerStats, TierBackend,
    TierEngineStats, TierQueueStats,
};
use crate::judge::Judger;
use crate::metrics::LatencySummary;
use crate::models::{llama_cascade, ModelSpec};
use crate::perf::ReplicaModel;
use crate::router::PolicySpec;
use crate::util::json::Json;
use crate::workload::{estimate_stats, generate_phased, paper_trace, PhasedTraceSpec, Request};

use super::core::{EngineConfig, StepBackend};
use super::kv::SeqId;

/// Benchmark knobs; [`BenchConfig::full`] is what `cascadia bench`
/// runs, [`BenchConfig::smoke`] the CI-sized variant.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub seed: u64,
    /// Wall-clock compression: arrivals/sleeps divided, latencies
    /// multiplied back for reporting.
    pub time_scale: f64,
    /// Tokens represented per engine decode step.
    pub token_scale: usize,
    /// Engine decode steps per request (`max_new_tokens`).
    pub decode_steps: usize,
    pub calm_requests: usize,
    pub burst_requests: usize,
    /// Squared coefficient of variation of the burst phase arrivals.
    pub burstiness: f64,
    /// Tier-0 acceptance bar.
    pub threshold: f64,
    pub page_tokens: usize,
}

impl BenchConfig {
    pub fn full() -> BenchConfig {
        BenchConfig {
            seed: 17,
            time_scale: 60.0,
            token_scale: 32,
            decode_steps: 8,
            calm_requests: 120,
            burst_requests: 200,
            burstiness: 4.0,
            threshold: 60.0,
            page_tokens: 16,
        }
    }

    /// Tiny-trace smoke variant for CI: same shape, heavier
    /// compression.
    pub fn smoke() -> BenchConfig {
        BenchConfig {
            calm_requests: 30,
            burst_requests: 60,
            time_scale: 240.0,
            token_scale: 48,
            decode_steps: 6,
            ..BenchConfig::full()
        }
    }
}

/// One mode's results, in uncompressed time.
#[derive(Debug, Clone)]
pub struct ModeReport {
    pub label: String,
    pub served: usize,
    pub latency: LatencySummary,
    pub throughput_rps: f64,
    pub makespan_s: f64,
    pub per_tier_processed: Vec<usize>,
    pub queue: Vec<TierQueueStats>,
    pub engine: Vec<TierEngineStats>,
}

/// The lockstep-vs-continuous comparison written to
/// `BENCH_serving.json`.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub calm_rate: f64,
    pub burst_rate: f64,
    pub n_requests: usize,
    pub burstiness: f64,
    pub lockstep: ModeReport,
    pub continuous: ModeReport,
    /// lockstep p95 / continuous p95 (>1 = engine wins).
    pub p95_speedup: f64,
    /// continuous throughput / lockstep throughput (>1 = engine wins).
    pub throughput_gain: f64,
    /// Page occupancy stayed within the pool budget in every iteration
    /// (and no forced expansions fired).
    pub occupancy_ok: bool,
    /// Continuous beat lockstep on BOTH p95 and throughput.
    pub win: bool,
}

impl BenchReport {
    pub fn to_json(&self) -> Json {
        let mode = |m: &ModeReport| {
            Json::obj(vec![
                ("served", Json::num(m.served as f64)),
                ("p50_s", Json::num(m.latency.p50)),
                ("p95_s", Json::num(m.latency.p95)),
                ("p99_s", Json::num(m.latency.p99)),
                ("mean_s", Json::num(m.latency.mean)),
                ("throughput_rps", Json::num(m.throughput_rps)),
                ("makespan_s", Json::num(m.makespan_s)),
                (
                    "per_tier_processed",
                    Json::arr(
                        m.per_tier_processed.iter().map(|&n| Json::num(n as f64)).collect(),
                    ),
                ),
                (
                    "queue",
                    Json::arr(
                        m.queue
                            .iter()
                            .map(|q| {
                                Json::obj(vec![
                                    ("peak_depth", Json::num(q.peak_depth as f64)),
                                    ("admitted", Json::num(q.admitted as f64)),
                                    ("mean_wait_s", Json::num(q.mean_wait_s)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "engine",
                    Json::arr(
                        m.engine
                            .iter()
                            .map(|e| {
                                Json::obj(vec![
                                    ("pool_pages", Json::num(e.pool_pages as f64)),
                                    ("peak_pool_pages", Json::num(e.peak_pool_pages as f64)),
                                    ("peak_pages", Json::num(e.peak_pages as f64)),
                                    ("preemptions", Json::num(e.preemptions as f64)),
                                    ("iterations", Json::num(e.iterations as f64)),
                                    (
                                        "forced_expansions",
                                        Json::num(e.forced_expansions as f64),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        Json::obj(vec![
            (
                "trace",
                Json::obj(vec![
                    ("n_requests", Json::num(self.n_requests as f64)),
                    ("calm_rate_rps", Json::num(self.calm_rate)),
                    ("burst_rate_rps", Json::num(self.burst_rate)),
                    ("burstiness", Json::num(self.burstiness)),
                ]),
            ),
            ("lockstep", mode(&self.lockstep)),
            ("continuous", mode(&self.continuous)),
            ("p95_speedup", Json::num(self.p95_speedup)),
            ("throughput_gain", Json::num(self.throughput_gain)),
            ("occupancy_ok", Json::Bool(self.occupancy_ok)),
            ("win", Json::Bool(self.win)),
        ])
    }
}

/// Sleeps simulated seconds, batching sub-millisecond debts so OS
/// timer granularity does not swamp compressed iteration costs.
struct PacedSleeper {
    time_scale: f64,
    debt: f64,
}

impl PacedSleeper {
    fn pay(&mut self, sim_secs: f64) {
        self.debt += sim_secs / self.time_scale;
        if self.debt >= 1e-3 {
            std::thread::sleep(Duration::from_secs_f64(self.debt.min(5.0)));
            self.debt = 0.0;
        }
    }
}

/// Whole-request calibrated backend (the lockstep discipline): serial
/// execution pays the full unamortized decode cost per request.
struct LockstepCalibrated {
    tier: usize,
    rm: ReplicaModel,
    decode_tokens: f64,
    sleeper: PacedSleeper,
}

impl TierBackend for LockstepCalibrated {
    fn generate(&mut self, prompt: &[i32], _max_new: usize) -> Result<Vec<i32>> {
        let secs = self.rm.prefill_latency(prompt.len() as f64)
            + self.decode_tokens * self.rm.decode_iteration(1);
        self.sleeper.pay(secs);
        Ok(vec![self.tier as i32])
    }
}

/// Step-calibrated backend (the continuous engine): decode cost is
/// `decode_iteration(b)` at the LIVE batch size — amortization is
/// whatever the cost model says.
struct ContinuousCalibrated {
    tier: usize,
    rm: ReplicaModel,
    token_scale: f64,
    sleeper: PacedSleeper,
}

impl StepBackend for ContinuousCalibrated {
    fn prefill(&mut self, _seq: SeqId, prompt: &[i32]) -> Result<i32> {
        let secs = self.rm.prefill_latency(prompt.len() as f64);
        self.sleeper.pay(secs);
        Ok(self.tier as i32)
    }

    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
        let secs = self.rm.decode_iteration(seqs.len()) * self.token_scale;
        self.sleeper.pay(secs);
        Ok(vec![self.tier as i32; seqs.len()])
    }

    fn release(&mut self, _seq: SeqId) {}
}

impl TierBackend for ContinuousCalibrated {
    fn generate(&mut self, prompt: &[i32], _max_new: usize) -> Result<Vec<i32>> {
        // Fallback (unused on the engine path): whole-request cost.
        let secs = self.rm.prefill_latency(prompt.len() as f64)
            + self.token_scale * self.rm.decode_iteration(1);
        self.sleeper.pay(secs);
        Ok(vec![self.tier as i32])
    }

    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        Some(self)
    }
}

/// Scores a benchmark response with the offline judger (the replay
/// harness's convention: prompt\[0\] carries the request id, output\[0\]
/// the serving tier).
struct BenchJudger {
    requests: Vec<Request>,
    models: Vec<ModelSpec>,
    judger: Judger,
}

impl ResponseJudger for BenchJudger {
    fn score(&self, prompt: &[i32], output: &[i32]) -> f64 {
        let id = prompt.first().copied().unwrap_or(0).max(0) as usize;
        let tier = (output.first().copied().unwrap_or(0).max(0) as usize)
            .min(self.models.len() - 1);
        match self.requests.get(id) {
            Some(req) => self.judger.score(&self.models[tier], req, tier),
            None => 0.0,
        }
    }
}

fn mode_report(label: &str, stats: &ServerStats, time_scale: f64) -> ModeReport {
    let lat: Vec<f64> = stats
        .completions
        .iter()
        .map(|c| c.e2e_latency.as_secs_f64() * time_scale)
        .collect();
    let makespan = stats.wall_clock.as_secs_f64() * time_scale;
    ModeReport {
        label: label.to_string(),
        served: stats.completions.len(),
        latency: LatencySummary::of(&lat),
        throughput_rps: stats.completions.len() as f64 / makespan.max(1e-9),
        makespan_s: makespan,
        per_tier_processed: stats.per_tier_processed.clone(),
        queue: stats
            .queue
            .iter()
            .map(|q| TierQueueStats { mean_wait_s: q.mean_wait_s * time_scale, ..*q })
            .collect(),
        engine: stats.engine.clone(),
    }
}

/// Run the calibrated lockstep-vs-continuous serving benchmark.
pub fn run_serving_bench(cfg: &BenchConfig) -> Result<BenchReport> {
    let cascade = llama_cascade();
    let cluster = ClusterSpec::paper_testbed();
    let replicas: Vec<usize> = vec![2, 1];
    let max_batch: Vec<usize> = vec![16, 8];
    let decode_tokens = (cfg.decode_steps * cfg.token_scale) as f64;

    // Probe trace for mean lengths (rates don't matter here).
    let probe = generate_phased(
        &PhasedTraceSpec {
            phases: vec![
                (paper_trace(3, 1.0), cfg.calm_requests.max(50)),
                (paper_trace(1, 1.0), cfg.burst_requests.max(50)),
            ],
        },
        cfg.seed,
    );
    let avg_in = estimate_stats(&probe.requests).avg_input;
    let avg_ctx = avg_in + decode_tokens;

    // Replica cost models: the 8B tier on single GPUs, the 70B tier on
    // a TP-8 server — the shapes the paper's testbed serves them at.
    let rms: Vec<ReplicaModel> = vec![
        ReplicaModel::new(&cascade[0], &cluster, 1, 1, avg_ctx),
        ReplicaModel::new(&cascade[1], &cluster, 8, 1, avg_ctx),
    ];

    // Capacity-derived rates: the burst is provisioned ABOVE lockstep
    // capacity but comfortably inside continuous capacity, on the
    // cascade's bottleneck tier (tier 1 sees ~half the traffic via
    // escalation on the hard phase).
    let esc = 0.5;
    let lock_cap = |t: usize| {
        replicas[t] as f64
            / (rms[t].prefill_latency(avg_in) + decode_tokens * rms[t].decode_iteration(1))
    };
    let cont_cap = |t: usize| {
        let b = (max_batch[t] / replicas[t]).clamp(1, rms[t].max_batch.max(1));
        replicas[t] as f64 * b as f64
            / (decode_tokens * rms[t].decode_iteration(b)
                + b as f64 * rms[t].prefill_latency(avg_in))
    };
    let bound_lock = lock_cap(0).min(lock_cap(1) / esc);
    let bound_cont = cont_cap(0).min(cont_cap(1) / esc);
    let burst_rate = (1.5 * bound_lock).min(0.7 * bound_cont).max(1.02 * bound_lock);
    let calm_rate = 0.4 * bound_lock;

    // The bursty phase-shift trace: calm/easy, then a bursty hard
    // phase (gamma renewal with SCV = burstiness).
    let mut burst_spec = paper_trace(1, burst_rate);
    burst_spec.burstiness = cfg.burstiness;
    let phased = generate_phased(
        &PhasedTraceSpec {
            phases: vec![
                (paper_trace(3, calm_rate), cfg.calm_requests),
                (burst_spec, cfg.burst_requests),
            ],
        },
        cfg.seed,
    );
    let trace: Vec<(f64, Vec<i32>)> = phased
        .requests
        .iter()
        .map(|r| {
            let len = (r.input_tokens as usize).clamp(2, 4096);
            let mut prompt = vec![0i32; len];
            prompt[0] = r.id as i32;
            (r.arrival / cfg.time_scale, prompt)
        })
        .collect();

    let judger = BenchJudger {
        requests: phased.requests.clone(),
        models: cascade.clone(),
        judger: Judger::new(cfg.seed),
    };
    let policy = PolicySpec::threshold(vec![cfg.threshold])?;

    // --- Lockstep baseline ---
    let lock_server = CascadeServer::new(ServerConfig {
        replicas: replicas.clone(),
        max_batch: max_batch.clone(),
        policy: policy.clone(),
        max_new_tokens: cfg.decode_steps,
        exec: ExecMode::BatchLockstep,
    })?;
    let rms_lock = rms.clone();
    let (ts, dt) = (cfg.time_scale, decode_tokens);
    let lock_factory = move |tier: usize| -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(LockstepCalibrated {
            tier,
            rm: rms_lock[tier].clone(),
            decode_tokens: dt,
            sleeper: PacedSleeper { time_scale: ts, debt: 0.0 },
        }))
    };
    let lock_stats = lock_server
        .serve(&trace, &lock_factory, &judger)
        .context("lockstep benchmark run")?;

    // --- Continuous engine ---
    let engines: Vec<EngineConfig> =
        rms.iter().map(|rm| EngineConfig::for_replica(rm, cfg.page_tokens)).collect();
    let cont_server = CascadeServer::new(ServerConfig {
        replicas: replicas.clone(),
        max_batch: max_batch.clone(),
        policy,
        max_new_tokens: cfg.decode_steps,
        exec: ExecMode::Continuous(engines),
    })?;
    let rms_cont = rms.clone();
    let tsc = cfg.token_scale as f64;
    let cont_factory = move |tier: usize| -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(ContinuousCalibrated {
            tier,
            rm: rms_cont[tier].clone(),
            token_scale: tsc,
            sleeper: PacedSleeper { time_scale: ts, debt: 0.0 },
        }))
    };
    let cont_stats = cont_server
        .serve(&trace, &cont_factory, &judger)
        .context("continuous benchmark run")?;

    let lockstep = mode_report("lockstep", &lock_stats, cfg.time_scale);
    let continuous = mode_report("continuous", &cont_stats, cfg.time_scale);
    let occupancy_ok = continuous
        .engine
        .iter()
        .all(|e| e.peak_pages <= e.peak_pool_pages && e.forced_expansions == 0);
    let p95_speedup = lockstep.latency.p95 / continuous.latency.p95.max(1e-9);
    let throughput_gain = continuous.throughput_rps / lockstep.throughput_rps.max(1e-9);
    let win = p95_speedup > 1.0 && throughput_gain > 1.0;
    Ok(BenchReport {
        calm_rate,
        burst_rate,
        n_requests: phased.requests.len(),
        burstiness: cfg.burstiness,
        lockstep,
        continuous,
        p95_speedup,
        throughput_gain,
        occupancy_ok,
        win,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_continuous_wins_within_budget() {
        // A sub-smoke run (CI test budget): the engine must beat the
        // lockstep baseline on tail latency and throughput while the
        // page occupancy stays inside every pool.
        let cfg = BenchConfig {
            calm_requests: 16,
            burst_requests: 36,
            time_scale: 400.0,
            ..BenchConfig::smoke()
        };
        let report = run_serving_bench(&cfg).unwrap();
        assert_eq!(report.lockstep.served, 52);
        assert_eq!(report.continuous.served, 52);
        assert!(report.occupancy_ok, "page occupancy exceeded a pool budget");
        for e in &report.continuous.engine {
            assert!(e.iterations > 0);
            assert!(e.peak_pages > 0);
        }
        assert!(
            report.win,
            "continuous must win: p95 speedup {:.2}, throughput gain {:.2}",
            report.p95_speedup, report.throughput_gain
        );
        // The report serializes with the fields CI greps for.
        let json = report.to_json().to_string();
        assert!(json.contains("\"win\":true"));
        assert!(json.contains("\"occupancy_ok\":true"));
    }
}
